//! Bench: regenerate **Figure 8** (ALB with cyclic vs blocked edge
//! distribution) and time it.
//!
//! Expected shape: cyclic wins everywhere (paper: up to 4x) — the win
//! emerges from the cache model (aligned binary-search trajectories +
//! coalesced edge reads), not from a hard-coded factor.

use alb_graph::apps::App;
use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -1, ..ReproConfig::default() };
    let apps = [App::Bfs, App::Sssp, App::Cc];
    let mut rendered = String::new();
    let stats = time_runs("fig8/cyclic-vs-blocked", 3, || {
        rendered = repro::fig8(&rc, &apps).expect("fig8").render();
    });
    println!("{rendered}");
    println!("{}", stats.report());
}
