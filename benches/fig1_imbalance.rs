//! Bench: regenerate **Figure 1** (thread-block load imbalance under TWC:
//! sssp/rmat rounds 0-2; bfs on road vs rmat; bfs vs pr) and time it.
//!
//! Expected shape: early sssp/bfs rounds on rmat show imbalance factors
//! >> 1 (one block owns the hub); road-s and pr stay near 1.

use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -1, ..ReproConfig::default() };
    let mut rendered = String::new();
    let stats = time_runs("fig1/block-imbalance", 3, || {
        rendered = repro::fig1(&rc).expect("fig1");
    });
    // The raw per-block vectors are long; print the summary lines only.
    for line in rendered.lines().filter(|l| !l.trim_start().starts_with("blocks:")) {
        println!("{line}");
    }
    println!("{}", stats.report());
}
