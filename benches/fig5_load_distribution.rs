//! Bench: regenerate **Figure 5** (per-block load distribution, TWC vs ALB,
//! for bfs/sssp on rmat, cc on road, pr on rmat) and time it.
//!
//! Expected shape: under TWC one block carries the hub's edges; under ALB
//! the LB kernel's edges are spread evenly and the TWC kernel keeps only
//! the small-degree remainder; road/pr identical under both.

use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -1, ..ReproConfig::default() };
    let mut rendered = String::new();
    let stats = time_runs("fig5/twc-vs-alb-distribution", 3, || {
        rendered = repro::fig5(&rc).expect("fig5");
    });
    for line in rendered.lines().filter(|l| !l.trim_start().starts_with("blocks:")) {
        println!("{line}");
    }
    println!("{}", stats.report());
}
