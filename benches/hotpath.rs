//! Bench: the simulator/engine hot paths in isolation — the targets of the
//! EXPERIMENTS.md §Perf optimization pass.
//!
//! Every optimized case is measured next to its `-ref` twin, the preserved
//! fresh-allocation implementation (`Balancer::schedule`,
//! `Simulator::simulate_reference`, `engine::run_push_reference`) that
//! matches the pre-optimization hot path — so the reported speedups are
//! measured in-binary and machine-independent, and the two paths are
//! asserted bit-identical before timing.
//!
//! Cases:
//! * `inspector[-ref]`    — ALB's threshold split + prefix build.
//! * `twc-sim[-ref]`      — per-thread TWC kernel accounting.
//! * `lb-sim-*[-ref]`     — LB kernel cache-model simulation.
//! * `frontier[-ref]`     — bitmap drain vs sort+dedup next-worklist.
//! * `frontier-drain[-ref]` — SWAR word-walk drain (4-word zero-skip +
//!                          byte-table decode) vs the preserved scalar
//!                          word walk, on a sparse 4M-vertex worklist.
//! * `degree-tally[-ref]` — warp-hoisted 8-wide per-block bottleneck
//!                          reduction vs the scalar per-thread tally on
//!                          the k80-like grid. The bench also records the
//!                          deterministic `reorder_*` locality metrics: a
//!                          label-gather cache trace of the rmat graph
//!                          under `--reorder none|degree|rcm`.
//! * `engine-bfs[-ref]`   — whole bfs run on rmat (end-to-end single GPU).
//! * `engine-sssp[-ref]`  — whole sssp run on rmat.
//! * `sim-par-*` / `sim-1t-*` — the pooled (DESIGN.md §9) vs 1-thread
//!                          kernel simulation of an all-active ALB round on
//!                          the rmat20 / rmat22 presets, where the block
//!                          loop dominates; their ratio is
//!                          `speedup_sim_parallel`.
//! * `partition-cvc-8`    — CVC partitioning of the rmat input.
//! * `dist-superstep`     — whole 4-GPU CVC bfs through the coordinator's
//!                          schedule-driven exchange; records per-round
//!                          comm bytes (total / intra / inter) as metrics.
//! * `serve-cold` / `serve-hit` — queries through the whole `alb serve`
//!                          stack (TCP loopback framing, protocol parse,
//!                          identity resolution) with the result cache
//!                          disabled (every query executes) vs warm (every
//!                          query served from the LRU); their ratio is
//!                          `speedup_serve_cache`.
//!
//! Flags (after `--` under `cargo bench --bench hotpath`):
//! * `--check-ratios <path>`    THE CI GATE (armed day one): compare this
//!                              run's machine-independent in-binary ratios
//!                              against the committed thresholds in
//!                              `<path>` (`BENCH_hotpath.json` at the repo
//!                              root): `min_speedup_engine_bfs`,
//!                              `min_speedup_engine_sssp`,
//!                              `min_speedup_sim_parallel`,
//!                              `min_speedup_frontier_drain`,
//!                              `min_speedup_degree_tally`,
//!                              `min_speedup_serve_cache`,
//!                              `max_reorder_cache_miss_ratio`,
//!                              `max_dist_comm_bytes_per_round`, and
//!                              `max_dist_comm_bytes_inter_per_round`.
//!                              Thresholds are requirements, not recorded
//!                              timings, so the gate needs no seeding run;
//!                              a missing threshold key is a LOUD failure.
//! * `--out <path>`             write the results as BENCH-json.
//! * `--check <baseline.json>`  optional *absolute* comparison: fail if
//!                              `engine-bfs` mean regresses more than
//!                              `--max-regress` percent vs the file.
//!                              Absolute ms are machine-dependent, so this
//!                              stays opt-in for same-machine trend
//!                              tracking; a baseline with an empty `cases`
//!                              array is a LOUD failure (the gate must
//!                              never silently skip): seed it from the
//!                              bench-smoke CI artifact
//!                              (`BENCH_hotpath.ci.json`).
//! * `--max-regress <pct>`      regression tolerance (default 25).
//! * `--require-speedup <x>`    fail unless both engine speedups >= x AND
//!                              `speedup_sim_parallel` >= min(x, 1.5) —
//!                              the parallel-sim target caps at 1.5x, and
//!                              a loosened x loosens it too.

use alb_graph::apps::engine::{run, run_push_reference, EngineConfig};
use alb_graph::apps::worklist::NextWorklist;
use alb_graph::apps::App;
use alb_graph::config::Framework;
use alb_graph::coordinator::{run_distributed, ClusterConfig};
use alb_graph::exec::Pool;
use alb_graph::gpu::{CacheSim, CostModel, GpuSpec, SimScratch, Simulator};
use alb_graph::graph::gen::rmat::{self, RmatConfig};
use alb_graph::graph::reorder::{self, Reorder};
use alb_graph::graph::{inputs, CsrGraph};
use alb_graph::lb::{alb, Direction, Distribution};
use alb_graph::metrics::bench::{
    mean_of, read_json, read_metric, speedup, time_runs, write_json, BenchStats,
};
use alb_graph::partition::{partition, Policy};
use alb_graph::serve::{ServeOpts, Server};
use alb_graph::session::Session;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let out_path = arg_value(&args, "--out");
    let check_path = arg_value(&args, "--check");
    let ratios_path = arg_value(&args, "--check-ratios");
    let max_regress: f64 = arg_value(&args, "--max-regress")
        .and_then(|s| s.parse().ok())
        .unwrap_or(25.0);
    let require_speedup: Option<f64> =
        arg_value(&args, "--require-speedup").and_then(|s| s.parse().ok());

    let g = CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(16, 7)));
    let spec = GpuSpec::default_sim();
    let cost = CostModel::default();
    let sim = Simulator::new(spec.clone(), cost);
    let active: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let mut cases: Vec<BenchStats> = Vec::new();
    let mut push = |s: BenchStats| {
        println!("{}", s.report());
        cases.push(s);
    };

    // --- inspector ---
    let mut ins = alb::Inspection::default();
    push(time_runs("hotpath/inspector", 10, || {
        alb::inspect_into(&active, &g, Direction::Push, &spec,
                          spec.huge_threshold(), &mut ins);
        ins.huge.len()
    }));
    push(time_runs("hotpath/inspector-ref", 10, || {
        alb::inspect(&active, &g, Direction::Push, &spec, spec.huge_threshold())
            .huge
            .len()
    }));

    // --- TWC kernel simulation ---
    let sched_twc = alb::schedule(
        &active, &g, Direction::Push, &spec, Distribution::Cyclic,
        u64::MAX, // force everything through TWC
        g.num_vertices() as u64,
    );
    let mut scratch = SimScratch::new();
    push(time_runs("hotpath/twc-sim", 10, || {
        sim.simulate_into(&sched_twc, true, &mut scratch);
        scratch.round.total_cycles
    }));
    push(time_runs("hotpath/twc-sim-ref", 10, || {
        sim.simulate_reference(&sched_twc, true).total_cycles
    }));

    // --- LB kernel simulation (both distributions) ---
    for dist in [Distribution::Cyclic, Distribution::Blocked] {
        let sched = alb::schedule(
            &active, &g, Direction::Push, &spec, dist,
            spec.huge_threshold(), g.num_vertices() as u64,
        );
        assert_eq!(
            sim.simulate(&sched, true),
            sim.simulate_reference(&sched, true),
            "optimized and reference simulations diverge ({dist:?})"
        );
        push(time_runs(&format!("hotpath/lb-sim-{dist:?}"), 10, || {
            sim.simulate_into(&sched, true, &mut scratch);
            scratch.round.total_cycles
        }));
        push(time_runs(&format!("hotpath/lb-sim-{dist:?}-ref"), 10, || {
            sim.simulate_reference(&sched, true).total_cycles
        }));
    }

    // --- frontier generation ---
    let pushes: Vec<u32> = {
        // Deterministic duplicate-heavy push stream.
        let n = g.num_vertices() as u64;
        let mut x = 88172645463325252u64;
        (0..400_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x % n) as u32
            })
            .collect()
    };
    let mut wl = NextWorklist::new(g.num_vertices());
    let mut drained: Vec<u32> = Vec::new();
    push(time_runs("hotpath/frontier", 10, || {
        for &v in &pushes {
            wl.push(v);
        }
        wl.take_sorted_into(&mut drained);
        drained.len()
    }));
    push(time_runs("hotpath/frontier-ref", 10, || {
        let mut next: Vec<u32> = Vec::new();
        let mut flags = vec![false; g.num_vertices()];
        for &v in &pushes {
            if !flags[v as usize] {
                flags[v as usize] = true;
                next.push(v);
            }
        }
        next.sort_unstable();
        next.len()
    }));

    // --- SWAR frontier drain (ISSUE 7) ---
    // The mid-traversal regime: a sparse frontier over a large vertex
    // range, where the drain's cost is the word walk itself. The SWAR
    // path's 4-word zero-skip and byte-table decode are timed against the
    // preserved scalar word walk on the same worklist type, asserted
    // bit-identical first.
    let drain_n = 1usize << 22;
    let sparse: Vec<u32> = {
        let mut x = 2862933555777941757u64;
        (0..4096)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x % drain_n as u64) as u32
            })
            .collect()
    };
    let mut wl_opt = NextWorklist::new(drain_n);
    let mut wl_ref = NextWorklist::new(drain_n);
    {
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for &v in &sparse {
            wl_opt.push(v);
            wl_ref.push(v);
        }
        wl_opt.take_sorted_into(&mut a);
        wl_ref.take_sorted_into_ref(&mut b);
        assert_eq!(a, b, "SWAR drain diverges from the scalar reference");
    }
    push(time_runs("hotpath/frontier-drain", 10, || {
        for &v in &sparse {
            wl_opt.push(v);
        }
        wl_opt.take_sorted_into(&mut drained);
        drained.len()
    }));
    push(time_runs("hotpath/frontier-drain-ref", 10, || {
        for &v in &sparse {
            wl_ref.push(v);
        }
        wl_ref.take_sorted_into_ref(&mut drained);
        drained.len()
    }));

    // --- SWAR degree tally (ISSUE 7) ---
    // The per-block bottleneck reduction over the full k80-like grid
    // (26,624 threads), warp-hoisted 8-wide max vs the scalar
    // thread-at-a-time walk (which re-divides t / warp_size per lane).
    // Both entry points are the exact chunk walks `sim_twc_into` uses.
    let k80 = Simulator::new(GpuSpec::k80_like(), CostModel::default());
    let (tally_t, tally_w, tally_c) = {
        let mut x = 0x243f6a8885a308d3u64;
        let mut rng = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            x >> 40
        };
        let t: Vec<u64> = (0..26_624).map(|_| rng()).collect();
        let w: Vec<u64> = (0..832).map(|_| rng()).collect();
        let c: Vec<u64> = (0..104).map(|_| rng()).collect();
        (t, w, c)
    };
    let (mut tally_out, mut tally_out_ref) = (Vec::new(), Vec::new());
    k80.bench_degree_tally(&tally_t, &tally_w, &tally_c, &mut tally_out);
    k80.bench_degree_tally_ref(&tally_t, &tally_w, &tally_c, &mut tally_out_ref);
    assert_eq!(tally_out, tally_out_ref, "SWAR tally diverges from reference");
    push(time_runs("hotpath/degree-tally", 10, || {
        let mut acc = 0u64;
        for _ in 0..64 {
            k80.bench_degree_tally(&tally_t, &tally_w, &tally_c, &mut tally_out);
            acc = acc.wrapping_add(tally_out[0]);
        }
        acc
    }));
    push(time_runs("hotpath/degree-tally-ref", 10, || {
        let mut acc = 0u64;
        for _ in 0..64 {
            k80.bench_degree_tally_ref(&tally_t, &tally_w, &tally_c, &mut tally_out_ref);
            acc = acc.wrapping_add(tally_out_ref[0]);
        }
        acc
    }));

    // --- reorder locality (ISSUE 7) ---
    // Deterministic label-gather trace: walk every out-edge in vertex
    // order and touch the destination's 4-byte label through a fresh
    // default-spec cache. Pure simulation — the miss counts are
    // bit-deterministic, so their ratio gates on any machine. The gate
    // takes the best reordering (the run-time `--reorder` choice is the
    // user's), which must not lose to generator order.
    let label_gather_misses = |gr: &CsrGraph| -> u64 {
        let mut c = CacheSim::new(spec.l1_kb, spec.cache_line_bytes, spec.cache_assoc);
        for v in 0..gr.num_vertices() as u32 {
            for &d in gr.out_edges(v).0 {
                c.access(d as u64 * 4);
            }
        }
        c.misses()
    };
    let misses_none = label_gather_misses(&g);
    let misses_degree = label_gather_misses(&reorder::reorder(&g, Reorder::Degree).0);
    let misses_rcm = label_gather_misses(&reorder::reorder(&g, Reorder::Rcm).0);
    let reorder_miss_ratio =
        misses_degree.min(misses_rcm) as f64 / misses_none.max(1) as f64;

    // --- end-to-end engines ---
    let src = g.max_out_degree_vertex();
    let cfg: EngineConfig = Framework::DIrglAlb.engine_config(spec.clone());
    for (app, name) in [(App::Bfs, "engine-bfs"), (App::Sssp, "engine-sssp")] {
        let hot = run(app, &mut g.clone(), src, &cfg, None).unwrap();
        let golden = run_push_reference(app, &mut g.clone(), src, &cfg).unwrap();
        assert_eq!(hot, golden, "hot path and reference diverge on {name}");
        // Clone once outside the timed region (push runs never mutate the
        // graph) so the O(V+E) copy does not dilute the measured ratio.
        let mut gg = g.clone();
        push(time_runs(&format!("hotpath/{name}"), 5, || {
            run(app, &mut gg, src, &cfg, None).unwrap().total_cycles
        }));
        let mut gg = g.clone();
        push(time_runs(&format!("hotpath/{name}-ref"), 5, || {
            run_push_reference(app, &mut gg, src, &cfg).unwrap().total_cycles
        }));
    }

    push(time_runs("hotpath/partition-cvc-8", 5, || partition(&g, 8, Policy::Cvc)));

    // --- serve query path (ISSUE 10) ---
    // The daemon's two regimes through the full stack — TCP loopback
    // framing, protocol parse, identity resolution — on the bench graph.
    // Cold: the result cache disabled, so every query runs bfs on the
    // session. Hit: a warm LRU, so every query is rendered from the cached
    // reply. Both time the same client loop against a live listener, so
    // the ratio is the cache's end-to-end win, gated machine-independently
    // as `min_speedup_serve_cache`.
    let spawn_serve = |cache_entries: usize| {
        Server::spawn(
            Session::new(g.clone(), "rmat16", cfg.clone()),
            ServeOpts { max_inflight: 4, cache_entries, max_rounds: 1_000_000 },
            0,
        )
        .unwrap()
    };
    let serve_round =
        |rd: &mut BufReader<TcpStream>, wr: &mut TcpStream, line: &str| -> usize {
            writeln!(wr, "{line}").unwrap();
            wr.flush().unwrap();
            let mut reply = String::new();
            rd.read_line(&mut reply).unwrap();
            assert!(reply.contains("\"status\":\"ok\""), "{reply}");
            reply.len()
        };
    let bfs_line = format!(r#"{{"app":"bfs","source":{src}}}"#);
    const SERVE_QUERIES: usize = 16;
    {
        let cold = spawn_serve(0);
        let s = TcpStream::connect(cold.addr()).unwrap();
        let (mut rd, mut wr) = (BufReader::new(s.try_clone().unwrap()), s);
        push(time_runs("hotpath/serve-cold", 5, || {
            (0..SERVE_QUERIES)
                .map(|_| serve_round(&mut rd, &mut wr, &bfs_line))
                .sum::<usize>()
        }));
        cold.stop();
    }
    {
        let hot = spawn_serve(64);
        let s = TcpStream::connect(hot.addr()).unwrap();
        let (mut rd, mut wr) = (BufReader::new(s.try_clone().unwrap()), s);
        serve_round(&mut rd, &mut wr, &bfs_line); // warm the cache
        push(time_runs("hotpath/serve-hit", 5, || {
            (0..SERVE_QUERIES)
                .map(|_| serve_round(&mut rd, &mut wr, &bfs_line))
                .sum::<usize>()
        }));
        hot.stop();
    }

    // --- distributed superstep (ISSUE 4: schedule-driven exchange) ---
    // A whole 4-GPU CVC bfs through the coordinator: per-GPU supersteps on
    // the shared pool plus the plan-driven reduce/broadcast. The recorded
    // comm metrics come from the exchange's actual byte counts, so the
    // perf trajectory tracks wire volume alongside host time.
    let cluster = ClusterConfig::single_host(4);
    let dist = run_distributed(App::Bfs, &g, src, &cfg, &cluster, None).unwrap();
    let dist_rounds = dist.rounds.len().max(1) as f64;
    // All three comm metrics are per-round averages so they stay mutually
    // comparable and independent of round count.
    let dist_bytes_per_round = dist.comm_bytes as f64 / dist_rounds;
    let dist_intra_per_round = dist.comm_bytes_intra as f64 / dist_rounds;
    let dist_inter_per_round = dist.comm_bytes_inter as f64 / dist_rounds;
    push(time_runs("hotpath/dist-superstep", 5, || {
        run_distributed(App::Bfs, &g, src, &cfg, &cluster, None)
            .unwrap()
            .total_cycles
    }));

    // --- intra-GPU parallel simulation (DESIGN.md §9) ---
    // An all-active ALB round on the power-law presets whose hubs force the
    // LB kernel, so the simulator's block/warp walks dominate. The pooled
    // path is timed against the 1-thread sequential walk in-binary; both
    // are asserted bit-identical to the golden reference first. >= 4 lanes
    // even on small runners so the recorded ratio reflects the pool, not
    // the host's core count.
    let par_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .max(4);
    let pool = Pool::new(par_threads);
    for preset in ["rmat20", "rmat22"] {
        let pg = inputs::build(preset, 0, 7)
            .unwrap_or_else(|| panic!("unknown preset {preset}"));
        let pactive: Vec<u32> = (0..pg.num_vertices() as u32).collect();
        let psched = alb::schedule(
            &pactive, &pg, Direction::Push, &spec, Distribution::Cyclic,
            spec.huge_threshold(), pg.num_vertices() as u64,
        );
        assert!(psched.lb.is_some(), "{preset} hub must trigger the LB kernel");
        let mut sp = SimScratch::new();
        sim.simulate_into_pooled(&psched, true, &mut sp, &pool);
        assert_eq!(
            sp.round,
            sim.simulate_reference(&psched, true),
            "pooled simulation diverges from the reference on {preset}"
        );
        push(time_runs(&format!("hotpath/sim-par-{preset}"), 10, || {
            sim.simulate_into_pooled(&psched, true, &mut sp, &pool);
            sp.round.total_cycles
        }));
        push(time_runs(&format!("hotpath/sim-1t-{preset}"), 10, || {
            sim.simulate_into(&psched, true, &mut scratch);
            scratch.round.total_cycles
        }));
    }

    // --- speedups (ref mean / optimized mean, measured in this binary) ---
    let ratio = |name: &str| -> f64 {
        speedup(
            &cases,
            &format!("hotpath/{name}"),
            &format!("hotpath/{name}-ref"),
        )
    };
    let sim_par = |preset: &str| -> f64 {
        speedup(
            &cases,
            &format!("hotpath/sim-par-{preset}"),
            &format!("hotpath/sim-1t-{preset}"),
        )
    };
    // The headline §9 metric: the worst of the two presets, so it cannot be
    // carried by one favorable input.
    let speedup_sim_parallel = sim_par("rmat20").min(sim_par("rmat22"));
    let speedup_serve_cache =
        speedup(&cases, "hotpath/serve-hit", "hotpath/serve-cold");
    let metrics: Vec<(&str, f64)> = vec![
        ("speedup_engine_bfs", ratio("engine-bfs")),
        ("speedup_engine_sssp", ratio("engine-sssp")),
        ("speedup_lb_sim_cyclic", ratio("lb-sim-Cyclic")),
        ("speedup_frontier", ratio("frontier")),
        ("speedup_frontier_drain", ratio("frontier-drain")),
        ("speedup_degree_tally", ratio("degree-tally")),
        ("reorder_cache_miss_ratio", reorder_miss_ratio),
        (
            "reorder_cache_miss_ratio_degree",
            misses_degree as f64 / misses_none.max(1) as f64,
        ),
        (
            "reorder_cache_miss_ratio_rcm",
            misses_rcm as f64 / misses_none.max(1) as f64,
        ),
        ("reorder_gather_misses_none", misses_none as f64),
        ("reorder_gather_misses_degree", misses_degree as f64),
        ("reorder_gather_misses_rcm", misses_rcm as f64),
        ("speedup_sim_parallel_rmat20", sim_par("rmat20")),
        ("speedup_sim_parallel_rmat22", sim_par("rmat22")),
        ("speedup_sim_parallel", speedup_sim_parallel),
        ("speedup_serve_cache", speedup_serve_cache),
        ("sim_parallel_threads", par_threads as f64),
        ("dist_comm_bytes_per_round", dist_bytes_per_round),
        ("dist_comm_bytes_intra_per_round", dist_intra_per_round),
        ("dist_comm_bytes_inter_per_round", dist_inter_per_round),
        ("dist_rounds", dist_rounds),
    ];
    for (k, v) in &metrics {
        // Only the speedup_* entries are ratios; the rest are plain counts.
        if k.starts_with("speedup_") {
            println!("{k:<34} {v:.2}x");
        } else {
            println!("{k:<34} {v:.2}");
        }
    }

    if let Some(path) = &out_path {
        write_json(path, "hotpath", &cases, &metrics).unwrap();
        println!("wrote {path}");
    }

    let mut failed = false;
    if let Some(thr_path) = &ratios_path {
        // The machine-independent gate (ISSUE 5): every compared quantity
        // is either a same-binary speedup ratio or a deterministic
        // simulation byte count, so the committed thresholds are
        // *requirements* that hold on any runner — no seeding run needed,
        // armed from day one. (min, measured-must-be-at-least) vs
        // (max, measured-must-be-at-most):
        let checks: [(&str, f64, bool); 9] = [
            ("min_speedup_engine_bfs", ratio("engine-bfs"), true),
            ("min_speedup_engine_sssp", ratio("engine-sssp"), true),
            ("min_speedup_sim_parallel", speedup_sim_parallel, true),
            ("min_speedup_frontier_drain", ratio("frontier-drain"), true),
            ("min_speedup_degree_tally", ratio("degree-tally"), true),
            ("min_speedup_serve_cache", speedup_serve_cache, true),
            ("max_reorder_cache_miss_ratio", reorder_miss_ratio, false),
            ("max_dist_comm_bytes_per_round", dist_bytes_per_round, false),
            ("max_dist_comm_bytes_inter_per_round", dist_inter_per_round, false),
        ];
        let mut missing: Vec<&str> = Vec::new();
        for (key, measured, is_min) in checks {
            match read_metric(thr_path, key) {
                None => missing.push(key),
                Some(threshold) => {
                    // NaN measurements (missing case) must fail, not pass.
                    let ok = if is_min {
                        measured >= threshold
                    } else {
                        measured <= threshold
                    };
                    if ok {
                        println!(
                            "ratio gate ok: {key:<38} measured {measured:.2} \
                             vs threshold {threshold:.2}"
                        );
                    } else {
                        eprintln!(
                            "RATIO GATE: {key}: measured {measured:.2} violates \
                             the committed threshold {threshold:.2} ({thr_path}). \
                             If this is an accepted trade-off, update the \
                             threshold in the same PR with the artifact as \
                             evidence; otherwise fix the regression."
                        );
                        failed = true;
                    }
                }
            }
        }
        if !missing.is_empty() {
            eprintln!(
                "MISSING THRESHOLDS: {thr_path} lacks {} — the ratio gate \
                 must never silently skip. Add the keys with the required \
                 bounds (see the committed BENCH_hotpath.json).",
                missing.join(", ")
            );
            failed = true;
        }
    }
    if let Some(base_path) = &check_path {
        match read_json(base_path) {
            Ok(base) if base.is_empty() => {
                // An empty baseline must never silently disarm the gate.
                eprintln!(
                    "EMPTY BASELINE: {base_path} has no timed cases, so the \
                     >{max_regress}% regression gate cannot run.\n\
                     To seed it, commit exactly one artifact:\n\
                     1. open any CI run's `bench-smoke (hotpath)` job and \
                     download the artifact named `BENCH_hotpath` (it \
                     contains `BENCH_hotpath.ci.json`, written by this \
                     binary's --out);\n\
                     2. `mv BENCH_hotpath.ci.json {base_path}`\n\
                     3. `git add {base_path}` and commit.\n\
                     (Equivalently, run `cargo bench --bench hotpath -- \
                     --out {base_path}` on the CI runner class.)"
                );
                failed = true;
            }
            Ok(base) => {
                let now = mean_of(&cases, "hotpath/engine-bfs").unwrap_or(f64::NAN);
                if let Some(then) = mean_of(&base, "hotpath/engine-bfs") {
                    let limit = then * (1.0 + max_regress / 100.0);
                    if now > limit {
                        eprintln!(
                            "REGRESSION: engine-bfs mean {now:.2} ms exceeds \
                             baseline {then:.2} ms by more than {max_regress}%"
                        );
                        failed = true;
                    } else {
                        println!(
                            "check ok: engine-bfs {now:.2} ms vs baseline \
                             {then:.2} ms (limit {limit:.2} ms)"
                        );
                    }
                } else {
                    eprintln!(
                        "BASELINE MISSING CASE: {base_path} has cases but no \
                         engine-bfs — regenerate it from a full bench run"
                    );
                    failed = true;
                }
            }
            Err(e) => {
                eprintln!("cannot read baseline {base_path}: {e}");
                failed = true;
            }
        }
    }
    if let Some(need) = require_speedup {
        for name in ["engine-bfs", "engine-sssp"] {
            let s = ratio(name);
            if s.is_nan() || s < need {
                eprintln!("SPEEDUP GATE: {name} {s:.2}x < required {need:.2}x");
                failed = true;
            }
        }
        // The parallel-sim acceptance target is 1.5x; a deliberately
        // loosened `x` (slow/oversubscribed runner) loosens this gate too.
        let sim_need = need.min(1.5);
        if speedup_sim_parallel.is_nan() || speedup_sim_parallel < sim_need {
            eprintln!(
                "SPEEDUP GATE: speedup_sim_parallel {speedup_sim_parallel:.2}x \
                 < required {sim_need:.2}x (pooled simulation vs 1 thread on \
                 rmat20/rmat22, {par_threads} lanes)"
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
}
