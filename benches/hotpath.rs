//! Bench: the simulator/engine hot paths in isolation — the targets of the
//! EXPERIMENTS.md §Perf optimization pass.
//!
//! Cases:
//! * `inspector`   — ALB's threshold split + prefix build over a large
//!                   active set (runs every round).
//! * `twc-sim`     — per-thread TWC kernel accounting.
//! * `lb-sim`      — LB kernel cache-model simulation (cyclic + blocked).
//! * `engine-bfs`  — whole bfs run on rmat (end-to-end single GPU).
//! * `partition`   — CVC partitioning of the rmat input.
//! * `relax-apply` — native operator application (label updates).

use alb_graph::apps::engine::{run, EngineConfig};
use alb_graph::apps::App;
use alb_graph::config::Framework;
use alb_graph::gpu::{CostModel, GpuSpec, Simulator};
use alb_graph::graph::gen::rmat::{self, RmatConfig};
use alb_graph::graph::CsrGraph;
use alb_graph::lb::{alb, Direction, Distribution};
use alb_graph::metrics::bench::time_runs;
use alb_graph::partition::{partition, Policy};

fn main() {
    let g = CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(16, 7)));
    let spec = GpuSpec::default_sim();
    let cost = CostModel::default();
    let sim = Simulator::new(spec.clone(), cost);
    let active: Vec<u32> = (0..g.num_vertices() as u32).collect();

    let s = time_runs("hotpath/inspector", 10, || {
        alb::inspect(&active, &g, Direction::Push, &spec, spec.huge_threshold())
    });
    println!("{}", s.report());

    let sched_twc = alb::schedule(
        &active, &g, Direction::Push, &spec, Distribution::Cyclic,
        u64::MAX, // force everything through TWC
        g.num_vertices() as u64,
    );
    let s = time_runs("hotpath/twc-sim", 10, || sim.simulate(&sched_twc, true));
    println!("{}", s.report());

    for dist in [Distribution::Cyclic, Distribution::Blocked] {
        let sched = alb::schedule(
            &active, &g, Direction::Push, &spec, dist,
            spec.huge_threshold(), g.num_vertices() as u64,
        );
        let s = time_runs(&format!("hotpath/lb-sim-{dist:?}"), 10, || {
            sim.simulate(&sched, true)
        });
        println!("{}", s.report());
    }

    let s = time_runs("hotpath/engine-bfs", 5, || {
        let mut gg = g.clone();
        let src = gg.max_out_degree_vertex();
        let cfg: EngineConfig = Framework::DIrglAlb.engine_config(spec.clone());
        run(App::Bfs, &mut gg, src, &cfg, None).unwrap()
    });
    println!("{}", s.report());

    let s = time_runs("hotpath/partition-cvc-8", 5, || partition(&g, 8, Policy::Cvc));
    println!("{}", s.report());

    let s = time_runs("hotpath/engine-sssp", 5, || {
        let mut gg = g.clone();
        let src = gg.max_out_degree_vertex();
        let cfg: EngineConfig = Framework::DIrglAlb.engine_config(spec.clone());
        run(App::Sssp, &mut gg, src, &cfg, None).unwrap()
    });
    println!("{}", s.report());
}
