//! Bench: regenerate **Figures 10 + 11** (multi-host multi-GPU, 2-16 GPUs
//! on the Bridges-like cluster: D-IrGL TWC/ALB and Lux; plus the 16-GPU
//! comp/comm breakdown) and time the sweep.
//!
//! Expected shape: D-IrGL beats Lux everywhere; ALB ~ TWC on uk-s (hub
//! below THRESHOLD), clearly ahead on rmat21/22 and twitter-s; breakdown
//! shows the win is in the computation component.

use alb_graph::apps::App;
use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -2, ..ReproConfig::default() };
    let apps = [App::Bfs, App::Cc, App::Pr];
    let mut fig10 = String::new();
    let mut fig11 = String::new();
    let stats = time_runs("fig10+11/cluster-sweep", 2, || {
        fig10 = repro::fig10(&rc, &apps).expect("fig10").render();
        fig11 = repro::fig11(&rc, &apps).expect("fig11").render();
    });
    println!("--- Figure 10 (2-16 GPUs, simulated ms) ---\n{fig10}");
    println!("--- Figure 11 (16-GPU breakdown) ---\n{fig11}");
    println!("{}", stats.report());
}
