//! Bench: regenerate **Table 2** (single-GPU execution time across
//! Gunrock(TWC), Gunrock(LB), D-IrGL(TWC), D-IrGL(ALB); 4 inputs x 5 apps)
//! and time the sweep.
//!
//! Expected shape vs the paper: ALB 3-5x over TWC on rmat push apps +
//! kcore; parity (1.00x) on orkut-s / road-s / pr; Gunrock(LB) beats
//! Gunrock(TWC) on rmat but pays overhead on balanced inputs.

use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -1, ..ReproConfig::default() };
    let mut rendered = String::new();
    let stats = time_runs("table2/full-sweep", 3, || {
        rendered = repro::table2(&rc).expect("table2").render();
    });
    println!("{rendered}");
    println!("{}", stats.report());
}
