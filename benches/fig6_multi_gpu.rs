//! Bench: regenerate **Figures 6 + 7** (single-host multi-GPU, 1-6 GPUs:
//! execution time for the four frameworks, plus the 6-GPU comp/comm
//! breakdown) and time the sweep.
//!
//! Expected shape: ALB fastest at every GPU count on rmat (except pr);
//! the Fig 7 breakdown shows TWC's time is computation-dominated and ALB
//! cuts exactly that component.

use alb_graph::apps::App;
use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -2, ..ReproConfig::default() };
    let apps = [App::Bfs, App::Sssp, App::Pr];
    let mut fig6 = String::new();
    let mut fig7 = String::new();
    let stats = time_runs("fig6+7/multi-gpu-sweep", 2, || {
        fig6 = repro::fig6(&rc, &apps).expect("fig6").render();
        fig7 = repro::fig7(&rc, &apps).expect("fig7").render();
    });
    println!("--- Figure 6 (1-6 GPUs, simulated ms) ---\n{fig6}");
    println!("--- Figure 7 (6-GPU breakdown) ---\n{fig7}");
    println!("{}", stats.report());
}
