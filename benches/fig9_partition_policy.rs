//! Bench: regenerate **Figure 9** (IEC vs OEC partitioning under TWC and
//! ALB on 4 GPUs) and time it.
//!
//! Expected shape: ALB wins under BOTH partitioning policies — inter-GPU
//! partitioning cannot fix intra-GPU thread-block imbalance (§6.2).

use alb_graph::apps::App;
use alb_graph::metrics::bench::time_runs;
use alb_graph::repro::{self, ReproConfig};

fn main() {
    let rc = ReproConfig { scale_delta: -2, ..ReproConfig::default() };
    let apps = [App::Bfs, App::Sssp];
    let mut rendered = String::new();
    let stats = time_runs("fig9/iec-vs-oec", 3, || {
        rendered = repro::fig9(&rc, &apps).expect("fig9").render();
    });
    println!("{rendered}");
    println!("{}", stats.report());
}
