//! End-to-end validation driver: proves the three layers compose.
//!
//! Loads the AOT-compiled JAX/Pallas kernels (Layer 1/2, built once by
//! `make artifacts`) through the PJRT runtime, then runs ALL five paper
//! applications on two real workloads (a paper-regime rmat graph and a road
//! grid) with the LB-kernel hot path executing as compiled HLO. Every
//! PJRT-computed result is checked against the pure-native engine, and the
//! TWC-vs-ALB comparison is reported per app.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use alb_graph::apps::engine::{run, ComputeMode, EngineConfig};
use alb_graph::apps::{App, ALL_APPS};
use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::{inputs, CsrGraph};
use alb_graph::metrics::Table;
use alb_graph::runtime::PjrtRuntime;

fn check_close(a: &[f32], b: &[f32], app: App) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let ok = if matches!(app, App::Pr) {
            (x - y).abs() <= 1e-5 * x.abs().max(1.0)
        } else {
            x == y
        };
        assert!(ok, "{} label mismatch at {i}: pjrt {x} vs native {y}", app.name());
    }
}

fn main() -> anyhow::Result<()> {
    // Example-local wall clock for the printed summary only.
    #[allow(clippy::disallowed_methods)]
    let started = std::time::Instant::now();
    // Layer 1/2: the AOT artifacts, compiled once onto the PJRT CPU client.
    let rt = PjrtRuntime::load_default()?;
    println!(
        "PJRT runtime up: {} compiled kernels on '{}'",
        rt.num_kernels(),
        rt.platform()
    );

    let spec = GpuSpec::default_sim();
    let mut table = Table::new(&[
        "input", "app", "twc(ms)", "alb(ms)", "speedup", "lb-rounds", "engine",
    ]);

    for input in ["rmat18", "road-s"] {
        let g0: CsrGraph = inputs::build(input, 0, 42).unwrap();
        let src = inputs::source_vertex(input, &g0);
        for app in ALL_APPS {
            // Native reference run (TWC baseline) ...
            let mut g = g0.clone();
            let twc_cfg = Framework::DIrglTwc.engine_config(spec.clone());
            let twc = run(app, &mut g, src, &twc_cfg, None)?;

            // ... ALB with the numeric hot paths on the compiled kernels.
            let mut g = g0.clone();
            let mut alb_cfg: EngineConfig =
                Framework::DIrglAlb.engine_config(spec.clone());
            alb_cfg.compute = ComputeMode::Pjrt;
            let alb = run(app, &mut g, src, &alb_cfg, Some(&rt))?;

            // Cross-engine agreement: PJRT numerics == native numerics.
            let mut g = g0.clone();
            let mut native_cfg = alb_cfg.clone();
            native_cfg.compute = ComputeMode::Native;
            let native = run(app, &mut g, src, &native_cfg, None)?;
            check_close(&alb.labels, &native.labels, app);
            // And strategy-independence of the answer itself.
            check_close(&twc.labels, &native.labels, app);

            table.row(vec![
                input.into(),
                app.name().into(),
                format!("{:.4}", twc.ms(&spec)),
                format!("{:.4}", alb.ms(&spec)),
                format!(
                    "{:.2}x",
                    twc.total_cycles as f64 / alb.total_cycles.max(1) as f64
                ),
                alb.rounds_with_lb().to_string(),
                "pjrt".into(),
            ]);
            println!(
                "  ok {input}/{}: {} rounds, labels verified vs native",
                app.name(),
                alb.rounds.len()
            );
        }
    }

    println!("\n{}", table.render());
    println!(
        "end-to-end complete in {:.1}s host time — all labels verified across \
         native/PJRT engines and TWC/ALB strategies",
        started.elapsed().as_secs_f64()
    );
    Ok(())
}
