//! Social-network analytics scenario (the paper's orkut / twitter40
//! motivation): community structure (cc), influence (pagerank), and dense
//! subgraph extraction (k-core) on two social-graph regimes —
//!
//! * `orkut-s`:   symmetric friendship graph, hub *below* the ALB
//!   threshold — the adaptive balancer must stay out of the way;
//! * `twitter-s`: directed follower graph with a celebrity hub far above
//!   it — the balancer must engage.
//!
//! ```bash
//! cargo run --release --example social_network_analytics
//! ```

use alb_graph::apps::engine::run;
use alb_graph::apps::App;
use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::{inputs, props};
use alb_graph::metrics::Table;

fn main() -> anyhow::Result<()> {
    let spec = GpuSpec::default_sim();
    let mut table = Table::new(&[
        "network", "app", "twc(ms)", "alb(ms)", "speedup", "alb-engaged",
    ]);

    for input in ["orkut-s", "twitter-s"] {
        let mut g = inputs::build(input, 0, 42).unwrap();
        let p = props::compute(&mut g);
        println!(
            "{input}: {} users, {} links, hub degree {} (ALB threshold {})",
            p.num_vertices,
            p.num_edges,
            p.max_dout,
            spec.huge_threshold()
        );
        let src = inputs::source_vertex(input, &g);

        for app in [App::Cc, App::Pr, App::Kcore] {
            let twc = run(
                app,
                &mut g.clone(),
                src,
                &Framework::DIrglTwc.engine_config(spec.clone()),
                None,
            )?;
            let alb = run(
                app,
                &mut g.clone(),
                src,
                &Framework::DIrglAlb.engine_config(spec.clone()),
                None,
            )?;
            table.row(vec![
                input.into(),
                app.name().into(),
                format!("{:.4}", twc.ms(&spec)),
                format!("{:.4}", alb.ms(&spec)),
                format!(
                    "{:.2}x",
                    twc.total_cycles as f64 / alb.total_cycles.max(1) as f64
                ),
                if alb.rounds_with_lb() > 0 { "yes" } else { "no" }.into(),
            ]);
        }

        // Scenario payload: report the analytics themselves.
        let mut gc = g.clone();
        let cc = run(App::Cc, &mut gc, src, &Framework::DIrglAlb.engine_config(spec.clone()), None)?;
        let mut comps = cc.labels.clone();
        comps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        comps.dedup();
        let mut gk = g.clone();
        let kc = run(App::Kcore, &mut gk, src, &Framework::DIrglAlb.engine_config(spec.clone()), None)?;
        let core_size = kc.labels.iter().filter(|&&x| x > 0.5).count();
        println!(
            "  -> {} connected components, {} users in the {}-core\n",
            comps.len(),
            core_size,
            100
        );
    }

    println!("{}", table.render());
    println!(
        "expected shape: ALB engages only on twitter-s (hub > threshold), \
         never on orkut-s, and pr never engages (pull/in-degree)."
    );
    Ok(())
}
