//! Quickstart: generate a power-law graph, run SSSP under the paper's
//! Adaptive Load Balancer, and compare it with plain TWC.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use alb_graph::apps::engine::{run, EngineConfig};
use alb_graph::apps::App;
use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::gen::rmat::{self, RmatConfig};
use alb_graph::graph::CsrGraph;

fn main() -> anyhow::Result<()> {
    // 1. An rmat input in the paper's regime: one vertex owns ~25% of all
    //    edges, which wrecks TWC's thread-block balance.
    let el = rmat::generate(&RmatConfig::paper(14, 42));
    let mut g = CsrGraph::from_edge_list(&el);
    let src = g.max_out_degree_vertex();
    println!(
        "graph: {} vertices, {} edges, hub degree {}",
        g.num_vertices(),
        g.num_edges(),
        g.out_degree(src)
    );

    // 2. Run SSSP under both strategies on the simulated GPU.
    let spec = GpuSpec::default_sim();
    let mut results = Vec::new();
    for fw in [Framework::DIrglTwc, Framework::DIrglAlb] {
        let cfg: EngineConfig = fw.engine_config(spec.clone());
        let r = run(App::Sssp, &mut g, src, &cfg, None)?;
        println!(
            "{:<14} {:>10.4} simulated ms   {} rounds   LB kernel in {} rounds",
            fw.name(),
            r.ms(&spec),
            r.rounds.len(),
            r.rounds_with_lb()
        );
        results.push(r);
    }

    // 3. Same labels, different speed — the whole point.
    assert_eq!(results[0].labels, results[1].labels);
    let speedup =
        results[0].total_cycles as f64 / results[1].total_cycles as f64;
    println!("ALB speedup over TWC: {speedup:.2}x");
    Ok(())
}
