//! Road-network routing scenario (the paper's road-USA input): shortest
//! paths and reachability on a long-diameter, flat-degree graph.
//!
//! This is the regime where the *adaptivity* of ALB matters: there are no
//! huge vertices, so a well-behaved balancer must add ~zero overhead over
//! TWC — and the interesting systems trade-off moves to worklist policy
//! (the paper's §6.1 Gunrock-vs-D-IrGL road-USA discussion): thousands of
//! nearly-empty rounds make the dense |V|-scan dominate.
//!
//! ```bash
//! cargo run --release --example road_network_routing
//! ```

use alb_graph::apps::engine::{run, EngineConfig};
use alb_graph::apps::worklist::WorklistKind;
use alb_graph::apps::App;
use alb_graph::config::Framework;
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::{inputs, props};
use alb_graph::metrics::Table;

fn main() -> anyhow::Result<()> {
    let spec = GpuSpec::default_sim();
    let mut g = inputs::build("road-s", 0, 42).unwrap();
    let p = props::compute(&mut g);
    println!(
        "road network: {} junctions, {} segments, max degree {}, diameter ~{}\n",
        p.num_vertices, p.num_edges, p.max_dout, p.approx_diameter
    );
    let src = 0u32; // paper: road sources are vertex 0

    // 1. ALB adds no overhead when there is nothing to balance.
    let mut table = Table::new(&["app", "twc(ms)", "alb(ms)", "lb-rounds", "rounds"]);
    for app in [App::Bfs, App::Sssp] {
        let twc = run(app, &mut g.clone(), src,
                      &Framework::DIrglTwc.engine_config(spec.clone()), None)?;
        let alb = run(app, &mut g.clone(), src,
                      &Framework::DIrglAlb.engine_config(spec.clone()), None)?;
        assert_eq!(twc.labels, alb.labels);
        assert_eq!(alb.rounds_with_lb(), 0, "ALB must stay dormant on roads");
        table.row(vec![
            app.name().into(),
            format!("{:.4}", twc.ms(&spec)),
            format!("{:.4}", alb.ms(&spec)),
            alb.rounds_with_lb().to_string(),
            alb.rounds.len().to_string(),
        ]);
    }
    println!("{}", table.render());

    // 2. The worklist trade-off: sparse wins when active sets are tiny.
    let mut table = Table::new(&["app", "dense-wl(ms)", "sparse-wl(ms)", "sparse-speedup"]);
    for app in [App::Bfs, App::Sssp] {
        let mk = |wl: WorklistKind| -> EngineConfig {
            EngineConfig {
                worklist: wl,
                ..Framework::DIrglAlb.engine_config(spec.clone())
            }
        };
        let dense = run(app, &mut g.clone(), src, &mk(WorklistKind::Dense), None)?;
        let sparse = run(app, &mut g.clone(), src, &mk(WorklistKind::Sparse), None)?;
        assert_eq!(dense.labels, sparse.labels);
        table.row(vec![
            app.name().into(),
            format!("{:.4}", dense.ms(&spec)),
            format!("{:.4}", sparse.ms(&spec)),
            format!("{:.2}x", dense.total_cycles as f64 / sparse.total_cycles.max(1) as f64),
        ]);
    }
    println!("{}", table.render());

    // 3. The routing answer itself: reachability + a sample route cost.
    let sssp = run(App::Sssp, &mut g, src,
                   &Framework::DIrglAlb.engine_config(spec.clone()), None)?;
    let reachable = sssp.labels.iter().filter(|&&d| d < alb_graph::apps::INF).count();
    let far = sssp
        .labels
        .iter()
        .enumerate()
        .filter(|(_, &d)| d < alb_graph::apps::INF)
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "routing: {}/{} junctions reachable from depot 0; farthest junction {} \
         at travel cost {}",
        reachable,
        g.num_vertices(),
        far.0,
        far.1
    );
    Ok(())
}
