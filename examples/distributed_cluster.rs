//! Distributed-cluster scenario: the paper's Bridges experiments (§6.3) —
//! run the full application suite across 16 simulated GPUs (8 hosts x 2),
//! with Gluon-style BSP synchronization, and show
//!
//! 1. strong scaling 2 -> 16 GPUs,
//! 2. the computation/communication breakdown (Fig. 11's accounting),
//! 3. that per-GPU thread-block imbalance throttles the *whole cluster*
//!    under TWC, and ALB recovers it,
//! 4. the partitioning-policy interaction (Fig. 9: IEC vs OEC vs CVC).
//!
//! ```bash
//! cargo run --release --example distributed_cluster
//! ```

use alb_graph::apps::App;
use alb_graph::comm::NetworkModel;
use alb_graph::config::Framework;
use alb_graph::coordinator::{run_distributed, ClusterConfig, ExecMode};
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::inputs;
use alb_graph::metrics::{gpu_loads, Table};
use alb_graph::partition::Policy;

fn main() -> anyhow::Result<()> {
    let spec = GpuSpec::default_sim();
    let input = "rmat21";
    let g = inputs::build(input, 0, 42).unwrap();
    let src = inputs::source_vertex(input, &g);
    println!(
        "cluster workload: {input} ({} vertices, {} edges) on up to 16 GPUs\n",
        g.num_vertices(),
        g.num_edges()
    );

    // 1. Strong scaling, TWC vs ALB.
    let mut t = Table::new(&["framework", "2 gpus", "4 gpus", "8 gpus", "16 gpus"]);
    for fw in [Framework::DIrglTwc, Framework::DIrglAlb] {
        let cfg = fw.engine_config(spec.clone());
        let mut row = vec![fw.name().to_string()];
        for k in [2u32, 4, 8, 16] {
            let r = run_distributed(App::Sssp, &g, src, &cfg,
                                    &ClusterConfig::bridges(k), None)?;
            row.push(format!("{:.4}", r.ms(&spec)));
        }
        t.row(row);
    }
    println!("sssp strong scaling (simulated ms):\n{}", t.render());

    // 2. Breakdown on 16 GPUs (Fig. 11 accounting), with the host
    //    wall-clock each simulated GPU's threads actually spent.
    let mut t = Table::new(&[
        "app", "framework", "comp(ms)", "comm(ms)", "imbalance", "threads",
        "wall(ms)",
    ]);
    for app in [App::Bfs, App::Sssp, App::Cc] {
        for fw in [Framework::DIrglTwc, Framework::DIrglAlb] {
            let cfg = fw.engine_config(spec.clone());
            let r = run_distributed(app, &g, src, &cfg,
                                    &ClusterConfig::bridges(16), None)?;
            // Per-GPU compute balance across the cluster.
            let max = *r.per_gpu_comp.iter().max().unwrap() as f64;
            let mean = r.per_gpu_comp.iter().sum::<u64>() as f64
                / r.per_gpu_comp.len() as f64;
            let wall: f64 = gpu_loads(&r.per_gpu_comp, &r.per_gpu_wall_ns)
                .iter()
                .map(|l| l.wall_ms())
                .sum();
            t.row(vec![
                app.name().into(),
                fw.name().into(),
                format!("{:.4}", r.comp_ms(&spec)),
                format!("{:.4}", r.comm_ms(&spec)),
                format!("{:.2}", max / mean.max(1.0)),
                r.num_threads().to_string(),
                format!("{wall:.2}"),
            ]);
        }
    }
    println!("16-GPU breakdown:\n{}", t.render());

    // 3. Partition-policy interaction (Fig. 9).
    let mut t = Table::new(&["policy", "twc(ms)", "alb(ms)", "alb-speedup"]);
    for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
        let cluster = ClusterConfig {
            num_gpus: 8,
            policy,
            net: NetworkModel::cluster(2),
            exec: ExecMode::Parallel,
        };
        let twc = run_distributed(
            App::Sssp, &g, src,
            &Framework::DIrglTwc.engine_config(spec.clone()), &cluster, None,
        )?;
        let alb = run_distributed(
            App::Sssp, &g, src,
            &Framework::DIrglAlb.engine_config(spec.clone()), &cluster, None,
        )?;
        assert_eq!(twc.labels, alb.labels);
        t.row(vec![
            policy.name().into(),
            format!("{:.4}", twc.ms(&spec)),
            format!("{:.4}", alb.ms(&spec)),
            format!("{:.2}x", twc.total_cycles as f64 / alb.total_cycles.max(1) as f64),
        ]);
    }
    println!("partitioning policies, 8 GPUs (sssp):\n{}", t.render());
    println!(
        "expected shape: ALB wins regardless of partitioning policy — \
         partitioning balances across GPUs, ALB balances within each GPU."
    );
    Ok(())
}
