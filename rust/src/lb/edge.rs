//! Edge-based LB over the whole active set — Gunrock's "LB" policy (§3.3).
//!
//! Every round, *all* active vertices' edges are evenly distributed across
//! all threads, regardless of whether the round is imbalanced. Perfect
//! block balance, but the prefix sum spans every active vertex and every
//! edge pays the binary-search cost — the non-adaptive overhead the paper's
//! Table 2 surfaces on balanced inputs (and which ALB avoids by splitting
//! only the huge bin).

use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{Distribution, Schedule, ScheduleScratch};
use crate::lb::segment::{self, Composition};
use crate::lb::Direction;

pub fn schedule(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    distribution: Distribution,
    scan_vertices: u64,
) -> Schedule {
    let mut scratch = ScheduleScratch::new();
    schedule_into(active, g, dir, spec, distribution, scan_vertices, &mut scratch);
    scratch.sched
}

/// A threshold-0 [`Composition`]: every active vertex (zero-degree ones
/// included — they still get prefix entries) lands in the LB segment; the
/// `PositiveEdges` gate skips the launch on edgeless frontiers. `spec`
/// only feeds the (unreachable) small-vertex bucket policy.
pub fn schedule_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    distribution: Distribution,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    segment::schedule_into(
        &Composition::edge_lb(distribution),
        active, g, dir, spec, scan_vertices, out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{CostModel, GpuSpec, Simulator};
    use crate::graph::EdgeList;

    fn chain_with_hub() -> CsrGraph {
        let mut el = EdgeList::new(50_002);
        for i in 0..50_000u32 {
            el.push(0, 2 + (i % 50_000), 1.0); // hub
        }
        el.push(1, 0, 1.0);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn prefix_covers_all_active_edges() {
        let g = chain_with_hub();
        let s = schedule(&[0, 1], &g, Direction::Push, &GpuSpec::default_sim(), Distribution::Cyclic, 2);
        let lb = s.lb.as_ref().unwrap();
        assert_eq!(lb.prefix, vec![50_000, 50_001]);
        assert_eq!(s.total_edges(), 50_001);
        assert_eq!(s.prefix_items, 2);
    }

    #[test]
    fn no_launch_when_no_edges() {
        let mut el = EdgeList::new(4);
        el.push(0, 1, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        let s = schedule(&[2, 3], &g, Direction::Push, &GpuSpec::default_sim(), Distribution::Cyclic, 2);
        assert!(s.lb.is_none());
    }

    #[test]
    fn always_balanced_even_on_hub() {
        let g = chain_with_hub();
        let spec = GpuSpec::default_sim();
        let s = schedule(&[0, 1], &g, Direction::Push, &spec, Distribution::Cyclic, 0);
        let sim = Simulator::new(spec, CostModel::default());
        let r = sim.simulate(&s, true);
        let k = r.kernels.iter().find(|k| k.label == "lb").unwrap();
        assert!(k.imbalance_factor() < 1.1);
    }

    #[test]
    fn pays_prefix_overhead_proportional_to_active() {
        // The non-adaptivity cost: big active set of tiny vertices still
        // builds a big prefix array.
        let mut el = EdgeList::new(10_000);
        for v in 0..9_999u32 {
            el.push(v, v + 1, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let active: Vec<u32> = (0..9_999).collect();
        let s = schedule(&active, &g, Direction::Push, &GpuSpec::default_sim(), Distribution::Cyclic, 0);
        assert_eq!(s.prefix_items, 9_999);
    }
}
