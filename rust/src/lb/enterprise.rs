//! Enterprise-style load balancing (paper §3.3; Liu & Huang [18]).
//!
//! Enterprise adds a fourth bin to TWC: vertices with *extremely large*
//! degree are processed by **all CTAs on the GPU**, one kernel launch per
//! such vertex. Unlike ALB's LB kernel there is no prefix-sum/binary-search
//! machinery — each launch handles a single source vertex, so every thread
//! knows its source implicitly — but the policy is static (no benefit
//! check; the paper notes Enterprise only applies it to bfs) and each hub
//! pays its own kernel launch.
//!
//! Modeled as an [`LbLaunch`] with `search: false` and per-vertex launch
//! accounting in the simulator.

use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{Schedule, ScheduleScratch};
use crate::lb::segment::{self, Composition};
use crate::lb::Direction;

/// Degree bound for the "extremely large" bin. Enterprise used a fixed
/// multiple of the block size; we follow ALB's convention (launched
/// threads) so the two strategies split the same vertices and differ only
/// in the *mechanism*.
pub fn schedule(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
) -> Schedule {
    let mut scratch = ScheduleScratch::new();
    schedule_into(active, g, dir, spec, scan_vertices, &mut scratch);
    scratch.sched
}

/// The ALB threshold split re-composed with grid-launch execution: blocked
/// distribution, one launch per hub, no edge-id search (single known
/// source per launch) and no prefix-sum kernel.
pub fn schedule_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    segment::schedule_into(
        &Composition::enterprise(spec.huge_threshold()),
        active, g, dir, spec, scan_vertices, out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{CostModel, Simulator};
    use crate::graph::EdgeList;
    use crate::lb::schedule::Distribution;
    use crate::lb::twc;

    fn two_hubs() -> CsrGraph {
        let n = 20_000u32;
        let mut el = EdgeList::new(n);
        for i in 0..8_000u32 {
            el.push(0, 2 + (i % (n - 2)), 1.0);
        }
        for i in 0..5_000u32 {
            el.push(1, 2 + (i % (n - 2)), 1.0);
        }
        for v in 2..100u32 {
            el.push(v, 0, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn hubs_go_to_grid_bin_without_search() {
        let g = two_hubs();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..100).collect();
        let s = schedule(&active, &g, Direction::Push, &spec, 0);
        let lb = s.lb.as_ref().unwrap();
        assert_eq!(lb.vertices, vec![0, 1]);
        assert!(!lb.search);
        assert_eq!(s.prefix_items, 0, "no prefix-sum kernel in Enterprise");
    }

    #[test]
    fn work_conserved() {
        let g = two_hubs();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..100).collect();
        let want: u64 = active.iter().map(|&v| g.out_degree(v)).sum();
        assert_eq!(schedule(&active, &g, Direction::Push, &spec, 0).total_edges(), want);
    }

    #[test]
    fn per_hub_launch_makes_it_costlier_than_alb() {
        // Same split as ALB, but N hubs -> N launches + no shared prefix:
        // ALB should win when several hubs are active in one round.
        let g = two_hubs();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..100).collect();
        let sim = Simulator::new(spec.clone(), CostModel::default());
        let ent = sim.simulate(&schedule(&active, &g, Direction::Push, &spec, 0), true);
        let alb = sim.simulate(
            &crate::lb::alb::schedule(
                &active, &g, Direction::Push, &spec,
                Distribution::Cyclic, spec.huge_threshold(), 0,
            ),
            true,
        );
        assert!(ent.total_cycles > alb.total_cycles,
                "enterprise {} vs alb {}", ent.total_cycles, alb.total_cycles);
    }

    #[test]
    fn still_beats_plain_twc_on_hubs() {
        let g = two_hubs();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..100).collect();
        let sim = Simulator::new(spec.clone(), CostModel::default());
        let ent = sim.simulate(&schedule(&active, &g, Direction::Push, &spec, 0), true);
        let twc = sim.simulate(
            &twc::schedule(&active, &g, Direction::Push, &spec, 0),
            true,
        );
        assert!(ent.total_cycles < twc.total_cycles);
    }
}
