//! The paper's Adaptive Load Balancer (§4).
//!
//! Inspector–executor, per round:
//!
//! 1. **Inspect** (fused into the TWC kernel in the generated code, Fig. 3
//!    lines 3–9): each active vertex with `degree >= THRESHOLD` goes to the
//!    *huge* worklist; the rest are TWC-binned as usual. THRESHOLD defaults
//!    to the launched thread count (§4.2 — the experimentally-found sweet
//!    spot; 26,624 on the paper's GPUs).
//! 2. **Prefix-sum** the huge degrees (Fig. 3 line 31).
//! 3. **Execute**: if the huge worklist is non-empty, launch the LB kernel —
//!    `total_edges / nthreads` edges per thread, cyclic by default (§4.1) —
//!    alongside the TWC kernel for the remaining vertices.
//!
//! Adaptivity is the point: when no vertex crosses the threshold (road-USA,
//! orkut, uk2007, or pr's flat in-degrees) the LB kernel is never launched
//! and the only cost over plain TWC is the threshold compare.

use crate::exec::Pool;
use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{Distribution, Schedule, ScheduleScratch, VertexItem};
use crate::lb::segment::{self, Bucket, Composition};
use crate::lb::Direction;

/// Outcome of the inspector phase — exposed for tests and metrics.
#[derive(Debug, Clone, Default)]
pub struct Inspection {
    pub huge: Vec<u32>,
    pub prefix: Vec<u64>,
    pub rest: Vec<VertexItem>,
}

/// Split the active set at `threshold` (paper Fig. 3 lines 3–9 + line 31).
pub fn inspect(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    threshold: u64,
) -> Inspection {
    let mut ins = Inspection::default();
    ins.rest.reserve(active.len());
    inspect_into(active, g, dir, spec, threshold, &mut ins);
    ins
}

/// [`inspect`] into a caller-owned, reusable [`Inspection`] (cleared first).
pub fn inspect_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    threshold: u64,
    ins: &mut Inspection,
) {
    ins.huge.clear();
    ins.prefix.clear();
    ins.rest.clear();
    segment::split_into(
        active, g, dir, spec, threshold, Bucket::Twc,
        &mut ins.huge, &mut ins.prefix, &mut ins.rest,
    );
}

#[allow(clippy::too_many_arguments)]
pub fn schedule(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    distribution: Distribution,
    threshold: u64,
    scan_vertices: u64,
) -> Schedule {
    let mut scratch = ScheduleScratch::new();
    schedule_into(
        active, g, dir, spec, distribution, threshold, scan_vertices,
        &mut scratch,
    );
    scratch.sched
}

/// Build the round schedule: a [`Composition::alb`] over the shared
/// segment core — the benefit check (§4: only pay the LB launch when the
/// huge bin is non-empty) is the composition's `NonEmptyHuge` gate.
#[allow(clippy::too_many_arguments)]
pub fn schedule_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    distribution: Distribution,
    threshold: u64,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    segment::schedule_into(
        &Composition::alb(distribution, threshold),
        active, g, dir, spec, scan_vertices, out,
    );
}

/// [`schedule_into`] with the inspector's threshold probe pass split into
/// fixed contiguous chunks of the active set on `pool` (DESIGN.md §9,
/// [`segment::schedule_into_pooled`]): bit-identical to the sequential
/// split for any pool width.
#[allow(clippy::too_many_arguments)]
pub fn schedule_into_pooled(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    distribution: Distribution,
    threshold: u64,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
    pool: &Pool,
) {
    segment::schedule_into_pooled(
        &Composition::alb(distribution, threshold),
        active, g, dir, spec, scan_vertices, out, pool,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{CostModel, Simulator};
    use crate::graph::EdgeList;
    use crate::lb::schedule::Unit;

    /// hub (degree 500k) + mid (degree 200) + 1000 leaves (degree 1).
    fn skewed() -> CsrGraph {
        let n = 60_000u32;
        let mut el = EdgeList::new(n);
        for i in 0..500_000u32 {
            el.push(0, 2 + (i % (n - 2)), 1.0);
        }
        for i in 0..200u32 {
            el.push(1, 2 + i, 1.0);
        }
        for v in 2..1_002u32 {
            el.push(v, 0, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn inspector_splits_at_threshold() {
        let g = skewed();
        let spec = GpuSpec::default_sim(); // threshold 3072
        let active: Vec<u32> = (0..1_002).collect();
        let ins = inspect(&active, &g, Direction::Push, &spec, spec.huge_threshold());
        assert_eq!(ins.huge, vec![0]);
        assert_eq!(ins.prefix, vec![500_000]);
        assert_eq!(ins.rest.len(), 1_001);
        assert!(ins.rest.iter().all(|i| i.degree < 3072));
    }

    #[test]
    fn threshold_zero_routes_everything_to_lb() {
        // §4.2: threshold 0 puts all vertices in the huge bin.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let s = schedule(&[0, 1, 2], &g, Direction::Push, &spec,
                         Distribution::Cyclic, 0, 0);
        assert!(s.twc.is_empty());
        assert_eq!(s.lb.unwrap().vertices, vec![0, 1, 2]);
    }

    #[test]
    fn threshold_above_max_degree_is_plain_twc() {
        // §4.2: threshold > max degree -> no huge bin, no LB kernel.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let s = schedule(&[0, 1, 2], &g, Direction::Push, &spec,
                         Distribution::Cyclic, u64::MAX, 0);
        assert!(s.lb.is_none());
        assert_eq!(s.twc.len(), 3);
        assert_eq!(s.prefix_items, 0);
    }

    #[test]
    fn adaptive_no_overhead_when_balanced() {
        // Road-USA regime: no huge vertices -> identical kernels to TWC.
        let mut el = EdgeList::new(1000);
        for v in 0..999u32 {
            el.push(v, v + 1, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..1000).collect();
        let alb = schedule(&active, &g, Direction::Push, &spec,
                           Distribution::Cyclic, spec.huge_threshold(), 1000);
        let plain = twc::schedule(&active, &g, Direction::Push, &spec, 1000);
        assert!(alb.lb.is_none());
        assert_eq!(alb.twc.len(), plain.twc.len());
        let sim = Simulator::new(spec, CostModel::default());
        assert_eq!(
            sim.simulate(&alb, true).total_cycles,
            sim.simulate(&plain, true).total_cycles
        );
    }

    #[test]
    fn alb_beats_twc_on_hub_rounds() {
        // The headline effect (Table 2 rmat rows): same active set, the hub
        // splits across blocks instead of serializing one CTA.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..1_002).collect();
        let sim = Simulator::new(spec.clone(), CostModel::default());
        let alb = schedule(&active, &g, Direction::Push, &spec,
                           Distribution::Cyclic, spec.huge_threshold(), 0);
        let plain = twc::schedule(&active, &g, Direction::Push, &spec, 0);
        let t_alb = sim.simulate(&alb, true).total_cycles;
        let t_twc = sim.simulate(&plain, true).total_cycles;
        assert!(
            t_alb * 2 < t_twc,
            "ALB {t_alb} must be well under TWC {t_twc}"
        );
    }

    #[test]
    fn work_conservation_under_split() {
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..1_002).collect();
        let want: u64 = active.iter().map(|&v| g.out_degree(v)).sum();
        let s = schedule(&active, &g, Direction::Push, &spec,
                         Distribution::Cyclic, spec.huge_threshold(), 0);
        assert_eq!(s.total_edges(), want);
    }

    #[test]
    fn huge_prefix_is_inclusive_cumsum() {
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let ins = inspect(&[0, 1], &g, Direction::Push, &spec, 150);
        assert_eq!(ins.huge, vec![0, 1]);
        assert_eq!(ins.prefix, vec![500_000, 500_200]);
    }

    #[test]
    fn rest_items_keep_twc_units() {
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let ins = inspect(&[1, 2], &g, Direction::Push, &spec, 3072);
        assert_eq!(ins.rest[0].unit, Unit::Block); // degree 200 >= 128
        assert_eq!(ins.rest[1].unit, Unit::Thread); // degree 1
    }

    #[test]
    fn pooled_split_matches_sequential_for_any_pool_width() {
        // §9 determinism: the chunked probe pass must produce the same
        // schedule as the sequential split — same huge order, same rebased
        // prefix, same TWC items — for any pool width and threshold,
        // including thresholds that spread huge vertices across chunks
        // (threshold 1: every active vertex with an edge is huge, so the
        // prefix rebase is exercised at every chunk boundary).
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..g.num_vertices() as u32).collect();
        assert!(active.len() >= segment::PAR_SPLIT_MIN);
        for threshold in [1u64, 150, spec.huge_threshold(), u64::MAX] {
            let mut want = ScheduleScratch::new();
            schedule_into(
                &active, &g, Direction::Push, &spec, Distribution::Cyclic,
                threshold, 9, &mut want,
            );
            for threads in [1usize, 2, 3, 7] {
                let pool = Pool::new(threads);
                let mut got = ScheduleScratch::new();
                schedule_into_pooled(
                    &active, &g, Direction::Push, &spec, Distribution::Cyclic,
                    threshold, 9, &mut got, &pool,
                );
                assert_eq!(
                    got.sched, want.sched,
                    "threads={threads} threshold={threshold}"
                );
            }
        }
    }

    #[test]
    fn pooled_split_small_frontier_takes_sequential_path() {
        // Below PAR_SPLIT_MIN the pooled entry point must still produce the
        // identical schedule (it delegates to the sequential walk).
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..100).collect();
        let pool = Pool::new(4);
        let mut got = ScheduleScratch::new();
        schedule_into_pooled(
            &active, &g, Direction::Push, &spec, Distribution::Cyclic,
            150, 0, &mut got, &pool,
        );
        let mut want = ScheduleScratch::new();
        schedule_into(
            &active, &g, Direction::Push, &spec, Distribution::Cyclic,
            150, 0, &mut want,
        );
        assert_eq!(got.sched, want.sched);
    }
}
