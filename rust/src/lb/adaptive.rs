//! The runtime-adaptive ALB controller (ROADMAP "adaptivity at runtime";
//! cf. the per-round feedback loops of arXiv 1711.00231).
//!
//! `Balancer::Adaptive` starts as plain ALB (round 0 is bit-identical to
//! `Balancer::Alb` at the same starting threshold) and then steers two
//! knobs from the previous round's *measured* block imbalance:
//!
//! * the **inspector threshold** — lowered (bounded multiplicative step)
//!   while the TWC kernel dominates the round with high imbalance, routing
//!   more of the skewed tail through the evenly-distributed LB kernel, and
//!   raised back toward the starting point only on rounds where the LB
//!   kernel did not trigger (so recovery can never perturb a schedule the
//!   controller is actively shaping);
//! * the **sampled-warp budget** of the LB cost model
//!   ([`crate::gpu::CostModel::lb_warp_step_sample_cap`]) — doubled while
//!   the controller is actively re-balancing (more simulation fidelity
//!   exactly when the LB kernel is load-bearing), decayed back to the
//!   configured cap once the round is balanced.
//!
//! The law is a pure function of `(state, RoundSignal)` — no clocks, no
//! randomness — so runs are bit-identical across `sim_threads` (the signal
//! itself is deterministic, DESIGN.md §9), and on a *fixed* signal every
//! knob moves monotonically until it hits a bound: the controller cannot
//! oscillate (pinned by unit tests here and in `apps::engine`).

use crate::gpu::{CostModel, GpuSpec};
use crate::lb::schedule::Distribution;
use crate::lb::Balancer;

/// Block imbalance above which the round is considered skewed enough to
/// pay for re-balancing (paper Fig. 1 territory).
pub const IMBALANCE_HIGH: f64 = 2.0;
/// Block imbalance below which the round counts as balanced and the
/// sampling budget decays back to the configured cap.
pub const IMBALANCE_LOW: f64 = 1.25;

/// What the controller observes after each simulated round — distilled
/// from the round's [`crate::gpu::KernelStats`] by the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundSignal {
    /// Max per-kernel block imbalance factor this round (1.0 = perfect).
    pub imbalance: f64,
    /// Cycles of the TWC kernel.
    pub twc_cycles: u64,
    /// Cycles of the LB kernel (0 when not launched).
    pub lb_cycles: u64,
    /// Whether the round's schedule triggered the LB kernel.
    pub lb_triggered: bool,
}

/// Per-round controller trace, recorded in
/// [`crate::apps::RoundRecord::adaptive`] for static balancers this is
/// `None`, so record equality checks between static strategies are
/// unaffected.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveRound {
    /// Inspector threshold the round was scheduled with.
    pub threshold: u64,
    /// Sampled-warp budget the round was simulated with.
    pub sample_cap: u64,
    /// Imbalance measured from the round's kernels (fed to the controller).
    pub imbalance: f64,
}

/// The feedback controller: one per engine run, one per simulated GPU in
/// the coordinator (each partition sees its own imbalance).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveController {
    distribution: Distribution,
    threshold: u64,
    base_threshold: u64,
    min_threshold: u64,
    sample_cap: u64,
    base_sample_cap: u64,
    max_sample_cap: u64,
}

impl AdaptiveController {
    pub fn new(
        distribution: Distribution,
        start_threshold: u64,
        spec: &GpuSpec,
        cost: &CostModel,
    ) -> Self {
        let base_threshold = start_threshold.max(1);
        let base_cap = cost.lb_warp_step_sample_cap.max(1);
        AdaptiveController {
            distribution,
            threshold: base_threshold,
            base_threshold,
            // Below a warp's worth of edges the LB kernel's search overhead
            // can never pay for itself — but a user-chosen start below the
            // warp floor wins: the floor must never *raise* the threshold
            // past the starting point (threshold stays in [min, base]).
            min_threshold: (spec.warp_size as u64).max(1).min(base_threshold),
            sample_cap: base_cap,
            base_sample_cap: base_cap,
            max_sample_cap: base_cap.saturating_mul(4),
        }
    }

    /// The controller for `b`, or `None` for static balancers. `Auto`
    /// reaching the engine unresolved falls back to the adaptive default
    /// (resolution normally happens at the CLI/campaign layer, see
    /// [`auto_balancer`]).
    pub fn for_balancer(b: &Balancer, spec: &GpuSpec, cost: &CostModel) -> Option<Self> {
        match b {
            Balancer::Adaptive { distribution, threshold } => Some(Self::new(
                *distribution,
                threshold.unwrap_or_else(|| spec.huge_threshold()),
                spec,
                cost,
            )),
            Balancer::Auto => Some(Self::new(
                Distribution::Cyclic,
                spec.huge_threshold(),
                spec,
                cost,
            )),
            _ => None,
        }
    }

    /// Inspector threshold for the next round.
    pub fn threshold(&self) -> u64 {
        self.threshold
    }

    /// Sampled-warp budget for the next round's LB cost model.
    pub fn sample_cap(&self) -> u64 {
        self.sample_cap
    }

    /// The effective balancer for the next round: plain ALB at the current
    /// threshold — which is why round 0 *is* plain ALB.
    pub fn balancer(&self) -> Balancer {
        Balancer::Alb {
            distribution: self.distribution,
            threshold: Some(self.threshold),
        }
    }

    /// Apply one bounded, deterministic controller step.
    ///
    /// * Skewed round dominated by the TWC kernel → lower the threshold by
    ///   a quarter (floor: one warp) and double the sampling budget.
    /// * LB kernel idle while below the starting threshold → recover
    ///   halfway back toward it, decaying the budget.
    /// * Balanced round → decay the budget toward the configured cap.
    ///
    /// Every branch moves each knob monotonically toward a bound for a
    /// fixed signal, so a static signal converges without oscillation.
    pub fn observe(&mut self, sig: &RoundSignal) {
        if sig.imbalance > IMBALANCE_HIGH && sig.twc_cycles >= sig.lb_cycles {
            self.threshold = (self.threshold - self.threshold / 4).max(self.min_threshold);
            self.sample_cap = self.sample_cap.saturating_mul(2).min(self.max_sample_cap);
        } else if !sig.lb_triggered && self.threshold < self.base_threshold {
            self.threshold =
                (self.threshold + self.threshold / 2 + 1).min(self.base_threshold);
            self.sample_cap = (self.sample_cap / 2).max(self.base_sample_cap);
        } else if sig.imbalance < IMBALANCE_LOW {
            self.sample_cap = (self.sample_cap / 2).max(self.base_sample_cap);
        }
    }
}

/// The committed auto-mode table: fastest *starting* strategy per
/// `(input, app)`, distilled from the campaign history behind
/// `CAMPAIGN.golden.json` (see DESIGN.md §12 for the update recipe).
/// Pairs not listed fall back to the adaptive default, which is never
/// worse than plain ALB on the measured matrix.
const AUTO_TABLE: &[(&str, &str, &str)] = &[
    // Balanced, low-degree inputs: the inspector never fires; plain TWC
    // avoids even the threshold probe's bookkeeping.
    ("road-s", "bfs", "twc"),
    ("road-s", "pr", "twc"),
    ("road-s", "kcore", "twc"),
    ("uk-s", "bfs", "twc"),
    // Skewed rmat/twitter inputs: adaptive (== ALB at round 0, lowering
    // the threshold on hub rounds) wins or ties everywhere measured.
];

/// Resolve `auto` for a concrete `(app, input)` pair.
pub fn auto_balancer(app: &str, input: &str) -> Balancer {
    for &(inp, a, strat) in AUTO_TABLE {
        if inp == input && a == app {
            return Balancer::parse(strat)
                .expect("AUTO_TABLE names a known strategy");
        }
    }
    Balancer::Adaptive { distribution: Distribution::Cyclic, threshold: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AdaptiveController {
        let spec = GpuSpec::default_sim();
        let cost = CostModel::default();
        AdaptiveController::new(
            Distribution::Cyclic,
            spec.huge_threshold(),
            &spec,
            &cost,
        )
    }

    fn skewed_signal() -> RoundSignal {
        RoundSignal { imbalance: 8.0, twc_cycles: 10_000, lb_cycles: 100, lb_triggered: true }
    }

    #[test]
    fn round_zero_is_plain_alb() {
        let spec = GpuSpec::default_sim();
        let c = ctl();
        assert_eq!(
            c.balancer(),
            Balancer::Alb {
                distribution: Distribution::Cyclic,
                threshold: Some(spec.huge_threshold()),
            }
        );
        assert_eq!(c.sample_cap(), CostModel::default().lb_warp_step_sample_cap);
    }

    #[test]
    fn skewed_rounds_lower_threshold_boundedly() {
        let mut c = ctl();
        let mut prev = c.threshold();
        for _ in 0..64 {
            c.observe(&skewed_signal());
            let t = c.threshold();
            assert!(t <= prev, "monotone under a fixed skewed signal");
            assert!(prev - t <= prev / 4 + 1, "step bounded to a quarter");
            prev = t;
        }
        assert_eq!(
            c.threshold(),
            GpuSpec::default_sim().warp_size as u64,
            "converges to the warp-size floor"
        );
    }

    #[test]
    fn static_signal_converges_without_oscillation() {
        // Whatever the fixed signal, the threshold trajectory must be
        // monotone and eventually constant.
        let signals = [
            skewed_signal(),
            RoundSignal { imbalance: 1.0, twc_cycles: 50, lb_cycles: 0, lb_triggered: false },
            RoundSignal { imbalance: 1.5, twc_cycles: 500, lb_cycles: 400, lb_triggered: true },
            RoundSignal { imbalance: 3.0, twc_cycles: 10, lb_cycles: 5_000, lb_triggered: true },
        ];
        for sig in signals {
            let mut c = ctl();
            // Pre-skew so recovery rules have room to move upward.
            for _ in 0..10 {
                c.observe(&skewed_signal());
            }
            let mut trace = vec![c.threshold()];
            for _ in 0..64 {
                c.observe(&sig);
                trace.push(c.threshold());
            }
            let increasing = trace.windows(2).all(|w| w[1] >= w[0]);
            let decreasing = trace.windows(2).all(|w| w[1] <= w[0]);
            assert!(increasing || decreasing, "monotone for {sig:?}: {trace:?}");
            let tail = &trace[trace.len() - 8..];
            assert!(
                tail.windows(2).all(|w| w[0] == w[1]),
                "settles to a fixed point for {sig:?}: {trace:?}"
            );
        }
    }

    #[test]
    fn recovery_never_exceeds_base_and_caps_decay() {
        let mut c = ctl();
        for _ in 0..6 {
            c.observe(&skewed_signal());
        }
        assert!(c.threshold() < c.base_threshold);
        assert!(c.sample_cap() > c.base_sample_cap);
        let idle = RoundSignal { imbalance: 1.0, twc_cycles: 10, lb_cycles: 0, lb_triggered: false };
        for _ in 0..64 {
            c.observe(&idle);
        }
        assert_eq!(c.threshold(), c.base_threshold);
        assert_eq!(c.sample_cap(), c.base_sample_cap);
    }

    #[test]
    fn lb_dominated_rounds_hold_the_threshold() {
        // When the LB kernel already dominates, lowering further would only
        // grow the dominant side: the controller must hold.
        let mut c = ctl();
        let sig = RoundSignal { imbalance: 4.0, twc_cycles: 10, lb_cycles: 100_000, lb_triggered: true };
        let before = c.threshold();
        c.observe(&sig);
        assert_eq!(c.threshold(), before);
    }

    #[test]
    fn sub_warp_start_is_never_raised() {
        // A user-chosen threshold below the warp floor: the floor clamps
        // to the start, so the "lower" rule can never push the threshold
        // above round 0's.
        let spec = GpuSpec::default_sim();
        let mut c =
            AdaptiveController::new(Distribution::Cyclic, 2, &spec, &CostModel::default());
        for _ in 0..16 {
            c.observe(&skewed_signal());
            assert_eq!(c.threshold(), 2);
        }
    }

    #[test]
    fn auto_table_resolves_or_defaults() {
        assert_eq!(auto_balancer("bfs", "road-s"), Balancer::Twc);
        assert_eq!(
            auto_balancer("bfs", "rmat18"),
            Balancer::Adaptive { distribution: Distribution::Cyclic, threshold: None }
        );
        // Every table row must name a parseable strategy.
        for &(_, _, strat) in AUTO_TABLE {
            assert!(Balancer::parse(strat).is_some(), "{strat}");
        }
    }
}
