//! The work-segmentation core all load-balancing strategies compose over.
//!
//! Every strategy in this crate answers the same two questions for each
//! active vertex: *which segment* does it belong to (the TWC kernel's
//! per-vertex bins, or the flat edge-parallel LB launch), and *how is the
//! LB segment executed* (searched cyclic/blocked distribution vs. one grid
//! launch per vertex). Following the segment-assignment formulation of
//! Osama et al. (arXiv 2301.04792), a strategy is just a [`Composition`]:
//!
//! * a **threshold** routing degree-`>= t` vertices to the LB segment
//!   (`u64::MAX` = never, `0` = always — the vertex/twc and edge-lb
//!   extremes);
//! * a **bucket policy** for the per-vertex segment ([`Bucket::Twc`]
//!   degree binning or [`Bucket::Thread`] one-thread-per-vertex);
//! * an **LB policy**: edge distribution, whether threads binary-search
//!   their source ([`LbLaunch::search`]), the launch gate, and whether the
//!   huge bin is charged a prefix-sum pass.
//!
//! The split walk itself ([`split_into`]) and its pooled variant are shared
//! verbatim by every composition, so the strategies stay bit-identical to
//! their historical hand-rolled forms (pinned by `tests/parity.rs`) while
//! the adaptive controller ([`crate::lb::adaptive`]) can re-parameterize
//! the threshold per round without touching any strategy code.

use crate::exec::Pool;
use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{
    Distribution, LbLaunch, ScheduleScratch, SplitChunk, Unit, VertexItem,
};
use crate::lb::{degree, twc, Direction};

/// Below this many active vertices the pooled split falls back to the
/// sequential walk — the threshold probe is too cheap to farm out.
pub(crate) const PAR_SPLIT_MIN: usize = 2048;

/// How vertices below the threshold are binned for the TWC kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bucket {
    /// Thread/Warp/CTA by degree ([`twc::bin`]).
    Twc,
    /// Always one thread per vertex (vertex-based baseline, §3.1).
    Thread,
}

impl Bucket {
    #[inline]
    pub fn bin(self, deg: u64, spec: &GpuSpec) -> Unit {
        match self {
            Bucket::Twc => twc::bin(deg, spec),
            Bucket::Thread => Unit::Thread,
        }
    }
}

/// When the LB segment actually launches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchGate {
    /// ALB/Enterprise benefit check (§4): launch iff the huge bin is
    /// non-empty.
    NonEmptyHuge,
    /// Gunrock-style edge LB: launch iff the segment holds at least one
    /// edge (zero-degree vertices still get prefix entries but never
    /// justify a launch on their own).
    PositiveEdges,
}

/// How the huge bin's shared prefix sum is charged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixAccounting {
    /// One prefix item per huge vertex (ALB Fig. 3 line 31; edge-lb spans
    /// the whole active set, which *is* its huge bin).
    HugeItems,
    /// No prefix-sum kernel: each launch knows its single source
    /// (Enterprise grid launches).
    None,
}

/// Execution policy for the LB segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LbPolicy {
    pub distribution: Distribution,
    /// Threads recover their source vertex by binary search (ALB,
    /// edge-lb); `false` models one grid launch per vertex (Enterprise).
    pub search: bool,
    pub gate: LaunchGate,
    pub prefix: PrefixAccounting,
}

/// A load-balancing strategy expressed as segment assignment + policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Composition {
    /// Degree bound for the LB segment (`d >= threshold` routes there).
    pub threshold: u64,
    pub bucket: Bucket,
    pub lb: LbPolicy,
}

impl Composition {
    /// Vertex-based baseline: no LB segment, one thread per vertex.
    pub fn vertex() -> Self {
        Composition {
            threshold: u64::MAX,
            bucket: Bucket::Thread,
            lb: LbPolicy {
                distribution: Distribution::Cyclic,
                search: true,
                gate: LaunchGate::NonEmptyHuge,
                prefix: PrefixAccounting::HugeItems,
            },
        }
    }

    /// Plain TWC: no LB segment, degree binning.
    pub fn twc() -> Self {
        Composition { bucket: Bucket::Twc, ..Self::vertex() }
    }

    /// The paper's ALB: TWC below the threshold, searched distribution
    /// above it, prefix sum over the huge bin.
    pub fn alb(distribution: Distribution, threshold: u64) -> Self {
        Composition {
            threshold,
            bucket: Bucket::Twc,
            lb: LbPolicy {
                distribution,
                search: true,
                gate: LaunchGate::NonEmptyHuge,
                prefix: PrefixAccounting::HugeItems,
            },
        }
    }

    /// Gunrock-style static edge LB: *everything* (zero-degree vertices
    /// included) lands in the LB segment every round.
    pub fn edge_lb(distribution: Distribution) -> Self {
        Composition {
            threshold: 0,
            bucket: Bucket::Twc, // unreachable: every degree >= 0
            lb: LbPolicy {
                distribution,
                search: true,
                gate: LaunchGate::PositiveEdges,
                prefix: PrefixAccounting::HugeItems,
            },
        }
    }

    /// Enterprise's extremely-large bin: blocked grid launches, one per
    /// hub, no search and no prefix-sum kernel.
    pub fn enterprise(threshold: u64) -> Self {
        Composition {
            threshold,
            bucket: Bucket::Twc,
            lb: LbPolicy {
                distribution: Distribution::Blocked,
                search: false,
                gate: LaunchGate::NonEmptyHuge,
                prefix: PrefixAccounting::None,
            },
        }
    }
}

/// The shared segment-assignment walk (paper Fig. 3 lines 3–9 + 31):
/// vertices at or above `threshold` accumulate into the huge list with an
/// inclusive degree prefix; the rest are binned per `bucket`. Callers own
/// (and pre-clear) the output buffers.
///
/// §Perf (DESIGN.md §13): the walk is batched 8 vertices per iteration —
/// the degree gather (two `row_offsets` loads per vertex, the pass's only
/// memory traffic) fills a `[u64; 8]` accumulator block first, then the
/// branchy routing consumes it in order. Separating the gather from the
/// routing keeps the loads pipelined across the unpredictable
/// huge-vs-rest branch. Output order and the running inclusive prefix are
/// untouched, so the schedule is bit-identical to
/// [`split_into_ref`](split_into_ref).
#[allow(clippy::too_many_arguments)]
pub(crate) fn split_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    threshold: u64,
    bucket: Bucket,
    huge: &mut Vec<u32>,
    prefix: &mut Vec<u64>,
    rest: &mut Vec<VertexItem>,
) {
    let mut run = 0u64;
    let mut degs = [0u64; 8];
    let mut batch = active.chunks_exact(8);
    for vs in batch.by_ref() {
        for (slot, &v) in degs.iter_mut().zip(vs) {
            *slot = degree(g, v, dir);
        }
        for (&v, &d) in vs.iter().zip(&degs) {
            if d >= threshold {
                run += d;
                huge.push(v);
                prefix.push(run);
            } else {
                rest.push(VertexItem { vertex: v, degree: d, unit: bucket.bin(d, spec) });
            }
        }
    }
    for &v in batch.remainder() {
        let d = degree(g, v, dir);
        if d >= threshold {
            run += d;
            huge.push(v);
            prefix.push(run);
        } else {
            rest.push(VertexItem { vertex: v, degree: d, unit: bucket.bin(d, spec) });
        }
    }
}

/// The pre-batching scalar walk (one degree probe + route per iteration),
/// kept in-binary as the `-ref` twin for the oracle tests. Not a hot path.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn split_into_ref(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    threshold: u64,
    bucket: Bucket,
    huge: &mut Vec<u32>,
    prefix: &mut Vec<u64>,
    rest: &mut Vec<VertexItem>,
) {
    let mut run = 0u64;
    for &v in active {
        let d = degree(g, v, dir);
        if d >= threshold {
            run += d;
            huge.push(v);
            prefix.push(run);
        } else {
            rest.push(VertexItem { vertex: v, degree: d, unit: bucket.bin(d, spec) });
        }
    }
}

/// Apply the composition's launch gate + prefix accounting to a completed
/// split, installing (or returning) the LB buffers.
fn finish(
    comp: &Composition,
    huge: Vec<u32>,
    prefix: Vec<u64>,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    out.sched.prefix_items = match comp.lb.prefix {
        PrefixAccounting::HugeItems => huge.len() as u64,
        PrefixAccounting::None => 0,
    };
    out.sched.scan_vertices = scan_vertices;
    let launch = match comp.lb.gate {
        LaunchGate::NonEmptyHuge => !huge.is_empty(),
        LaunchGate::PositiveEdges => prefix.last().copied().unwrap_or(0) > 0,
    };
    if launch {
        out.sched.lb = Some(LbLaunch {
            vertices: huge,
            prefix,
            distribution: comp.lb.distribution,
            search: comp.lb.search,
        });
    } else {
        out.restore_lb_buffers(huge, prefix);
    }
}

/// Build the round schedule for `comp` into caller-owned buffers (`out` is
/// reset first).
pub fn schedule_into(
    comp: &Composition,
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    out.reset();
    let (mut huge, mut prefix) = out.lb_buffers();
    split_into(
        active, g, dir, spec, comp.threshold, comp.bucket,
        &mut huge, &mut prefix, &mut out.sched.twc,
    );
    finish(comp, huge, prefix, scan_vertices, out);
}

/// [`schedule_into`] with the segment-assignment walk split into fixed
/// contiguous chunks of the active set on `pool` (DESIGN.md §9). Each
/// chunk probes degrees into its own [`SplitChunk`] buffers; the fold
/// appends huge/rest lists in chunk (= active) order and rebases each
/// chunk's local degree prefix by the running total, so the schedule is
/// bit-identical to the sequential split for any pool width. Small active
/// sets and 1-thread pools take the sequential path unchanged.
#[allow(clippy::too_many_arguments)]
pub fn schedule_into_pooled(
    comp: &Composition,
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
    pool: &Pool,
) {
    if pool.threads() <= 1 || active.len() < PAR_SPLIT_MIN {
        schedule_into(comp, active, g, dir, spec, scan_vertices, out);
        return;
    }
    out.reset();
    let nchunks = pool.threads().min(active.len()).max(1);
    let per = active.len().div_ceil(nchunks);
    out.ensure_split_chunks(nchunks);
    {
        let chunks = &out.split_chunks[..nchunks];
        pool.run(nchunks, &|ci| {
            let lo = (ci * per).min(active.len());
            let hi = ((ci + 1) * per).min(active.len());
            let mut c = chunks[ci].lock().unwrap();
            let c: &mut SplitChunk = &mut c;
            c.huge.clear();
            c.prefix.clear();
            c.rest.clear();
            split_into(
                &active[lo..hi], g, dir, spec, comp.threshold, comp.bucket,
                &mut c.huge, &mut c.prefix, &mut c.rest,
            );
        });
    }
    // Fold in chunk (= active) order, rebasing each chunk's local prefix.
    let (mut huge, mut prefix) = out.lb_buffers();
    let ScheduleScratch { sched, split_chunks, .. } = out;
    let mut offset = 0u64;
    for m in &split_chunks[..nchunks] {
        let c = m.lock().unwrap();
        huge.extend_from_slice(&c.huge);
        for &p in &c.prefix {
            prefix.push(p + offset);
        }
        offset += c.prefix.last().copied().unwrap_or(0);
        sched.twc.extend_from_slice(&c.rest);
    }
    finish(comp, huge, prefix, scan_vertices, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;
    use crate::lb::{alb, edge, enterprise, vertex, Balancer};

    /// hub (500k) + mid (200) + leaves (1) + isolated tail vertices.
    fn skewed() -> CsrGraph {
        let n = 10_000u32;
        let mut el = EdgeList::new(n);
        for i in 0..500_000u32 {
            el.push(0, 2 + (i % (n - 2)), 1.0);
        }
        for i in 0..200u32 {
            el.push(1, 2 + i, 1.0);
        }
        for v in 2..1_002u32 {
            el.push(v, 0, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn compositions_reproduce_every_strategy() {
        // The refactor's contract: each hand-rolled strategy equals its
        // composition, field for field.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let t = spec.huge_threshold();
        let cases: Vec<(Composition, crate::lb::Schedule)> = vec![
            (
                Composition::vertex(),
                vertex::schedule(&active, &g, Direction::Push, 7),
            ),
            (
                Composition::twc(),
                twc::schedule(&active, &g, Direction::Push, &spec, 7),
            ),
            (
                Composition::alb(Distribution::Cyclic, t),
                alb::schedule(
                    &active, &g, Direction::Push, &spec,
                    Distribution::Cyclic, t, 7,
                ),
            ),
            (
                Composition::edge_lb(Distribution::Cyclic),
                edge::schedule(&active, &g, Direction::Push, &spec, Distribution::Cyclic, 7),
            ),
            (
                Composition::enterprise(t),
                enterprise::schedule(&active, &g, Direction::Push, &spec, 7),
            ),
        ];
        for (comp, want) in cases {
            let mut got = ScheduleScratch::new();
            schedule_into(&comp, &active, &g, Direction::Push, &spec, 7, &mut got);
            assert_eq!(got.sched, want, "{comp:?}");
        }
    }

    #[test]
    fn edge_gate_skips_edgeless_frontiers() {
        // PositiveEdges: zero-degree-only frontier builds prefix entries
        // but must not launch.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let comp = Composition::edge_lb(Distribution::Cyclic);
        let mut s = ScheduleScratch::new();
        schedule_into(&comp, &[5_000, 5_001], &g, Direction::Push, &spec, 2, &mut s);
        assert!(s.sched.lb.is_none());
        assert_eq!(s.sched.prefix_items, 2, "prefix pass still spans the frontier");
    }

    #[test]
    fn pooled_matches_sequential_for_every_composition() {
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..g.num_vertices() as u32).collect();
        assert!(active.len() >= PAR_SPLIT_MIN);
        let comps = [
            Composition::vertex(),
            Composition::twc(),
            Composition::alb(Distribution::Cyclic, 150),
            Composition::edge_lb(Distribution::Blocked),
            Composition::enterprise(spec.huge_threshold()),
        ];
        for comp in comps {
            let mut want = ScheduleScratch::new();
            schedule_into(&comp, &active, &g, Direction::Push, &spec, 3, &mut want);
            for threads in [1usize, 2, 3, 7] {
                let pool = Pool::new(threads);
                let mut got = ScheduleScratch::new();
                schedule_into_pooled(
                    &comp, &active, &g, Direction::Push, &spec, 3, &mut got, &pool,
                );
                assert_eq!(got.sched, want.sched, "{comp:?} threads={threads}");
            }
        }
    }

    #[test]
    fn batched_split_matches_scalar_reference() {
        // Oracle for the 8-wide probe batch: the skewed graph supplies
        // hub/mid/leaf/zero degrees; thresholds cover never/always/middle;
        // lengths exercise every chunk remainder 0..=7 plus the full set.
        let g = skewed();
        let spec = GpuSpec::default_sim();
        let all: Vec<u32> = (0..g.num_vertices() as u32).collect();
        for threshold in [0u64, 1, 150, 500_000, u64::MAX] {
            for len in [0usize, 1, 5, 7, 8, 9, 15, 1_000, 9_999, 10_000] {
                let active = &all[..len];
                let (mut h, mut p, mut r) = (Vec::new(), Vec::new(), Vec::new());
                split_into(
                    active, &g, Direction::Push, &spec, threshold, Bucket::Twc,
                    &mut h, &mut p, &mut r,
                );
                let (mut hr, mut pr, mut rr) = (Vec::new(), Vec::new(), Vec::new());
                split_into_ref(
                    active, &g, Direction::Push, &spec, threshold, Bucket::Twc,
                    &mut hr, &mut pr, &mut rr,
                );
                assert_eq!(h, hr, "huge t={threshold} len={len}");
                assert_eq!(p, pr, "prefix t={threshold} len={len}");
                assert_eq!(r, rr, "rest t={threshold} len={len}");
            }
        }
    }

    #[test]
    fn balancer_compositions_match_dispatch() {
        // Balancer::schedule routes through the composition core; spot
        // check the mapping stays the inverse of Composition constructors.
        let spec = GpuSpec::default_sim();
        let t = spec.huge_threshold();
        let cases = [
            (Balancer::Vertex, Composition::vertex()),
            (Balancer::Twc, Composition::twc()),
            (
                Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
                Composition::alb(Distribution::Cyclic, t),
            ),
            (
                Balancer::EdgeLb { distribution: Distribution::Blocked },
                Composition::edge_lb(Distribution::Blocked),
            ),
            (Balancer::Enterprise, Composition::enterprise(t)),
        ];
        for (b, comp) in cases {
            assert_eq!(b.composition(&spec), comp, "{}", b.name());
        }
    }
}
