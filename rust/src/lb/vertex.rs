//! Vertex-based load distribution (§3.1): every active vertex is handed to
//! exactly one thread, which walks all its edges serially. The baseline that
//! collapses on power-law degree distributions (and what Lux-style
//! frameworks approximate at the intra-GPU level).

use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{Schedule, ScheduleScratch};
use crate::lb::segment::{self, Composition};
use crate::lb::Direction;

pub fn schedule(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    scan_vertices: u64,
) -> Schedule {
    let mut scratch = ScheduleScratch::new();
    schedule_into(active, g, dir, &GpuSpec::default_sim(), scan_vertices, &mut scratch);
    scratch.sched
}

/// A no-LB-segment [`Composition`] with the uniform `Thread` bucket: every
/// active vertex is one thread's serial work, whatever its degree.
pub fn schedule_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    segment::schedule_into(
        &Composition::vertex(),
        active, g, dir, spec, scan_vertices, out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{CostModel, Simulator};
    use crate::graph::EdgeList;
    use crate::lb::schedule::Unit;

    fn hub_plus_leaves() -> CsrGraph {
        // vertex 0: degree 10_000; vertices 1..=100: degree 1
        let mut el = EdgeList::new(10_101);
        for i in 0..10_000 {
            el.push(0, 101 + i, 1.0);
        }
        for v in 1..=100 {
            el.push(v, 0, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn all_items_are_thread_level() {
        let g = hub_plus_leaves();
        let s = schedule(&[0, 1, 2], &g, Direction::Push, 3);
        assert!(s.twc.iter().all(|i| i.unit == Unit::Thread));
        assert!(s.lb.is_none());
        assert_eq!(s.twc[0].degree, 10_000);
    }

    #[test]
    fn hub_serializes_on_one_thread() {
        // The §3.1 failure mode: one thread walks 10k edges while the rest
        // of the GPU idles — kernel time ~ hub degree.
        let g = hub_plus_leaves();
        let active: Vec<u32> = (0..101).collect();
        let s = schedule(&active, &g, Direction::Push, 0);
        let sim = Simulator::new(GpuSpec::default_sim(), CostModel::default());
        let r = sim.simulate(&s, true);
        let k = &r.kernels[0];
        let per_edge = sim.cost.cycles_edge + sim.cost.cycles_atomic;
        assert!(k.kernel_cycles >= 10_000 * per_edge);
        assert!(k.imbalance_factor() > 5.0);
    }

    #[test]
    fn empty_active_set() {
        let g = hub_plus_leaves();
        let s = schedule(&[], &g, Direction::Push, 0);
        assert!(s.twc.is_empty());
        assert_eq!(s.total_edges(), 0);
    }
}
