//! Schedule types — the contract between load-balancing strategies
//! ([`crate::lb`]) and the kernel simulator ([`crate::gpu::sim`]).
//!
//! A round's schedule names up to two kernel launches, mirroring the paper's
//! generated code (Fig. 3): the TWC kernel (always launched — it doubles as
//! the inspector) and the LB kernel (launched only when the huge worklist is
//! non-empty).

use std::sync::Mutex;


/// Which level of the thread hierarchy processes a vertex's edges (TWC bins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Small bin: one thread walks all edges serially.
    Thread,
    /// Medium bin: a warp's 32 lanes split the edges.
    Warp,
    /// Large bin: the whole thread block (CTA) splits the edges.
    Block,
}

/// One vertex's work assignment in the TWC kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VertexItem {
    pub vertex: u32,
    pub degree: u64,
    pub unit: Unit,
}

/// How the LB kernel spreads edges across threads (paper Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distribution {
    /// Thread `t` takes edges `t, t+p, t+2p, ...` — consecutive lanes search
    /// consecutive edge ids (cache-friendly; the paper's winner).
    Cyclic,
    /// Thread `t` takes a contiguous chunk `[t*w, (t+1)*w)`.
    Blocked,
}

/// The LB kernel launch: every edge of the `huge` vertices, distributed
/// evenly across all launched threads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LbLaunch {
    /// Vertices whose edges are being distributed (paper's huge bin — or all
    /// active vertices for Gunrock-style static LB).
    pub vertices: Vec<u32>,
    /// Inclusive prefix sum of their out-degrees; `prefix.last()` =
    /// total_edges (paper Fig. 3 line 14).
    pub prefix: Vec<u64>,
    pub distribution: Distribution,
    /// Whether threads recover sources by binary search (ALB / Gunrock-LB).
    /// Enterprise-style grid launches (`false`) process one known vertex
    /// per launch: no search, but one kernel launch *per vertex*.
    pub search: bool,
}

impl LbLaunch {
    pub fn total_edges(&self) -> u64 {
        self.prefix.last().copied().unwrap_or(0)
    }
}

/// One round's kernel launches plus worklist-management accounting.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Schedule {
    /// TWC kernel work items, in worklist order.
    pub twc: Vec<VertexItem>,
    /// LB kernel, if the strategy triggered it this round.
    pub lb: Option<LbLaunch>,
    /// Vertices scanned to discover the active set (dense worklists scan
    /// |V|, sparse scan |active| — the §6.1 road-USA effect).
    pub scan_vertices: u64,
    /// Items run through the inspector's prefix sum this round.
    pub prefix_items: u64,
}

impl Schedule {
    /// Total edges this schedule will process (TWC + LB).
    pub fn total_edges(&self) -> u64 {
        let twc: u64 = self.twc.iter().map(|i| i.degree).sum();
        twc + self.lb.as_ref().map_or(0, |l| l.total_edges())
    }
}

/// Reusable schedule buffers (DESIGN.md §8): the engine owns one of these
/// per run (the coordinator: one per simulated GPU) and every
/// [`crate::lb::Balancer::schedule_into`] call refills `sched` in place.
/// When a round triggers the LB kernel, its `vertices`/`prefix` vecs live
/// inside `sched.lb`; [`reset`](ScheduleScratch::reset) recovers them into
/// the spares, so the steady state allocates nothing once capacities warm.
#[derive(Debug, Default)]
pub struct ScheduleScratch {
    pub sched: Schedule,
    spare_vertices: Vec<u32>,
    spare_prefix: Vec<u64>,
    /// Per-chunk buffers for the pooled ALB inspector split (DESIGN.md §9).
    /// A chunk index is written by exactly one pool task per round; the
    /// mutex satisfies the shared-closure aliasing rules and is never
    /// contended. Capacities persist across rounds (§8).
    pub(crate) split_chunks: Vec<Mutex<SplitChunk>>,
}

/// One contiguous active-range chunk of the ALB inspector's threshold probe
/// pass: the chunk's huge vertices, their *chunk-local* inclusive degree
/// prefix (rebased by the fold), and the TWC-binned rest.
#[derive(Debug, Default)]
pub(crate) struct SplitChunk {
    pub huge: Vec<u32>,
    pub prefix: Vec<u64>,
    pub rest: Vec<VertexItem>,
}

impl ScheduleScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the split-chunk list to at least `n` slots (capacities persist).
    pub(crate) fn ensure_split_chunks(&mut self, n: usize) {
        while self.split_chunks.len() < n {
            self.split_chunks.push(Mutex::new(SplitChunk::default()));
        }
    }

    /// Clear for the next round, recovering the LB buffers' capacity.
    pub fn reset(&mut self) {
        self.sched.twc.clear();
        self.sched.scan_vertices = 0;
        self.sched.prefix_items = 0;
        if let Some(lb) = self.sched.lb.take() {
            self.spare_vertices = lb.vertices;
            self.spare_vertices.clear();
            self.spare_prefix = lb.prefix;
            self.spare_prefix.clear();
        }
    }

    /// Hand out the (empty, capacity-retaining) LB buffers for a strategy
    /// to fill. A strategy that ends up not launching the LB kernel must
    /// give them back via [`restore_lb_buffers`](Self::restore_lb_buffers).
    pub fn lb_buffers(&mut self) -> (Vec<u32>, Vec<u64>) {
        (
            std::mem::take(&mut self.spare_vertices),
            std::mem::take(&mut self.spare_prefix),
        )
    }

    /// Return unused LB buffers so their capacity survives to next round.
    pub fn restore_lb_buffers(&mut self, mut vertices: Vec<u32>, mut prefix: Vec<u64>) {
        vertices.clear();
        prefix.clear();
        self.spare_vertices = vertices;
        self.spare_prefix = prefix;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_total_edges_from_prefix() {
        let lb = LbLaunch {
            vertices: vec![1, 2],
            prefix: vec![10, 25],
            distribution: Distribution::Cyclic,
            search: true,
        };
        assert_eq!(lb.total_edges(), 25);
    }

    #[test]
    fn empty_lb_is_zero() {
        let lb = LbLaunch {
            vertices: vec![],
            prefix: vec![],
            distribution: Distribution::Blocked,
            search: true,
        };
        assert_eq!(lb.total_edges(), 0);
    }

    #[test]
    fn scratch_reset_recovers_lb_capacity() {
        let mut s = ScheduleScratch::new();
        let (mut v, mut p) = s.lb_buffers();
        v.extend_from_slice(&[1, 2, 3]);
        p.extend_from_slice(&[10, 20, 30]);
        let vcap = v.capacity();
        s.sched.lb = Some(LbLaunch {
            vertices: v,
            prefix: p,
            distribution: Distribution::Cyclic,
            search: true,
        });
        s.sched.twc.push(VertexItem { vertex: 9, degree: 5, unit: Unit::Thread });
        s.reset();
        assert!(s.sched.twc.is_empty());
        assert!(s.sched.lb.is_none());
        let (v2, p2) = s.lb_buffers();
        assert!(v2.is_empty() && p2.is_empty());
        assert!(v2.capacity() >= vcap, "capacity must survive reset");
        s.restore_lb_buffers(v2, p2);
    }

    #[test]
    fn schedule_total_combines_kernels() {
        let s = Schedule {
            twc: vec![
                VertexItem { vertex: 0, degree: 3, unit: Unit::Thread },
                VertexItem { vertex: 1, degree: 40, unit: Unit::Warp },
            ],
            lb: Some(LbLaunch {
                vertices: vec![2],
                prefix: vec![100],
                distribution: Distribution::Cyclic,
                search: true,
            }),
            scan_vertices: 10,
            prefix_items: 1,
        };
        assert_eq!(s.total_edges(), 143);
    }
}
