//! Load-balancing strategies (paper §3–§4).
//!
//! Each strategy maps the round's active vertices to a [`Schedule`] — the
//! kernel launches the simulated GPU executes. Implemented strategies:
//!
//! * [`vertex`]   — vertex-based: every active vertex to one thread (§3.1);
//! * [`twc`]      — Thread-Warp-CTA binning by degree (§3.2, Merrill et al.);
//! * [`edge`]     — edge-based LB over *all* active edges every round —
//!                  Gunrock's "LB" policy (§3.3);
//! * [`alb`]      — **the paper's contribution**: TWC plus a runtime
//!                  inspector that routes huge-degree vertices (degree >=
//!                  launched threads) to an even, cyclic edge distribution
//!                  across all thread blocks (§4).

pub mod adaptive;
pub mod alb;
pub mod edge;
pub mod enterprise;
pub mod schedule;
pub mod segment;
pub mod twc;
pub mod vertex;


use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
pub use schedule::{
    Distribution, LbLaunch, Schedule, ScheduleScratch, Unit, VertexItem,
};

/// Which edge set an operator traverses (push reads out-edges, pull reads
/// in-edges) — binning uses the matching degree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Push,
    Pull,
}

/// Degree of `v` along `dir` (Pull requires the CSC view to be built).
#[inline]
pub fn degree(g: &CsrGraph, v: u32, dir: Direction) -> u64 {
    match dir {
        Direction::Push => g.out_degree(v),
        Direction::Pull => g.in_degree(v),
    }
}

/// A load-balancing policy, selectable per run (CLI `--balancer`).
#[derive(Debug, Clone, PartialEq)]
pub enum Balancer {
    /// One thread per active vertex.
    Vertex,
    /// Thread/Warp/CTA degree binning, no inter-block balancing.
    Twc,
    /// Gunrock-style static LB: all active edges evenly split every round.
    EdgeLb { distribution: Distribution },
    /// The paper's adaptive balancer. `threshold`: degree bound for the
    /// huge bin (default = launched threads, §4.2).
    Alb { distribution: Distribution, threshold: Option<u64> },
    /// Enterprise-style (§3.3, [18]): TWC + an "extremely large" bin
    /// processed by all CTAs, one launch per hub, no search.
    Enterprise,
    /// ALB plus a per-round feedback controller that steers the inspector
    /// threshold and the LB cost model's sampled-warp budget from the
    /// previous round's measured imbalance ([`adaptive`]). `threshold` is
    /// the controller's *starting* point (round 0 == plain ALB).
    Adaptive { distribution: Distribution, threshold: Option<u64> },
    /// Pick the starting strategy per (input, app) from committed campaign
    /// history ([`adaptive::auto_balancer`]); resolved at the CLI/campaign
    /// layer, and treated as default [`Balancer::Adaptive`] by the engine
    /// if it ever arrives unresolved.
    Auto,
}

/// Every strategy name [`Balancer::parse`] accepts, in display order —
/// keep CLI error messages and help text in sync with this one list.
pub const BALANCER_NAMES: &[&str] =
    &["vertex", "twc", "edge-lb", "alb", "enterprise", "adaptive", "auto"];

impl Balancer {
    /// Parse a strategy name (CLI `--balancer`, campaign `--balancers`):
    /// the inverse of [`name`](Self::name), with cyclic distribution and
    /// the default ALB threshold. `None` for unknown names.
    pub fn parse(s: &str) -> Option<Balancer> {
        match s {
            "vertex" => Some(Balancer::Vertex),
            "twc" => Some(Balancer::Twc),
            "edge-lb" => Some(Balancer::EdgeLb { distribution: Distribution::Cyclic }),
            "alb" => Some(Balancer::Alb {
                distribution: Distribution::Cyclic,
                threshold: None,
            }),
            "enterprise" => Some(Balancer::Enterprise),
            "adaptive" => Some(Balancer::Adaptive {
                distribution: Distribution::Cyclic,
                threshold: None,
            }),
            "auto" => Some(Balancer::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Balancer::Vertex => "vertex",
            Balancer::Twc => "twc",
            Balancer::EdgeLb { .. } => "edge-lb",
            Balancer::Alb { .. } => "alb",
            Balancer::Enterprise => "enterprise",
            Balancer::Adaptive { .. } => "adaptive",
            Balancer::Auto => "auto",
        }
    }

    /// The strategy's [`segment::Composition`] — how it parameterizes the
    /// shared segment-assignment core. For [`Balancer::Adaptive`] this is
    /// the starting (round-0) composition; the engine swaps in the
    /// controller's current threshold each round.
    pub fn composition(&self, spec: &GpuSpec) -> segment::Composition {
        use segment::Composition;
        match self {
            Balancer::Vertex => Composition::vertex(),
            Balancer::Twc => Composition::twc(),
            Balancer::EdgeLb { distribution } => Composition::edge_lb(*distribution),
            Balancer::Alb { distribution, threshold }
            | Balancer::Adaptive { distribution, threshold } => Composition::alb(
                *distribution,
                threshold.unwrap_or_else(|| spec.huge_threshold()),
            ),
            Balancer::Enterprise => Composition::enterprise(spec.huge_threshold()),
            Balancer::Auto => {
                Composition::alb(Distribution::Cyclic, spec.huge_threshold())
            }
        }
    }

    /// Build the round schedule into freshly-allocated buffers. Convenience
    /// wrapper over [`schedule_into`](Self::schedule_into) for tests and
    /// one-shot callers; the engine's hot loop uses `schedule_into` with a
    /// per-run [`ScheduleScratch`] so the steady state allocates nothing.
    pub fn schedule(
        &self,
        active: &[u32],
        g: &CsrGraph,
        dir: Direction,
        spec: &GpuSpec,
        scan_vertices: u64,
    ) -> Schedule {
        let mut scratch = ScheduleScratch::new();
        self.schedule_into(active, g, dir, spec, scan_vertices, &mut scratch);
        scratch.sched
    }

    /// [`schedule_into`](Self::schedule_into) with the segment-assignment
    /// walk chunked onto the shared worker pool (DESIGN.md §9,
    /// [`segment::schedule_into_pooled`]). Output is bit-identical to the
    /// sequential walk for any pool width.
    #[allow(clippy::too_many_arguments)]
    pub fn schedule_into_pooled(
        &self,
        active: &[u32],
        g: &CsrGraph,
        dir: Direction,
        spec: &GpuSpec,
        scan_vertices: u64,
        out: &mut ScheduleScratch,
        pool: &crate::exec::Pool,
    ) {
        segment::schedule_into_pooled(
            &self.composition(spec),
            active, g, dir, spec, scan_vertices, out, pool,
        );
    }

    /// Build the round schedule into caller-owned buffers (`out` is reset
    /// first). `scan_vertices` is the worklist-discovery cost the engine
    /// charges (dense: |V|; sparse: |active|).
    pub fn schedule_into(
        &self,
        active: &[u32],
        g: &CsrGraph,
        dir: Direction,
        spec: &GpuSpec,
        scan_vertices: u64,
        out: &mut ScheduleScratch,
    ) {
        segment::schedule_into(
            &self.composition(spec),
            active, g, dir, spec, scan_vertices, out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn star(deg: u32) -> CsrGraph {
        let mut el = EdgeList::new(deg + 1);
        for i in 1..=deg {
            el.push(0, i, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn degree_direction_dispatch() {
        let mut g = star(5);
        g.build_csc();
        assert_eq!(degree(&g, 0, Direction::Push), 5);
        assert_eq!(degree(&g, 0, Direction::Pull), 0);
        assert_eq!(degree(&g, 3, Direction::Pull), 1);
    }

    #[test]
    fn balancer_names() {
        assert_eq!(Balancer::Twc.name(), "twc");
        assert_eq!(
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None }.name(),
            "alb"
        );
    }

    #[test]
    fn balancer_parse_inverts_name() {
        for &name in BALANCER_NAMES {
            let b = Balancer::parse(name).unwrap();
            assert_eq!(b.name(), name);
        }
        assert_eq!(Balancer::parse("bogus"), None);
        assert_eq!(
            Balancer::parse("alb"),
            Some(Balancer::Alb { distribution: Distribution::Cyclic, threshold: None })
        );
    }

    #[test]
    fn every_balancer_covers_all_edges() {
        // Work conservation: whatever the strategy, the schedule must account
        // for exactly the active vertices' edges.
        let g = star(2000);
        let spec = GpuSpec::default_sim();
        let active: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let total: u64 = active.iter().map(|&v| g.out_degree(v)).sum();
        for b in [
            Balancer::Vertex,
            Balancer::Twc,
            Balancer::EdgeLb { distribution: Distribution::Cyclic },
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: Some(100) },
            Balancer::Enterprise,
        ] {
            let s = b.schedule(&active, &g, Direction::Push, &spec, 0);
            assert_eq!(s.total_edges(), total, "{}", b.name());
        }
    }
}
