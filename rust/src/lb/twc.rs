//! Thread-Warp-CTA (TWC) binning (§3.2; Merrill et al. [22], IrGL [28]).
//!
//! Vertices are routed by degree: `< warp_size` -> a single thread;
//! `< threads_per_block` -> a warp; otherwise -> a whole thread block (CTA).
//! Good intra-block balance and locality, *no* inter-block balancing — the
//! large bin has no upper bound, which is exactly the weakness Figure 1
//! demonstrates and ALB fixes.

use crate::graph::CsrGraph;
use crate::gpu::GpuSpec;
use crate::lb::schedule::{Schedule, ScheduleScratch, Unit};
use crate::lb::Direction;

/// Bin one degree per the TWC thresholds.
#[inline]
pub fn bin(deg: u64, spec: &GpuSpec) -> Unit {
    if deg < spec.warp_size as u64 {
        Unit::Thread
    } else if deg < spec.threads_per_block as u64 {
        Unit::Warp
    } else {
        Unit::Block
    }
}

pub fn schedule(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
) -> Schedule {
    let mut scratch = ScheduleScratch::new();
    schedule_into(active, g, dir, spec, scan_vertices, &mut scratch);
    scratch.sched
}

/// A no-LB-segment [`Composition`][crate::lb::segment::Composition]:
/// threshold `u64::MAX` keeps every vertex in the binned TWC kernel.
pub fn schedule_into(
    active: &[u32],
    g: &CsrGraph,
    dir: Direction,
    spec: &GpuSpec,
    scan_vertices: u64,
    out: &mut ScheduleScratch,
) {
    crate::lb::segment::schedule_into(
        &crate::lb::segment::Composition::twc(),
        active, g, dir, spec, scan_vertices, out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{CostModel, Simulator};
    use crate::graph::EdgeList;

    fn mixed_graph() -> CsrGraph {
        // degrees: v0 = 4 (thread), v1 = 64 (warp), v2 = 500 (block)
        let mut el = EdgeList::new(600);
        for i in 0..4 {
            el.push(0, 10 + i, 1.0);
        }
        for i in 0..64 {
            el.push(1, 20 + i, 1.0);
        }
        for i in 0..500 {
            el.push(2, 90 + i % 500, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn binning_thresholds() {
        let spec = GpuSpec::default_sim(); // warp 32, block 128
        assert_eq!(bin(0, &spec), Unit::Thread);
        assert_eq!(bin(31, &spec), Unit::Thread);
        assert_eq!(bin(32, &spec), Unit::Warp);
        assert_eq!(bin(127, &spec), Unit::Warp);
        assert_eq!(bin(128, &spec), Unit::Block);
        assert_eq!(bin(1 << 30, &spec), Unit::Block);
    }

    #[test]
    fn schedule_assigns_expected_units() {
        let g = mixed_graph();
        let spec = GpuSpec::default_sim();
        let s = schedule(&[0, 1, 2], &g, Direction::Push, &spec, 3);
        assert_eq!(s.twc[0].unit, Unit::Thread);
        assert_eq!(s.twc[1].unit, Unit::Warp);
        assert_eq!(s.twc[2].unit, Unit::Block);
        assert!(s.lb.is_none());
    }

    #[test]
    fn twc_beats_vertex_based_on_mixed_degrees() {
        let g = mixed_graph();
        let spec = GpuSpec::default_sim();
        let sim = Simulator::new(spec.clone(), CostModel::default());
        let active = vec![0u32, 1, 2];
        let twc = sim.simulate(&schedule(&active, &g, Direction::Push, &spec, 0), true);
        let vb = sim.simulate(
            &crate::lb::vertex::schedule(&active, &g, Direction::Push, 0),
            true,
        );
        assert!(twc.total_cycles < vb.total_cycles);
    }

    #[test]
    fn unbounded_large_bin_is_the_weakness() {
        // A mega-hub still lands in a single CTA: TWC's block imbalance.
        let mut el = EdgeList::new(100_001);
        for i in 0..100_000u32 {
            el.push(0, 1 + i, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let spec = GpuSpec::default_sim();
        let s = schedule(&[0], &g, Direction::Push, &spec, 1);
        let sim = Simulator::new(spec, CostModel::default());
        let r = sim.simulate(&s, true);
        assert!(r.kernels[0].imbalance_factor() > 20.0);
    }

    #[test]
    fn pull_direction_uses_in_degree() {
        let mut g = mixed_graph();
        g.build_csc();
        let spec = GpuSpec::default_sim();
        // vertex 0 has in-degree 1 (from v1's edges? no — check: edges go
        // 1 -> 20..84, 2 -> 90.., 0 -> 10..14; so in-degree of 10 is >= 1).
        let s = schedule(&[10], &g, Direction::Pull, &spec, 1);
        assert_eq!(s.twc[0].degree, g.in_degree(10));
    }
}
