//! The reproduction harness: regenerates every table and figure from the
//! paper's evaluation (§6) on the simulated testbed.
//!
//! Each `table*` / `fig*` function returns the rendered text (and data rows)
//! that `alb repro <exp>` prints and writes under `results/`. DESIGN.md §4
//! maps each experiment to the paper's and EXPERIMENTS.md records the
//! measured-vs-paper comparison.

use anyhow::Result;

use crate::apps::engine::{self, EngineConfig};
use crate::apps::App;
use crate::config::{Framework, TABLE2_FRAMEWORKS};
use crate::coordinator::{run_distributed, ClusterConfig};
use crate::gpu::GpuSpec;
use crate::graph::{inputs, props, CsrGraph};
use crate::lb::{Balancer, Distribution};
use crate::metrics::table::ms;
use crate::metrics::Table;
use crate::partition::Policy;

/// Shared experiment parameters.
#[derive(Debug, Clone)]
pub struct ReproConfig {
    /// Shifts every input preset's size exponent.
    pub scale_delta: i32,
    pub seed: u64,
    pub spec: GpuSpec,
}

impl Default for ReproConfig {
    fn default() -> Self {
        ReproConfig { scale_delta: 0, seed: 42, spec: GpuSpec::default_sim() }
    }
}

impl ReproConfig {
    /// Smaller inputs for quick checks / benches.
    pub fn quick() -> Self {
        ReproConfig { scale_delta: -3, ..ReproConfig::default() }
    }

    fn graph(&self, name: &str) -> CsrGraph {
        inputs::build(name, self.scale_delta, self.seed)
            .unwrap_or_else(|| panic!("unknown input {name}"))
    }

    fn engine_cfg(&self, fw: Framework) -> EngineConfig {
        fw.engine_config(self.spec.clone())
    }
}

fn source_for(name: &str, g: &CsrGraph) -> u32 {
    inputs::source_vertex(name, g)
}

/// Run one (input, app, framework) single-GPU cell; returns simulated ms.
pub fn run_cell(
    rc: &ReproConfig,
    input: &str,
    app: App,
    fw: Framework,
) -> Result<f64> {
    let mut g = rc.graph(input);
    let src = source_for(input, &g);
    let cfg = rc.engine_cfg(fw);
    let r = engine::run(app, &mut g, src, &cfg, None)?;
    Ok(r.ms(&rc.spec))
}

// ----------------------------------------------------------------- Table 1

/// Table 1: input properties.
pub fn table1(rc: &ReproConfig) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "paper", "|V|", "|E|", "E/V", "maxDout", "maxDin", "diam",
        "size(MB)",
    ]);
    for name in inputs::ALL_INPUTS {
        let mut g = rc.graph(name);
        let p = props::compute(&mut g);
        t.row(vec![
            name.to_string(),
            inputs::paper_name(name).to_string(),
            p.num_vertices.to_string(),
            p.num_edges.to_string(),
            format!("{:.0}", p.avg_degree),
            p.max_dout.to_string(),
            p.max_din.to_string(),
            p.approx_diameter.to_string(),
            format!("{:.1}", p.size_bytes as f64 / 1e6),
        ]);
    }
    Ok(t)
}

// ----------------------------------------------------------------- Figure 1

/// Per-block edge counts for chosen rounds of a run.
pub struct BlockProfile {
    pub label: String,
    /// (round, kernel label, per-block edges).
    pub rounds: Vec<(u32, String, Vec<u64>)>,
}

/// Record per-block distributions for `keep_rounds` rounds of (input, app)
/// under `balancer`.
pub fn block_profile(
    rc: &ReproConfig,
    input: &str,
    app: App,
    fw: Framework,
    keep_rounds: &[u32],
) -> Result<BlockProfile> {
    let mut g = rc.graph(input);
    let src = source_for(input, &g);
    let mut cfg = rc.engine_cfg(fw);
    cfg.record_blocks = true;
    let r = engine::run(app, &mut g, src, &cfg, None)?;
    let mut rounds = Vec::new();
    for rec in &r.rounds {
        if keep_rounds.contains(&rec.round) {
            if let Some(kernels) = &rec.kernels {
                for k in kernels {
                    rounds.push((rec.round, k.label.to_string(), k.block_edges.clone()));
                }
            }
        }
    }
    Ok(BlockProfile {
        label: format!("{}/{}/{}", input, app.name(), fw.name()),
        rounds,
    })
}

fn render_profile(p: &BlockProfile) -> String {
    let mut out = format!("== {} ==\n", p.label);
    for (round, kernel, edges) in &p.rounds {
        let total: u64 = edges.iter().sum();
        let max = edges.iter().max().copied().unwrap_or(0);
        let imb = crate::metrics::imbalance(edges);
        out.push_str(&format!(
            "round {round} kernel {kernel}: total {total} max-block {max} imbalance {:.2}\n  blocks: {:?}\n",
            imb.factor, edges
        ));
    }
    out
}

/// Figure 1: thread-block load imbalance under TWC across rounds, apps, and
/// inputs. Returns rendered text.
pub fn fig1(rc: &ReproConfig) -> Result<String> {
    let mut out = String::new();
    // (a) sssp on rmat20 (paper rmat25), rounds 0-2, D-IrGL (TWC).
    out.push_str(&render_profile(&block_profile(
        rc, "rmat20", App::Sssp, Framework::DIrglTwc, &[0, 1, 2],
    )?));
    // (b) bfs: road-s vs rmat18, round with the largest active set.
    out.push_str(&render_profile(&block_profile(
        rc, "road-s", App::Bfs, Framework::DIrglTwc, &[1, 2],
    )?));
    out.push_str(&render_profile(&block_profile(
        rc, "rmat18", App::Bfs, Framework::DIrglTwc, &[0, 1],
    )?));
    // (c) bfs (push) vs pr (pull) on rmat18.
    out.push_str(&render_profile(&block_profile(
        rc, "rmat18", App::Pr, Framework::DIrglTwc, &[0, 1],
    )?));
    Ok(out)
}

// ----------------------------------------------------------------- Table 2

/// Table 2: single-GPU execution time (simulated ms) for the four
/// frameworks across single-host inputs and all five apps.
pub fn table2(rc: &ReproConfig) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "gunrock(twc)", "gunrock(lb)", "d-irgl(twc)",
        "d-irgl(alb)", "alb-speedup",
    ]);
    for input in inputs::SINGLE_HOST_INPUTS {
        for app in crate::apps::ALL_APPS {
            // The paper omits Gunrock pr/kcore (unsupported/incorrect).
            let mut cells = Vec::new();
            for fw in TABLE2_FRAMEWORKS {
                let skip_gunrock = matches!(
                    fw,
                    Framework::GunrockTwc | Framework::GunrockLb
                ) && matches!(app, App::Pr | App::Kcore);
                if skip_gunrock {
                    cells.push("-".to_string());
                } else {
                    cells.push(ms(run_cell(rc, input, app, fw)?));
                }
            }
            let twc: f64 = cells[2].parse().unwrap_or(f64::NAN);
            let alb: f64 = cells[3].parse().unwrap_or(f64::NAN);
            let speedup = if alb > 0.0 { twc / alb } else { f64::NAN };
            let mut row = vec![input.to_string(), app.name().to_string()];
            row.extend(cells);
            row.push(format!("{speedup:.2}x"));
            t.row(row);
        }
    }
    Ok(t)
}

// ----------------------------------------------------------------- Figure 5

/// Figure 5: per-block load distribution, D-IrGL (TWC) vs D-IrGL (ALB), for
/// the paper's four configurations.
pub fn fig5(rc: &ReproConfig) -> Result<String> {
    let mut out = String::new();
    let configs: [(&str, App, &[u32]); 4] = [
        ("rmat18", App::Bfs, &[0]),   // 5a/5b
        ("rmat18", App::Sssp, &[1]),  // 5c/5d
        ("road-s", App::Cc, &[1]),    // 5e/5f
        ("rmat18", App::Pr, &[0]),    // 5g/5h
    ];
    for (input, app, rounds) in configs {
        for fw in [Framework::DIrglTwc, Framework::DIrglAlb] {
            out.push_str(&render_profile(&block_profile(rc, input, app, fw, rounds)?));
        }
    }
    Ok(out)
}

// ------------------------------------------------------- Figures 6, 7, 8, 9

/// One multi-GPU cell.
pub fn run_dist_cell(
    rc: &ReproConfig,
    input: &str,
    app: App,
    fw: Framework,
    cluster: &ClusterConfig,
) -> Result<crate::coordinator::DistRunResult> {
    let g = rc.graph(input);
    let src = source_for(input, &g);
    let cfg = rc.engine_cfg(fw);
    run_distributed(app, &g, src, &cfg, cluster, None)
}

/// Figure 6: execution time on 1-6 GPUs (Momentum-like), four frameworks.
pub fn fig6(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "framework", "1", "2", "3", "4", "5", "6",
    ]);
    for input in ["rmat18", "rmat20"] {
        for &app in apps {
            for fw in TABLE2_FRAMEWORKS {
                if matches!(fw, Framework::GunrockTwc | Framework::GunrockLb)
                    && matches!(app, App::Pr | App::Kcore)
                {
                    continue;
                }
                let mut row = vec![
                    input.to_string(),
                    app.name().to_string(),
                    fw.name().to_string(),
                ];
                for k in 1..=6u32 {
                    let r = run_dist_cell(
                        rc, input, app, fw, &ClusterConfig::single_host(k),
                    )?;
                    row.push(ms(r.ms(&rc.spec)));
                }
                t.row(row);
            }
        }
    }
    Ok(t)
}

/// Figure 7: computation / communication breakdown on 6 GPUs.
pub fn fig7(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    breakdown(rc, apps, &["rmat18", "rmat20"], &ClusterConfig::single_host(6))
}

/// Figure 11: breakdown on 16 GPUs of the Bridges-like cluster.
pub fn fig11(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    breakdown(
        rc,
        apps,
        &["rmat21", "rmat22", "twitter-s", "uk-s"],
        &ClusterConfig::bridges(16),
    )
}

fn breakdown(
    rc: &ReproConfig,
    apps: &[App],
    ins: &[&str],
    cluster: &ClusterConfig,
) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "framework", "comp(ms)", "comm(ms)", "total(ms)",
    ]);
    for input in ins {
        for &app in apps {
            for fw in [Framework::DIrglTwc, Framework::DIrglAlb] {
                let r = run_dist_cell(rc, input, app, fw, cluster)?;
                t.row(vec![
                    input.to_string(),
                    app.name().to_string(),
                    fw.name().to_string(),
                    ms(r.comp_ms(&rc.spec)),
                    ms(r.comm_ms(&rc.spec)),
                    ms(r.ms(&rc.spec)),
                ]);
            }
        }
    }
    Ok(t)
}

/// Figure 8: ALB with cyclic vs blocked distribution (1 and 4 GPUs).
pub fn fig8(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "gpus", "cyclic(ms)", "blocked(ms)", "cyclic-speedup",
    ]);
    for input in ["rmat18", "rmat20"] {
        for &app in apps {
            for k in [1u32, 4] {
                let cell = |d: Distribution| -> Result<f64> {
                    let g = rc.graph(input);
                    let src = source_for(input, &g);
                    let mut cfg = rc.engine_cfg(Framework::DIrglAlb);
                    cfg.balancer = Balancer::Alb { distribution: d, threshold: None };
                    let r = run_distributed(
                        app, &g, src, &cfg, &ClusterConfig::single_host(k), None,
                    )?;
                    Ok(r.ms(&rc.spec))
                };
                let cyc = cell(Distribution::Cyclic)?;
                let blk = cell(Distribution::Blocked)?;
                t.row(vec![
                    input.to_string(),
                    app.name().to_string(),
                    k.to_string(),
                    ms(cyc),
                    ms(blk),
                    format!("{:.2}x", blk / cyc),
                ]);
            }
        }
    }
    Ok(t)
}

/// Figure 9: IEC vs OEC partitioning under TWC and ALB (4 GPUs).
pub fn fig9(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "policy", "twc(ms)", "alb(ms)", "alb-speedup",
    ]);
    for input in ["rmat18", "rmat20"] {
        for &app in apps {
            for policy in [Policy::Iec, Policy::Oec] {
                let cluster = ClusterConfig {
                    policy,
                    ..ClusterConfig::single_host(4)
                };
                let twc = run_dist_cell(rc, input, app, Framework::DIrglTwc, &cluster)?
                    .ms(&rc.spec);
                let alb = run_dist_cell(rc, input, app, Framework::DIrglAlb, &cluster)?
                    .ms(&rc.spec);
                t.row(vec![
                    input.to_string(),
                    app.name().to_string(),
                    policy.name().to_string(),
                    ms(twc),
                    ms(alb),
                    format!("{:.2}x", twc / alb),
                ]);
            }
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------- Figure 10

/// Figure 10: 2-16 GPUs on the Bridges-like cluster; D-IrGL (TWC/ALB) and
/// Lux (cc and pr only, as in the paper).
pub fn fig10(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let mut t = Table::new(&[
        "input", "app", "framework", "2", "4", "8", "16",
    ]);
    for input in inputs::MULTI_HOST_INPUTS {
        for &app in apps {
            for fw in [Framework::DIrglTwc, Framework::DIrglAlb, Framework::Lux] {
                // Paper runs Lux only for cc and pr.
                if fw == Framework::Lux && !matches!(app, App::Cc | App::Pr) {
                    continue;
                }
                let mut row = vec![
                    input.to_string(),
                    app.name().to_string(),
                    fw.name().to_string(),
                ];
                for k in [2u32, 4, 8, 16] {
                    let r = run_dist_cell(rc, input, app, fw, &ClusterConfig::bridges(k))?;
                    row.push(ms(r.ms(&rc.spec)));
                }
                t.row(row);
            }
        }
    }
    Ok(t)
}

// ----------------------------------------------------------- Ablation §4.2

/// Threshold ablation (paper §4.2): sweep the huge-bin degree threshold
/// from 0 (everything through the LB kernel, max balance, max search
/// overhead) past the launched-thread count (the paper's sweet spot) to
/// effectively-infinite (plain TWC). The paper argues the sweet spot sits
/// at THRESHOLD = launched threads; this regenerates that analysis.
pub fn ablation_threshold(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let p = rc.spec.total_threads();
    let thresholds: Vec<(String, u64)> = vec![
        ("0".into(), 0),
        ("p/16".into(), p / 16),
        ("p/4".into(), p / 4),
        ("p (paper)".into(), p),
        ("4p".into(), 4 * p),
        ("16p".into(), 16 * p),
        ("inf (twc)".into(), u64::MAX),
    ];
    let mut t = Table::new(&["input", "app", "threshold", "ms", "lb-rounds"]);
    for input in ["rmat18", "rmat20"] {
        for &app in apps {
            for (label, th) in &thresholds {
                let mut g = rc.graph(input);
                let src = source_for(input, &g);
                let mut cfg = rc.engine_cfg(Framework::DIrglAlb);
                cfg.balancer = Balancer::Alb {
                    distribution: Distribution::Cyclic,
                    threshold: Some(*th),
                };
                let r = engine::run(app, &mut g, src, &cfg, None)?;
                t.row(vec![
                    input.to_string(),
                    app.name().to_string(),
                    label.clone(),
                    ms(r.ms(&rc.spec)),
                    r.rounds_with_lb().to_string(),
                ]);
            }
        }
    }
    Ok(t)
}

/// GPU-spec ablation: the ALB-vs-TWC comparison across hardware presets.
/// THRESHOLD tracks each spec's launched-thread count, so the adaptive
/// behaviour must be preserved on every GPU — including the paper-faithful
/// K80 preset with its 26,624 threads.
pub fn ablation_gpu(rc: &ReproConfig, apps: &[App]) -> Result<Table> {
    let mut t = Table::new(&[
        "gpu", "threads", "app", "twc(ms)", "alb(ms)", "speedup",
    ]);
    for spec in [
        GpuSpec::default_sim(),
        GpuSpec::k80_like(),
        GpuSpec::gtx1080_like(),
        GpuSpec::p100_like(),
    ] {
        for &app in apps {
            let rc2 = ReproConfig { spec: spec.clone(), ..rc.clone() };
            let twc = run_cell(&rc2, "rmat20", app, Framework::DIrglTwc)?;
            let alb = run_cell(&rc2, "rmat20", app, Framework::DIrglAlb)?;
            t.row(vec![
                spec.name.clone(),
                spec.total_threads().to_string(),
                app.name().to_string(),
                ms(twc),
                ms(alb),
                format!("{:.2}x", twc / alb),
            ]);
        }
    }
    Ok(t)
}

// ------------------------------------------- campaign golden expectations

/// Repository path of the committed smoke-campaign golden (see
/// DESIGN.md §11 for the seeding story).
pub const CAMPAIGN_GOLDEN: &str = "CAMPAIGN.golden.json";

/// Whole-matrix golden expectations for campaign results — the structural
/// invariants that hold on *any* machine, so they are armed from day one
/// even before `CAMPAIGN.golden.json` is seeded with exact hashes:
///
/// 1. **Balancer independence** (the paper's correctness baseline, §3):
///    cells that differ only in the balancer produce identical labels, so
///    their labels-hashes must be equal.
/// 2. **Scale-out label consistency**: bfs (and its direction-optimizing
///    variant), delta-stepping sssp, and k-core converge to a unique
///    fixpoint, so every cell of the same (family, input) — across GPU
///    counts, policies, and balancers — shares one hash. PageRank is
///    excluded: its float summation order legitimately depends on the
///    partition layout (DESIGN.md §10), so only invariant 1 applies to it.
/// 3. **Adaptive dominance on skewed inputs**: on the
///    [`inputs::HIGH_IMBALANCE_INPUTS`] presets (the regime the controller
///    targets), an `adaptive` cell must not spend more cycles than any
///    static strategy of the same (app, input, policy, gpus). Balanced
///    inputs are exempt here — the strict all-inputs form is the opt-in
///    [`check_adaptive_dominance`] behind `alb sweep --check-adaptive`.
pub fn check_campaign_invariants(
    cells: &[crate::campaign::CellResult],
) -> Result<(), String> {
    use std::collections::HashMap;

    // 1. Same (app, input, policy, gpus), different balancer => same hash.
    let mut by_cfg: HashMap<(&str, &str, &str, u32), (&str, &str)> = HashMap::new();
    for c in cells {
        let key = (c.app.as_str(), c.input.as_str(), c.policy.as_str(), c.gpus);
        match by_cfg.get(&key) {
            None => {
                by_cfg.insert(key, (c.labels_hash.as_str(), c.id.as_str()));
            }
            Some((hash, first_id)) if *hash != c.labels_hash => {
                return Err(format!(
                    "balancer-independence violated: {} hashed {} but {} hashed \
                     {} — balancers must converge to identical labels",
                    first_id, hash, c.id, c.labels_hash
                ));
            }
            Some(_) => {}
        }
    }

    // 2. Unique-fixpoint families agree across balancers, policies, GPUs.
    let family = |app: &str| -> Option<&'static str> {
        match app {
            "bfs" | "bfs-dopt" => Some("bfs"),
            "sssp-delta" => Some("sssp"),
            "kcore" => Some("kcore"),
            _ => None, // pr: partition-dependent float summation order
        }
    };
    let mut by_family: HashMap<(&'static str, &str), (&str, &str)> = HashMap::new();
    for c in cells {
        let Some(fam) = family(&c.app) else { continue };
        let key = (fam, c.input.as_str());
        match by_family.get(&key) {
            None => {
                by_family.insert(key, (c.labels_hash.as_str(), c.id.as_str()));
            }
            Some((hash, first_id)) if *hash != c.labels_hash => {
                return Err(format!(
                    "scale-out label consistency violated for {fam} on {}: {} \
                     hashed {} but {} hashed {}",
                    c.input, first_id, hash, c.id, c.labels_hash
                ));
            }
            Some(_) => {}
        }
    }

    // 3. Adaptive beats (or ties) every static strategy on skewed inputs.
    let violations = adaptive_dominance_violations(cells, |input| {
        inputs::HIGH_IMBALANCE_INPUTS.contains(&input)
    });
    if let Some(v) = violations.first() {
        return Err(format!(
            "adaptive-dominance violated on a high-imbalance input ({} group{}):\n{}",
            violations.len(),
            if violations.len() == 1 { "" } else { "s" },
            v
        ));
    }
    Ok(())
}

/// The cycle comparisons behind the adaptive-beats-static gate: for every
/// (app, input, policy, gpus) group that ran both an `adaptive` cell and at
/// least one static strategy, adaptive's `total_cycles` must be <= each
/// static cell's. Returns one formatted line per losing comparison, sorted
/// for deterministic output. `input_filter` scopes which inputs count.
fn adaptive_dominance_violations(
    cells: &[crate::campaign::CellResult],
    input_filter: impl Fn(&str) -> bool,
) -> Vec<String> {
    use std::collections::HashMap;
    let mut adaptive: HashMap<(&str, &str, &str, u32), (&str, u64)> = HashMap::new();
    for c in cells {
        if c.balancer == "adaptive" && input_filter(&c.input) {
            let key = (c.app.as_str(), c.input.as_str(), c.policy.as_str(), c.gpus);
            adaptive.insert(key, (c.id.as_str(), c.total_cycles));
        }
    }
    let mut out = Vec::new();
    for c in cells {
        // `auto` is excluded from the static side: it may itself resolve
        // to the adaptive controller.
        if c.balancer == "adaptive" || c.balancer == "auto" {
            continue;
        }
        let key = (c.app.as_str(), c.input.as_str(), c.policy.as_str(), c.gpus);
        if let Some(&(aid, acycles)) = adaptive.get(&key) {
            if acycles > c.total_cycles {
                out.push(format!(
                    "  {aid}: {acycles} cycles, loses to {} at {} cycles",
                    c.id, c.total_cycles
                ));
            }
        }
    }
    out.sort();
    out
}

/// The strict, all-inputs form of campaign invariant 3, behind `alb sweep
/// --check-adaptive` and CI's `adaptive-gate` job: adaptive must not lose
/// to *any* static strategy in *any* (app, input, policy, gpus) group the
/// sweep ran — the sweep's input filter is the scoping mechanism.
pub fn check_adaptive_dominance(
    cells: &[crate::campaign::CellResult],
) -> Result<(), String> {
    let violations = adaptive_dominance_violations(cells, |_| true);
    if violations.is_empty() {
        return Ok(());
    }
    Err(format!(
        "ADAPTIVE GATE FAILED ({} comparison{} lost):\n{}\n\
         The runtime controller must never cost cycles against the static \
         strategies it starts from; a regression here means a controller-law \
         change made some round's re-balancing unprofitable.",
        violations.len(),
        if violations.len() == 1 { "" } else { "s" },
        violations.join("\n")
    ))
}

/// The fault-recovery gate behind `alb sweep --check-faults` and CI's
/// `chaos-gate` job (DESIGN.md §14): every fault-injected cell must have
///
/// 1. a fault-free twin in the same sweep (same app/input/balancer/policy/
///    gpus, `fault = "none"`) — the gate refuses to run unarmed;
/// 2. a `labels_hash` bit-identical to that twin's (recovery restores the
///    exact fixpoint, not an approximation);
/// 3. `converged = true` (a recovery that burns the round budget is a
///    failure, not a pass); and
/// 4. a retry count within the per-exchange budget summed over its rounds.
pub fn check_fault_recovery(
    cells: &[crate::campaign::CellResult],
) -> Result<(), String> {
    use std::collections::HashMap;
    let budget = crate::comm::fault::MAX_EXCHANGE_ATTEMPTS as u64;

    let mut fault_free: HashMap<(&str, &str, &str, &str, u32), &crate::campaign::CellResult> =
        HashMap::new();
    for c in cells {
        if c.fault == "none" {
            let key =
                (c.app.as_str(), c.input.as_str(), c.balancer.as_str(), c.policy.as_str(), c.gpus);
            fault_free.insert(key, c);
        }
    }

    let mut checked = 0usize;
    let mut failures = Vec::new();
    for c in cells {
        if c.fault == "none" {
            continue;
        }
        let key =
            (c.app.as_str(), c.input.as_str(), c.balancer.as_str(), c.policy.as_str(), c.gpus);
        let Some(twin) = fault_free.get(&key) else {
            failures.push(format!(
                "  {}: no fault-free twin in this sweep — include \"none\" in --faults",
                c.id
            ));
            continue;
        };
        checked += 1;
        if c.labels_hash != twin.labels_hash {
            failures.push(format!(
                "  {}: recovered labels hashed {} but fault-free twin {} hashed {}",
                c.id, c.labels_hash, twin.id, twin.labels_hash
            ));
        }
        if !c.converged {
            failures.push(format!("  {}: did not converge after recovery", c.id));
        }
        if c.retry_count > budget * c.rounds.max(1) {
            failures.push(format!(
                "  {}: {} exchange retries exceeds the budget of {} per round over {} rounds",
                c.id, c.retry_count, budget, c.rounds
            ));
        }
    }
    if !failures.is_empty() {
        return Err(format!(
            "FAULT GATE FAILED ({} problem{}):\n{}\n\
             Recovery must restore the exact fault-free fixpoint: a hash \
             mismatch means replay-from-checkpoint or survivor re-partitioning \
             diverged from the clean run (DESIGN.md §14).",
            failures.len(),
            if failures.len() == 1 { "" } else { "s" },
            failures.join("\n")
        ));
    }
    if checked == 0 {
        return Err(
            "UNARMED FAULT GATE: the sweep ran no fault-injected cells, so \
             --check-faults cannot verify anything. Pass --faults with at \
             least one non-\"none\" preset (e.g. --faults none,gpu-death,chaos)."
                .to_string(),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ReproConfig {
        ReproConfig { scale_delta: -6, seed: 7, ..ReproConfig::default() }
    }

    #[test]
    fn table1_has_all_inputs() {
        let t = table1(&quick()).unwrap();
        assert_eq!(t.num_rows(), 8);
    }

    #[test]
    fn table2_shape_and_speedups() {
        let rc = quick();
        let t = table2(&rc).unwrap();
        assert_eq!(t.num_rows(), 4 * 5);
        let rendered = t.render();
        assert!(rendered.contains("rmat18"));
        assert!(rendered.contains("kcore"));
    }

    #[test]
    fn fig1_reports_imbalance() {
        let out = fig1(&quick()).unwrap();
        assert!(out.contains("sssp"));
        assert!(out.contains("imbalance"));
    }

    #[test]
    fn fig5_contains_both_frameworks() {
        let out = fig5(&quick()).unwrap();
        assert!(out.contains("d-irgl(twc)"));
        assert!(out.contains("d-irgl(alb)"));
    }

    #[test]
    fn fig8_cyclic_wins_overall() {
        let rc = quick();
        let t = fig8(&rc, &[App::Bfs]).unwrap();
        assert_eq!(t.num_rows(), 4);
    }

    #[test]
    fn run_cell_smoke() {
        let rc = quick();
        let ms = run_cell(&rc, "rmat18", App::Bfs, Framework::DIrglAlb).unwrap();
        assert!(ms > 0.0);
    }

    #[test]
    fn fault_gate_verdicts() {
        use crate::campaign::CellResult;
        let cell = |fault: &str, hash: &str, converged: bool| CellResult {
            id: if fault == "none" {
                "bfs/rmat18/twc/cvc/4".into()
            } else {
                format!("bfs/rmat18/twc/cvc/4/{fault}")
            },
            app: "bfs".into(),
            input: "rmat18".into(),
            balancer: "twc".into(),
            policy: "cvc".into(),
            gpus: 4,
            labels_hash: hash.into(),
            rounds: 10,
            fault: fault.to_string(),
            converged,
            ..CellResult::default()
        };

        // Armed and matching: passes.
        let ok = vec![cell("none", "aaaa", true), cell("chaos", "aaaa", true)];
        check_fault_recovery(&ok).unwrap();

        // Hash divergence names both cells.
        let bad = vec![cell("none", "aaaa", true), cell("chaos", "bbbb", true)];
        let e = check_fault_recovery(&bad).unwrap_err();
        assert!(e.contains("FAULT GATE FAILED"), "{e}");
        assert!(e.contains("bfs/rmat18/twc/cvc/4/chaos"), "{e}");

        // Non-convergence after recovery fails.
        let stuck = vec![cell("none", "aaaa", true), cell("chaos", "aaaa", false)];
        assert!(check_fault_recovery(&stuck).unwrap_err().contains("converge"));

        // Missing twin fails loudly.
        let orphan = vec![cell("gpu-death", "aaaa", true)];
        assert!(check_fault_recovery(&orphan).unwrap_err().contains("twin"));

        // A fault-free-only sweep must not silently pass the gate.
        let unarmed = vec![cell("none", "aaaa", true)];
        assert!(check_fault_recovery(&unarmed).unwrap_err().contains("UNARMED"));

        // Retry counts beyond the per-round budget fail.
        let mut retries = vec![cell("none", "aaaa", true), cell("drop", "aaaa", true)];
        retries[1].retry_count =
            crate::comm::fault::MAX_EXCHANGE_ATTEMPTS as u64 * 10 + 1;
        assert!(check_fault_recovery(&retries).unwrap_err().contains("budget"));
    }
}
