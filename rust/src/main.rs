//! `alb` — the launcher for the ALB graph-analytics framework.
//!
//! Subcommands:
//!
//! ```text
//! alb props  [--input <name>] [--scale-delta D] [--seed S]
//! alb gen    --input <name> --out <file.albg> [--scale-delta D] [--seed S]
//! alb run    --app <bfs|sssp|cc|pr|kcore> --input <name|file.albg>
//!            [--framework <dirgl-twc|dirgl-alb|gunrock-twc|gunrock-lb|lux>]
//!            [--gpus K] [--policy <oec|iec|cvc>] [--engine <native|pjrt>]
//!            [--exec <parallel|sequential>] [--sim-threads N]
//!            [--gpu-spec <sim-default|k80-like|gtx1080-like|p100-like>]
//!            [--distribution <cyclic|blocked>] [--threshold T]
//!            [--balancer <vertex|twc|edge-lb|alb|enterprise|adaptive|auto>]
//!            [--direction-opt true] [--delta W] [--kcore-k K]
//!            [--reorder <none|degree|rcm>] [--graph-cache DIR]
//!            [--faults <none|gpu-death|corrupt|drop|slow|chaos|spec,...>]
//!            [--checkpoint-every K] [--checkpoint-dir DIR]
//!            [--max-rounds N] [--scale-delta D] [--seed S] [--json <out.json>]
//! alb repro  <table1|fig1|table2|fig5|fig6|fig7|fig8|fig9|fig10|fig11|all>
//!            [--out results] [--scale-delta D] [--quick]
//! alb sweep  [--smoke] [--list] [--apps a,b] [--inputs x,y]
//!            [--balancers b1,b2] [--policies p1,p2] [--gpus 1,4,8]
//!            [--faults f1,f2] [--scale-delta D] [--seed S] [--delta W]
//!            [--sim-threads N] [--exec <parallel|sequential>]
//!            [--out CAMPAIGN.json] [--resume true|false]
//!            [--check-golden CAMPAIGN.golden.json] [--check-adaptive]
//!            [--check-faults] [--graph-cache DIR]
//! alb serve  --graph <name|file.albg> [--port N] [--max-inflight K]
//!            [--cache-entries N] [--max-rounds N] [--balancer B]
//!            [--framework F] [--gpu-spec S] [--sim-threads N]
//!            [--scale-delta D] [--seed S] [--graph-cache DIR]
//! alb lint   [--root DIR] [--format <text|json>] [--out report.json]
//! ```
//!
//! Argument parsing is hand-rolled on std (the offline vendored crate set
//! has no clap); see `Args`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use alb_graph::apps::engine::{ComputeMode, EngineConfig};
use alb_graph::apps::App;
use alb_graph::comm::fault::{FaultPlan, FAULTS_USAGE};
use alb_graph::config::Framework;
use alb_graph::coordinator::{ExecMode, FaultConfig};
use alb_graph::gpu::GpuSpec;
use alb_graph::graph::reorder::{self, Reorder};
use alb_graph::graph::{disk, inputs, io, props, CsrGraph};
use alb_graph::lb::{adaptive, Balancer, Distribution};
use alb_graph::metrics::{Json, Table};
use alb_graph::partition::Policy;
use alb_graph::repro::{self, ReproConfig};
use alb_graph::runtime::PjrtRuntime;
use alb_graph::serve::{ServeOpts, Server};
use alb_graph::session::{ClusterRequest, RunRequest, Session, SCHEMA_VERSION};

/// Tiny std-only flag parser: `--key value` pairs plus positionals.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // Value-less boolean flags.
                if matches!(key, "quick" | "smoke" | "list" | "check-adaptive" | "check-faults") {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                    continue;
                }
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_i32(&self, key: &str, default: i32) -> Result<i32> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }
}

fn load_graph(input: &str, scale_delta: i32, seed: u64) -> Result<CsrGraph> {
    if input.ends_with(".albg") {
        return io::load(Path::new(input)).with_context(|| format!("load {input}"));
    }
    inputs::build(input, scale_delta, seed).ok_or_else(|| {
        anyhow!(
            "unknown input preset {input} (and not a .albg file); valid presets: {}",
            inputs::preset_names()
        )
    })
}

fn cmd_props(args: &Args) -> Result<()> {
    let delta = args.get_i32("scale-delta", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let names: Vec<&str> = match args.get("input") {
        Some(one) => vec![one],
        None => inputs::ALL_INPUTS.to_vec(),
    };
    let mut t = Table::new(&[
        "input", "paper", "|V|", "|E|", "E/V", "maxDout", "maxDin", "diam",
        "size(MB)",
    ]);
    for name in names {
        let mut g = load_graph(name, delta, seed)?;
        let p = props::compute(&mut g);
        t.row(vec![
            name.to_string(),
            inputs::paper_name(name).to_string(),
            p.num_vertices.to_string(),
            p.num_edges.to_string(),
            format!("{:.0}", p.avg_degree),
            p.max_dout.to_string(),
            p.max_din.to_string(),
            p.approx_diameter.to_string(),
            format!("{:.1}", p.size_bytes as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let input = args.get("input").ok_or_else(|| anyhow!("--input required"))?;
    let out = args.get("out").ok_or_else(|| anyhow!("--out required"))?;
    let delta = args.get_i32("scale-delta", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let g = load_graph(input, delta, seed)?;
    io::save(&g, Path::new(out))?;
    println!(
        "wrote {out}: {} vertices, {} edges",
        g.num_vertices(),
        g.num_edges()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let app_name = args.get("app").ok_or_else(|| anyhow!("--app required"))?;
    let app = App::parse(app_name).ok_or_else(|| {
        anyhow!("unknown --app {app_name}; valid values: {}", alb_graph::apps::APP_NAMES)
    })?;
    let input = args.get("input").ok_or_else(|| anyhow!("--input required"))?;
    let delta = args.get_i32("scale-delta", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let spec_name = args.get_or("gpu-spec", "sim-default");
    let spec = GpuSpec::by_name(&spec_name).ok_or_else(|| {
        anyhow!("unknown --gpu-spec {spec_name}; valid values: {}", GpuSpec::NAMES)
    })?;
    let fw_name = args.get_or("framework", "dirgl-alb");
    let fw = Framework::parse(&fw_name).ok_or_else(|| {
        anyhow!("unknown --framework {fw_name}; valid values: {}", Framework::NAMES)
    })?;
    let gpus = args.get_u64("gpus", 1)? as u32;
    let policy_name = args.get_or("policy", "cvc");
    let policy = Policy::parse(&policy_name).ok_or_else(|| {
        anyhow!(
            "unknown --policy {policy_name}; valid values: {}",
            alb_graph::partition::POLICY_NAMES
        )
    })?;
    let gpus_per_host = args.get_u64("gpus-per-host", u32::MAX as u64)? as u32;
    let exec = ExecMode::parse_or_usage(&args.get_or("exec", "parallel"))
        .map_err(|e| anyhow!(e))?;
    // Intra-GPU simulation pool width (DESIGN.md §9): default = available
    // parallelism (or ALB_SIM_THREADS), 1 = the sequential reference walk,
    // 0 / garbage = a loud error naming the valid range.
    let sim_threads =
        alb_graph::exec::parse_threads(args.get("sim-threads")).map_err(|e| anyhow!(e))?;

    // Everything below layers onto the framework defaults through the
    // `EngineConfig` builders — the same surface `Session::effective_config`
    // uses, so a CLI run and a serve query derive their configs identically.
    let mut cfg: EngineConfig =
        fw.engine_config(spec.clone()).with_sim_threads(sim_threads);
    // --balancer first, so --distribution / --threshold below refine the
    // chosen strategy rather than the framework default it replaces.
    if let Some(b) = args.get("balancer") {
        cfg = cfg.with_balancer(Balancer::parse(b).ok_or_else(|| {
            anyhow!(
                "unknown --balancer {b}; valid values: {}",
                alb_graph::lb::BALANCER_NAMES.join(", ")
            )
        })?);
    }
    // `auto` is a meta-strategy: resolve it here, where app and input are
    // both known, exactly as the campaign runner does per cell.
    if matches!(cfg.balancer, Balancer::Auto) {
        let resolved = adaptive::auto_balancer(app.name(), input);
        eprintln!("auto: resolved to {}", resolved.name());
        cfg = cfg.with_balancer(resolved);
    }
    if let Some(d) = args.get("distribution") {
        let dist = match d {
            "cyclic" => Distribution::Cyclic,
            "blocked" => Distribution::Blocked,
            _ => bail!("--distribution cyclic|blocked"),
        };
        cfg = cfg.with_balancer(match cfg.balancer.clone() {
            Balancer::Alb { threshold, .. } => {
                Balancer::Alb { distribution: dist, threshold }
            }
            Balancer::Adaptive { threshold, .. } => {
                Balancer::Adaptive { distribution: dist, threshold }
            }
            Balancer::EdgeLb { .. } => Balancer::EdgeLb { distribution: dist },
            other => other,
        });
    }
    if let Some(t) = args.get("threshold") {
        let th: u64 = t.parse()?;
        cfg = cfg.with_balancer(match cfg.balancer.clone() {
            Balancer::Alb { distribution, .. } => {
                Balancer::Alb { distribution, threshold: Some(th) }
            }
            Balancer::Adaptive { distribution, .. } => {
                Balancer::Adaptive { distribution, threshold: Some(th) }
            }
            other => other,
        });
    }
    if let Some(k) = args.get("kcore-k") {
        cfg = cfg.with_kcore_k(k.parse()?);
    }
    if args.get("direction-opt").map(|v| v == "true" || v == "1") == Some(true) {
        cfg = cfg.with_direction_opt(true);
    }
    if let Some(d) = args.get("delta") {
        cfg = cfg.with_sssp_delta(Some(d.parse()?));
    }
    if let Some(m) = args.get("max-rounds") {
        match m.parse::<u32>() {
            Ok(n) if n >= 1 => cfg = cfg.with_max_rounds(n),
            _ => bail!("bad --max-rounds {m}; valid values: 1..=4294967295"),
        }
    }

    let pjrt_runtime;
    let pjrt = match args.get_or("engine", "native").as_str() {
        "native" => None,
        "pjrt" => {
            cfg = cfg.with_compute(ComputeMode::Pjrt);
            pjrt_runtime = PjrtRuntime::load_default()?;
            eprintln!(
                "pjrt: {} kernels on {}",
                pjrt_runtime.num_kernels(),
                pjrt_runtime.platform()
            );
            Some(&pjrt_runtime)
        }
        other => bail!("--engine native|pjrt (got {other})"),
    };

    let reorder_kind = match args.get("reorder") {
        Some(r) => Reorder::parse(r).ok_or_else(|| {
            anyhow!(
                "unknown --reorder {r}; valid values: {}",
                reorder::REORDER_NAMES.join(", ")
            )
        })?,
        None => Reorder::None,
    };

    // Fault injection / checkpointing (DESIGN.md §14). Any of these flags
    // routes the distributed run through the fault-tolerant driver; all are
    // rejected on a single GPU, where there is no exchange to fault and no
    // survivor to re-partition onto.
    let fault_cfg = {
        let plan = match args.get("faults") {
            Some(spec) => Some(FaultPlan::parse(spec, gpus, seed).map_err(|e| anyhow!(e))?),
            None => None,
        };
        let every = match args.get("checkpoint-every") {
            Some(v) => Some(v.parse::<u64>().map_err(|_| {
                anyhow!(
                    "bad --checkpoint-every {v}; valid values: a round interval >= 1, \
                     or 0 for the initial checkpoint only"
                )
            })?),
            None => None,
        };
        let dir = args.get("checkpoint-dir").map(PathBuf::from);
        if plan.is_none() && every.is_none() && dir.is_none() {
            None
        } else {
            Some(FaultConfig {
                plan: plan.unwrap_or_else(FaultPlan::none),
                checkpoint_every: every.unwrap_or(0),
                checkpoint_dir: dir,
            })
        }
    };
    if fault_cfg.is_some() && gpus <= 1 {
        bail!(
            "--faults/--checkpoint-every/--checkpoint-dir require --gpus > 1; \
             the fault model covers the distributed exchange (valid --faults: {FAULTS_USAGE})"
        );
    }

    let (mut g, cache_outcome) = match args.get("graph-cache") {
        Some(dir) if !input.ends_with(".albg") => {
            disk::GraphCache::new(Path::new(dir))?.load_or_build(input, delta, seed)?
        }
        Some(_) => bail!("--graph-cache applies to named input presets, not .albg files"),
        None => (load_graph(input, delta, seed)?, disk::CacheOutcome::Miss),
    };
    // Source selection always runs on original ids; reordering then renames
    // it through the permutation so the run is the same traversal
    // (DESIGN.md §13).
    let mut src = inputs::source_vertex(input, &g);
    if reorder_kind != Reorder::None {
        let (renamed, perm) = reorder::reorder(&g, reorder_kind);
        g = renamed;
        src = perm.to_new(src);
    }
    // Host-side wall clock for the progress report only — an allowlisted
    // D001 site; never feeds deterministic outputs.
    #[allow(clippy::disallowed_methods)]
    let started = std::time::Instant::now();

    let mut report = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("app", app.name())
        .set("input", input)
        .set("framework", fw.name())
        .set("gpu_spec", spec.name.as_str())
        .set("gpus", gpus)
        .set("graph_cache_hit", cache_outcome.name())
        .set("reorder", reorder_kind.name())
        .set("seed", seed)
        .set("sim_threads", cfg.sim_threads);

    // Single- and multi-GPU runs both execute through the Session API —
    // the exact code path an `alb serve` query takes, which is what makes
    // the serve parity gate (labels_hash equality across transports) a
    // meaningful check rather than a coincidence of two implementations.
    // The PJRT client is not Sync: the coordinator runs partitions
    // sequentially whenever a runtime is attached, whatever --exec says.
    let effective_exec = if pjrt.is_some() { ExecMode::Sequential } else { exec };
    let session = Session::new(g, input, cfg.clone());
    let req = RunRequest {
        source: Some(src),
        cluster: (gpus > 1).then(|| ClusterRequest {
            gpus,
            policy,
            gpus_per_host: (gpus_per_host != u32::MAX).then_some(gpus_per_host),
            exec: effective_exec,
        }),
        fault: fault_cfg,
        ..RunRequest::new(app)
    };
    let r = session.run(&req, pjrt)?;
    report = report
        .set("labels_hash", r.labels_hash.as_str())
        .set("source", r.source)
        .set("simulated_ms", r.simulated_ms)
        .set("rounds", r.rounds)
        .set("converged", r.converged);

    match &r.dist {
        None => {
            println!(
                "{} on {} [{}]: {:.1} simulated ms, {} rounds, {} edges, LB in {} rounds ({} host ms)",
                app.name(),
                input,
                fw.name(),
                r.simulated_ms,
                r.rounds,
                r.total_edges,
                r.lb_rounds,
                started.elapsed().as_millis(),
            );
            report = report
                .set("edges", r.total_edges)
                .set("lb_rounds", r.lb_rounds);
        }
        Some(d) => {
            println!(
                "{} on {} [{}] x{} GPUs ({}, {} exec on {} threads): {:.1} simulated ms (comp {:.1} + comm {:.1}), {} rounds ({} host ms)",
                app.name(),
                input,
                fw.name(),
                gpus,
                policy.name(),
                effective_exec.name(),
                d.os_threads,
                r.simulated_ms,
                d.comp_ms,
                d.comm_ms,
                r.rounds,
                started.elapsed().as_millis(),
            );
            let wall_ms: Vec<Json> = d
                .per_gpu_wall_ns
                .iter()
                .map(|&ns| Json::Num(ns as f64 / 1e6))
                .collect();
            report = report
                .set("comp_ms", d.comp_ms)
                .set("comm_ms", d.comm_ms)
                .set("comm_bytes", d.comm_bytes)
                .set("comm_bytes_intra", d.comm_bytes_intra)
                .set("comm_bytes_inter", d.comm_bytes_inter)
                .set("policy", policy.name())
                .set("exec", effective_exec.name())
                .set("os_threads", d.os_threads)
                .set("per_gpu_wall_ms", Json::Arr(wall_ms))
                .set("recoveries", d.recoveries)
                .set("replayed_rounds", d.replayed_rounds)
                .set("retry_count", d.retry_count)
                .set("checkpoint_bytes", d.checkpoint_bytes);
        }
    }

    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_string_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `alb serve` — the multi-tenant graph-query daemon (DESIGN.md §16): load
/// one graph into a [`Session`], then answer concurrent line-delimited JSON
/// queries over TCP with admission control, same-key coalescing, and an LRU
/// result cache.
fn cmd_serve(args: &Args) -> Result<()> {
    let input = args.get("graph").ok_or_else(|| {
        anyhow!(
            "--graph required (name or .albg file); valid presets: {}",
            inputs::preset_names()
        )
    })?;
    let delta = args.get_i32("scale-delta", 0)?;
    let seed = args.get_u64("seed", 42)?;
    let port = match args.get("port") {
        None => 7411u16,
        Some(v) => match v.parse::<u16>() {
            Ok(p) => p,
            Err(_) => bail!(
                "bad --port {v}; valid values: 0..=65535 (0 binds an ephemeral port)"
            ),
        },
    };
    let max_inflight = match args.get("max-inflight") {
        None => 4usize,
        Some(v) => match v.parse::<usize>() {
            Ok(n) if (1..=1024).contains(&n) => n,
            _ => bail!("bad --max-inflight {v}; valid values: 1..=1024"),
        },
    };
    let cache_entries = match args.get("cache-entries") {
        None => 64usize,
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n <= 1_048_576 => n,
            _ => bail!(
                "bad --cache-entries {v}; valid values: 0..=1048576 \
                 (0 disables the result cache)"
            ),
        },
    };
    let spec_name = args.get_or("gpu-spec", "sim-default");
    let spec = GpuSpec::by_name(&spec_name).ok_or_else(|| {
        anyhow!("unknown --gpu-spec {spec_name}; valid values: {}", GpuSpec::NAMES)
    })?;
    let fw_name = args.get_or("framework", "dirgl-alb");
    let fw = Framework::parse(&fw_name).ok_or_else(|| {
        anyhow!("unknown --framework {fw_name}; valid values: {}", Framework::NAMES)
    })?;
    let sim_threads =
        alb_graph::exec::parse_threads(args.get("sim-threads")).map_err(|e| anyhow!(e))?;
    let mut cfg = fw.engine_config(spec).with_sim_threads(sim_threads);
    if let Some(b) = args.get("balancer") {
        // `auto` stays unresolved here: the session resolves it per query
        // app, exactly as the campaign does per cell.
        cfg = cfg.with_balancer(Balancer::parse(b).ok_or_else(|| {
            anyhow!(
                "unknown --balancer {b}; valid values: {}",
                alb_graph::lb::BALANCER_NAMES.join(", ")
            )
        })?);
    }
    if let Some(m) = args.get("max-rounds") {
        match m.parse::<u32>() {
            Ok(n) if n >= 1 => cfg = cfg.with_max_rounds(n),
            _ => bail!("bad --max-rounds {m}; valid values: 1..=4294967295"),
        }
    }
    // The serve-side admission budget is the same number a query's omitted
    // `max_rounds` resolves to, so default queries match `alb run` exactly.
    let max_rounds = cfg.max_rounds;

    let (g, cache_outcome) = match args.get("graph-cache") {
        Some(dir) if !input.ends_with(".albg") => {
            disk::GraphCache::new(Path::new(dir))?.load_or_build(input, delta, seed)?
        }
        Some(_) => bail!("--graph-cache applies to named input presets, not .albg files"),
        None => (load_graph(input, delta, seed)?, disk::CacheOutcome::Miss),
    };
    let session = Session::new(g, input, cfg);
    let (nv, ne) = (session.num_vertices(), session.graph().num_edges());
    let handle = Server::spawn(
        session,
        ServeOpts { max_inflight, cache_entries, max_rounds },
        port,
    )?;
    println!(
        "alb serve: {input} ({nv} vertices, {ne} edges, graph cache {}) on {} — \
         max-inflight {max_inflight}, cache {cache_entries} entries, \
         round budget {max_rounds}",
        cache_outcome.name(),
        handle.addr(),
    );
    handle.join();
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("repro needs an experiment name or 'all'"))?;
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let mut rc = if args.get("quick").is_some() {
        ReproConfig::quick()
    } else {
        ReproConfig::default()
    };
    rc.scale_delta = args.get_i32("scale-delta", rc.scale_delta)?;
    rc.seed = args.get_u64("seed", rc.seed)?;

    let apps_all = alb_graph::apps::ALL_APPS;
    let push_apps = [App::Bfs, App::Sssp, App::Cc];
    let emit = |name: &str, body: String| -> Result<()> {
        let path = out_dir.join(format!("{name}.txt"));
        std::fs::write(&path, &body)?;
        println!("### {name}\n{body}");
        Ok(())
    };

    let all = what == "all";
    let mut matched = all;
    if all || what == "table1" {
        emit("table1", repro::table1(&rc)?.render())?;
        matched = true;
    }
    if all || what == "fig1" {
        emit("fig1", repro::fig1(&rc)?)?;
        matched = true;
    }
    if all || what == "table2" {
        emit("table2", repro::table2(&rc)?.render())?;
        matched = true;
    }
    if all || what == "fig5" {
        emit("fig5", repro::fig5(&rc)?)?;
        matched = true;
    }
    if all || what == "fig6" {
        emit("fig6", repro::fig6(&rc, &apps_all)?.render())?;
        matched = true;
    }
    if all || what == "fig7" {
        emit("fig7", repro::fig7(&rc, &apps_all)?.render())?;
        matched = true;
    }
    if all || what == "fig8" {
        emit("fig8", repro::fig8(&rc, &push_apps)?.render())?;
        matched = true;
    }
    if all || what == "fig9" {
        emit("fig9", repro::fig9(&rc, &push_apps)?.render())?;
        matched = true;
    }
    if all || what == "fig10" {
        emit("fig10", repro::fig10(&rc, &apps_all)?.render())?;
        matched = true;
    }
    if all || what == "fig11" {
        emit("fig11", repro::fig11(&rc, &apps_all)?.render())?;
        matched = true;
    }
    if all || what == "ablation-gpu" {
        emit(
            "ablation_gpu",
            repro::ablation_gpu(&rc, &[App::Bfs, App::Sssp])?.render(),
        )?;
        matched = true;
    }
    if all || what == "ablation-threshold" {
        emit(
            "ablation_threshold",
            repro::ablation_threshold(&rc, &[App::Bfs, App::Sssp])?.render(),
        )?;
        matched = true;
    }
    if !matched {
        bail!(
            "unknown experiment {what}; valid values: table1, fig1, table2, fig5, \
             fig6, fig7, fig8, fig9, fig10, fig11, ablation-gpu, \
             ablation-threshold, all"
        );
    }
    Ok(())
}

/// `alb sweep` — enumerate and execute the scenario matrix (DESIGN.md §11).
fn cmd_sweep(args: &Args) -> Result<()> {
    use alb_graph::campaign::{self, artifact, CampaignSpec};

    let mut spec = if args.get("smoke").is_some() {
        CampaignSpec::smoke()
    } else {
        CampaignSpec::full()
    };
    spec.scale_delta = args.get_i32("scale-delta", spec.scale_delta)?;
    spec.seed = args.get_u64("seed", spec.seed)?;
    spec.sim_threads =
        alb_graph::exec::parse_threads(args.get("sim-threads")).map_err(|e| anyhow!(e))?;
    if let Some(e) = args.get("exec") {
        spec.exec = ExecMode::parse_or_usage(e).map_err(|e| anyhow!(e))?;
    }
    if let Some(d) = args.get("delta") {
        spec.sssp_delta = d.parse().with_context(|| format!("--delta {d}"))?;
    }
    // Dimension filters; each rejects unknown values with the valid set.
    if let Some(v) = args.get("apps") {
        spec.filter_apps(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get("inputs") {
        spec.filter_inputs(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get("balancers") {
        spec.filter_balancers(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get("policies") {
        spec.filter_policies(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get("gpus") {
        spec.filter_gpus(v).map_err(|e| anyhow!(e))?;
    }
    if let Some(v) = args.get("faults") {
        spec.filter_faults(v).map_err(|e| anyhow!(e))?;
    }

    let cells = spec.cells();
    if args.get("list").is_some() {
        for c in &cells {
            println!("{}", c.id());
        }
        println!("{} cells", cells.len());
        return Ok(());
    }

    let out = PathBuf::from(args.get_or("out", "CAMPAIGN.json"));
    let resume = match args.get("resume") {
        None | Some("true") | Some("1") => true,
        Some("false") | Some("0") => false,
        Some(other) => bail!("--resume true|false (got {other})"),
    };
    let mut prior = HashMap::new();
    if resume && out.exists() {
        let prev = artifact::read(&out).with_context(|| format!("read {}", out.display()))?;
        if !prev.matches_spec(&spec) {
            bail!(
                "refusing to resume into {}: it records seed {} / scale-delta {} \
                 / smoke {}, this sweep uses {} / {} / {}; pass --resume false \
                 to overwrite, or --out for a fresh artifact",
                out.display(),
                prev.seed,
                prev.scale_delta,
                prev.smoke,
                spec.seed,
                spec.scale_delta,
                spec.smoke,
            );
        }
        for c in prev.cells {
            prior.insert(c.id.clone(), c);
        }
    }

    // Load the golden up front: a mistyped path must fail before the
    // sweep, not after hours of cell execution.
    let golden = match args.get("check-golden") {
        Some(gpath) => {
            let file = artifact::read(Path::new(gpath))
                .with_context(|| format!("read golden {gpath}"))?;
            Some((gpath.to_string(), file))
        }
        None => None,
    };

    let graph_cache = args.get("graph-cache").map(PathBuf::from);
    let total = cells.len();
    // Host-side wall clock for the progress report only — an allowlisted
    // D001 site; never feeds deterministic outputs.
    #[allow(clippy::disallowed_methods)]
    let started = std::time::Instant::now();
    let mut done = 0usize;
    let outcome = campaign::run_sweep_cached(
        &spec,
        &prior,
        Some(&out),
        graph_cache.as_deref(),
        |r, executed| {
            done += 1;
            println!(
                "[{done:>4}/{total}] {:<44} {:>6} rounds {:>14} cycles{}",
                r.id,
                r.rounds,
                r.total_cycles,
                if executed { "" } else { "  (cached)" },
            );
        },
    )?;

    // Whole-matrix golden expectations that hold on any machine
    // (balancer-independence, scale-out label consistency).
    repro::check_campaign_invariants(&outcome.results).map_err(|e| anyhow!(e))?;

    let mut t = Table::new(&["cell", "rounds", "cycles", "imb", "comm(B)", "inter(B)", "sim ms"]);
    for r in &outcome.results {
        t.row(vec![
            r.id.clone(),
            r.rounds.to_string(),
            r.total_cycles.to_string(),
            format!("{:.2}", r.imbalance_factor),
            r.comm_bytes.to_string(),
            r.comm_bytes_inter.to_string(),
            alb_graph::metrics::table::ms(r.simulated_ms),
        ]);
    }
    print!("{}", t.render());
    println!(
        "{total} cells ({} executed, {} resumed) in {} host ms -> {}",
        outcome.executed,
        outcome.skipped,
        started.elapsed().as_millis(),
        out.display(),
    );

    if let Some((gpath, file)) = &golden {
        let rep = artifact::check_golden(&outcome.results, file, gpath)
            .map_err(|e| anyhow!(e))?;
        println!(
            "golden ok: {} labels-hashes matched, {} cells await seeding",
            rep.seeded, rep.unseeded
        );
    }

    // CI's adaptive-gate: the strict, all-inputs form of the dominance
    // invariant — adaptive must match or beat every static strategy in
    // every (app, input, policy, gpus) group this sweep covered.
    if args.get("check-adaptive").is_some() {
        repro::check_adaptive_dominance(&outcome.results).map_err(|e| anyhow!(e))?;
        println!("adaptive gate ok: adaptive matched or beat every static strategy");
    }

    // CI's chaos-gate: every faulty cell must have recovered to labels
    // bit-identical to its fault-free twin, with bounded retries.
    if args.get("check-faults").is_some() {
        repro::check_fault_recovery(&outcome.results).map_err(|e| anyhow!(e))?;
        println!("fault gate ok: every faulty cell recovered to its fault-free labels");
    }
    Ok(())
}

/// `alb lint`: run the repo-invariant static analyzer (DESIGN.md §15) over
/// the tree at `--root` (default: the current directory). `--format json`
/// emits the machine-readable report (the CI artifact); `--out FILE`
/// additionally writes the rendered report to a file. Exits nonzero on any
/// unsuppressed diagnostic or stale allowlist entry.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.get_or("root", "."));
    let format = args.get_or("format", "text");
    if format != "text" && format != "json" {
        bail!("unknown --format {format}; valid values: text, json");
    }
    let report = alb_graph::analysis::run_lint(&root)?;
    let rendered = if format == "json" {
        report.to_json().to_string_pretty()
    } else {
        report.render_text()
    };
    if let Some(out) = args.get("out") {
        std::fs::write(out, &rendered).with_context(|| format!("write {out}"))?;
    }
    print!("{rendered}");
    if format == "json" {
        println!();
    }
    if !report.clean() {
        bail!(
            "lint failed: {} diagnostic(s), {} stale allowlist entr{}",
            report.diagnostics.len(),
            report.stale.len(),
            if report.stale.len() == 1 { "y" } else { "ies" }
        );
    }
    Ok(())
}

fn usage() {
    eprintln!(
        "alb — Adaptive Load Balancer for graph analytics (paper reproduction)\n\
         usage: alb <props|gen|run|sweep|serve|repro|lint> [flags]\n\
         see `rust/src/main.rs` header or README.md for full flag lists"
    );
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        usage();
        return ExitCode::FAILURE;
    };
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "props" => cmd_props(&args),
        "gen" => cmd_gen(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "repro" => cmd_repro(&args),
        "lint" => cmd_lint(&args),
        _ => {
            usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
