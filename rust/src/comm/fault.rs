//! Deterministic fault injection for distributed BSP rounds (ISSUE 8).
//!
//! A [`FaultPlan`] is a pure, seedable schedule of injected events — GPU
//! death at a given round, transient per-message corruption or drop on an
//! exchange link, and slow-link stalls — parsed once from `--faults` and
//! replayed by a [`FaultSession`] threaded through the coordinator's round
//! loop. Nothing here consults a clock or an RNG at run time: preset
//! placement is derived from the run seed through splitmix64 at parse time,
//! so every faulty run is bit-reproducible (the ISSUE 8 determinism gate
//! asserts identical recovery metrics across `sim_threads` ∈ {1, 2, 4}).
//!
//! Event timing is keyed on **wall rounds** — a monotone count of executed
//! supersteps including replayed ones — not on logical (algorithm) rounds:
//! replaying rounds after a recovery must not re-fire the events that
//! caused the recovery. An event fires at the first wall round `>=` its
//! scheduled round and is consumed exactly once; events scheduled past
//! convergence simply never fire.
//!
//! Corruption and drops are *detected*, not silently tolerated: the
//! exchange stages its per-pair reduce messages read-only
//! ([`super::exchange::ExchangePlan::stage_reduce_messages`]), hashes each
//! payload with FNV-1a ([`fnv64`]), injects the round's link faults into
//! scratch copies, and verifies on the receive side (checksum per message,
//! expected message count). A failed attempt never touches partition state
//! — the retry re-ships the same staged bytes (re-priced on the wire) —
//! so the clean attempt applies through the unchanged
//! `reduce_min`/`broadcast_min` walk and fault-free label parity is
//! automatic. After [`MAX_EXCHANGE_ATTEMPTS`] failures the run aborts
//! loudly rather than spin.

use super::exchange::Flow;
use super::NetworkModel;

/// Attempt budget for one guarded exchange; exceeding it is a hard error.
pub const MAX_EXCHANGE_ATTEMPTS: u32 = 8;

/// FNV-1a (64-bit) over a byte slice — the same hash family the `.albc`
/// trailer and the campaign label hashes use. Single-byte changes always
/// change the hash (xor + odd multiply are bijective mod 2^64), which is
/// what makes it a sound per-message corruption detector.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// splitmix64 — the seed mixer used for preset event placement.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// One kind of injected fault. GPU ids and link endpoints are taken modulo
/// the live partition count at fire time, so a plan written for 4 GPUs
/// stays meaningful after a death shrinks the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// GPU `gpu` dies at the start of the round: its superstep slot is
    /// masked out, the round is discarded, and the coordinator recovers by
    /// re-partitioning across survivors and replaying from the checkpoint.
    GpuDeath { gpu: u32 },
    /// Corrupt one staged exchange message on link (src, dst) — detected by
    /// the per-message FNV-1a checksum — on `times` consecutive attempts.
    Corrupt { src: u32, dst: u32, times: u32 },
    /// Drop one staged exchange message on link (src, dst) — detected by
    /// the expected-message-count check — on `times` consecutive attempts.
    Drop { src: u32, dst: u32, times: u32 },
    /// Multiply link (src, dst)'s transfer time by `factor` for one round
    /// (priced through [`NetworkModel::stall_cycles`]).
    Slow { src: u32, dst: u32, factor: u32 },
}

/// One scheduled event: `kind` fires at the first wall round `>= round`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// 1-based wall round (the first executed superstep is round 1).
    pub round: u64,
    pub kind: FaultKind,
}

/// A parsed, immutable fault schedule.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

/// The `--faults` usage string, shared by the parser's errors and the CLI.
pub const FAULTS_USAGE: &str = "none, gpu-death, corrupt, drop, slow, chaos, \
     or explicit gpu-death@R:G, corrupt@R:S-D[xN], drop@R:S-D[xN], \
     slow@R:S-D[xF]";

fn bad_item(item: &str) -> String {
    format!("unknown --faults item '{item}' (valid: {FAULTS_USAGE})")
}

/// Preset link endpoints: distinct when more than one partition exists.
fn preset_link(h: u64, k: u32) -> (u32, u32) {
    if k <= 1 {
        return (0, 0);
    }
    let s = (h % k as u64) as u32;
    let d = (s + 1 + ((h >> 16) % (k as u64 - 1)) as u32) % k;
    (s, d)
}

impl FaultPlan {
    /// The empty plan (also what `--faults none` parses to).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Does the plan schedule any GPU death? (Re-partition legality checks
    /// key on this — DESIGN.md §14.)
    pub fn has_death(&self) -> bool {
        self.events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::GpuDeath { .. }))
    }

    /// Parse a comma-separated `--faults` spec. Items are either presets
    /// (`none`, `gpu-death`, `corrupt`, `drop`, `slow`, `chaos`) whose
    /// placement is derived deterministically from `seed`, or explicit
    /// events (`gpu-death@R:G`, `corrupt@R:S-D[xN]`, `drop@R:S-D[xN]`,
    /// `slow@R:S-D[xF]`). Rounds are 1-based wall rounds.
    pub fn parse(spec: &str, num_gpus: u32, seed: u64) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match item {
                "none" => {}
                "gpu-death" => {
                    let h = splitmix64(seed ^ 0xdead);
                    events.push(FaultEvent {
                        round: 2,
                        kind: FaultKind::GpuDeath {
                            gpu: (h % num_gpus.max(1) as u64) as u32,
                        },
                    });
                }
                "corrupt" => {
                    let (s, d) = preset_link(splitmix64(seed ^ 0xc0), num_gpus);
                    events.push(FaultEvent {
                        round: 1,
                        kind: FaultKind::Corrupt { src: s, dst: d, times: 2 },
                    });
                    events.push(FaultEvent {
                        round: 3,
                        kind: FaultKind::Corrupt { src: d, dst: s, times: 1 },
                    });
                }
                "drop" => {
                    let (s, d) = preset_link(splitmix64(seed ^ 0xd0), num_gpus);
                    events.push(FaultEvent {
                        round: 2,
                        kind: FaultKind::Drop { src: s, dst: d, times: 3 },
                    });
                }
                "slow" => {
                    let (s, d) = preset_link(splitmix64(seed ^ 0x510), num_gpus);
                    events.push(FaultEvent {
                        round: 1,
                        kind: FaultKind::Slow { src: s, dst: d, factor: 4 },
                    });
                    events.push(FaultEvent {
                        round: 3,
                        kind: FaultKind::Slow { src: d, dst: s, factor: 2 },
                    });
                }
                "chaos" => {
                    // Every fault class in one plan: corruption, drops, a
                    // stall, then a death — the soak-test scenario.
                    let (s, d) = preset_link(splitmix64(seed ^ 0xc4a0), num_gpus);
                    events.push(FaultEvent {
                        round: 1,
                        kind: FaultKind::Corrupt { src: s, dst: d, times: 2 },
                    });
                    events.push(FaultEvent {
                        round: 2,
                        kind: FaultKind::Drop { src: d, dst: s, times: 2 },
                    });
                    events.push(FaultEvent {
                        round: 3,
                        kind: FaultKind::Slow { src: s, dst: d, factor: 3 },
                    });
                    let h = splitmix64(seed ^ 0xc4a05);
                    events.push(FaultEvent {
                        round: 4,
                        kind: FaultKind::GpuDeath {
                            gpu: (h % num_gpus.max(1) as u64) as u32,
                        },
                    });
                }
                _ => events.push(Self::parse_explicit(item)?),
            }
        }
        events.sort_by_key(|e| e.round);
        Ok(FaultPlan { events })
    }

    /// Parse one explicit `kind@round:args` event.
    fn parse_explicit(item: &str) -> Result<FaultEvent, String> {
        let (name, rest) = item.split_once('@').ok_or_else(|| bad_item(item))?;
        let (round_s, args) = rest.split_once(':').ok_or_else(|| bad_item(item))?;
        let round: u64 = round_s.parse().map_err(|_| bad_item(item))?;
        if round == 0 {
            return Err(format!(
                "--faults rounds are 1-based; '{item}' schedules round 0 \
                 (valid: {FAULTS_USAGE})"
            ));
        }
        if name == "gpu-death" {
            let gpu: u32 = args.parse().map_err(|_| bad_item(item))?;
            return Ok(FaultEvent { round, kind: FaultKind::GpuDeath { gpu } });
        }
        // Link kinds: S-D with an optional xN / xF suffix.
        let (link, x) = match args.split_once('x') {
            Some((l, n)) => (l, Some(n)),
            None => (args, None),
        };
        let (src_s, dst_s) = link.split_once('-').ok_or_else(|| bad_item(item))?;
        let src: u32 = src_s.parse().map_err(|_| bad_item(item))?;
        let dst: u32 = dst_s.parse().map_err(|_| bad_item(item))?;
        let xval: u32 = match x {
            Some(n) => n.parse().map_err(|_| bad_item(item))?,
            None => 0,
        };
        let kind = match name {
            "corrupt" => FaultKind::Corrupt { src, dst, times: xval.max(1) },
            "drop" => FaultKind::Drop { src, dst, times: xval.max(1) },
            "slow" => FaultKind::Slow { src, dst, factor: xval.max(2) },
            _ => return Err(bad_item(item)),
        };
        Ok(FaultEvent { round, kind })
    }
}

impl NetworkModel {
    /// Extra cycles a slow-link stall adds to a round: the stalled link
    /// re-pays its transfer time `factor - 1` more times. Zero when the
    /// link carries no bytes this round or the factor is degenerate.
    pub fn stall_cycles(
        &self,
        flows: &[Flow],
        src: u32,
        dst: u32,
        factor: u32,
    ) -> u64 {
        if factor <= 1 || src == dst {
            return 0;
        }
        let bytes: u64 = flows
            .iter()
            .filter(|&&(s, d, b)| s == src && d == dst && b > 0)
            .map(|&(_, _, b)| b)
            .sum();
        if bytes == 0 {
            return 0;
        }
        let (alpha, bpc) = if self.same_host(src, dst) {
            (self.intra_alpha_cycles, self.intra_bytes_per_cycle)
        } else {
            (self.inter_alpha_cycles, self.inter_bytes_per_cycle)
        };
        (alpha + (bytes as f64 / bpc) as u64) * (factor as u64 - 1)
    }
}

/// One in-flight link fault taken for the current exchange.
struct LinkFault {
    drop: bool,
    src: u32,
    dst: u32,
    times: u32,
}

/// The runtime side of a fault plan: tracks the wall round, which events
/// have been consumed, and the exchange retry counter.
#[derive(Debug, Clone)]
pub struct FaultSession {
    events: Vec<FaultEvent>,
    consumed: Vec<bool>,
    /// Total failed exchange attempts across the run.
    pub retry_count: u64,
    wall_round: u64,
}

impl FaultSession {
    pub fn new(plan: &FaultPlan) -> FaultSession {
        FaultSession {
            events: plan.events.clone(),
            consumed: vec![false; plan.events.len()],
            retry_count: 0,
            wall_round: 0,
        }
    }

    /// Advance to the next wall round (call once at the top of every
    /// executed superstep, replays included) and return its number.
    pub fn advance_round(&mut self) -> u64 {
        self.wall_round += 1;
        self.wall_round
    }

    pub fn wall_round(&self) -> u64 {
        self.wall_round
    }

    /// Consume one due GPU-death event, if any, returning the dead GPU id
    /// reduced modulo `live` (a plan written for the original cluster size
    /// stays meaningful after earlier deaths).
    pub fn take_death(&mut self, live: u32) -> Option<u32> {
        for (i, e) in self.events.iter().enumerate() {
            if self.consumed[i] || e.round > self.wall_round {
                continue;
            }
            if let FaultKind::GpuDeath { gpu } = e.kind {
                self.consumed[i] = true;
                return Some(gpu % live.max(1));
            }
        }
        None
    }

    /// Consume every due slow-link event and price its stall against this
    /// round's flows (link endpoints taken modulo `num_parts`).
    pub fn take_stalls(
        &mut self,
        net: &NetworkModel,
        num_parts: u32,
        flows: &[Flow],
    ) -> u64 {
        let k = num_parts.max(1);
        let mut extra = 0u64;
        for (i, e) in self.events.iter().enumerate() {
            if self.consumed[i] || e.round > self.wall_round {
                continue;
            }
            if let FaultKind::Slow { src, dst, factor } = e.kind {
                self.consumed[i] = true;
                extra += net.stall_cycles(flows, src % k, dst % k, factor);
            }
        }
        extra
    }

    /// Consume every due corrupt/drop event for this exchange.
    fn take_link_faults(&mut self) -> Vec<LinkFault> {
        let mut out = Vec::new();
        for (i, e) in self.events.iter().enumerate() {
            if self.consumed[i] || e.round > self.wall_round {
                continue;
            }
            match e.kind {
                FaultKind::Corrupt { src, dst, times } => {
                    self.consumed[i] = true;
                    out.push(LinkFault { drop: false, src, dst, times });
                }
                FaultKind::Drop { src, dst, times } => {
                    self.consumed[i] = true;
                    out.push(LinkFault { drop: true, src, dst, times });
                }
                _ => {}
            }
        }
        out
    }

    /// Run the verification/retry protocol over one round's staged exchange
    /// messages (`(src, dst, payload)` per traffic-bearing pair).
    ///
    /// Each attempt injects the due link faults into scratch copies, then
    /// verifies receive-side: every expected message present (drop check)
    /// and every payload re-hashing to its staged FNV-1a checksum
    /// (corruption check). Failed attempts bump `retry_count` and re-price
    /// the staged bytes into `flows` (the wire carried them either way);
    /// partition state is untouched, so the caller applies the real
    /// reduce/broadcast only after a clean attempt. A fault scheduled on a
    /// link with no traffic redirects to the round's first staged message,
    /// so a scheduled fault always fires when any traffic exists; an empty
    /// exchange consumes the events as harmless no-ops.
    ///
    /// Returns the number of attempts taken (1 = clean first try), or an
    /// error once [`MAX_EXCHANGE_ATTEMPTS`] attempts all failed.
    pub fn exchange_guarded(
        &mut self,
        num_parts: u32,
        staged: &[(u32, u32, Vec<u8>)],
        flows: &mut Vec<Flow>,
    ) -> Result<u32, String> {
        let sums: Vec<u64> = staged.iter().map(|(_, _, p)| fnv64(p)).collect();
        let mut faults = self.take_link_faults();
        let k = num_parts.max(1);
        for attempt in 1..=MAX_EXCHANGE_ATTEMPTS {
            let mut dropped = vec![false; staged.len()];
            let mut scratch: Vec<Option<Vec<u8>>> = vec![None; staged.len()];
            for f in faults.iter_mut() {
                if f.times == 0 || staged.is_empty() {
                    continue;
                }
                f.times -= 1;
                let (s, d) = (f.src % k, f.dst % k);
                let idx = staged
                    .iter()
                    .position(|&(a, b, _)| a == s && b == d)
                    .unwrap_or(0);
                if f.drop {
                    dropped[idx] = true;
                } else {
                    let copy = scratch[idx]
                        .get_or_insert_with(|| staged[idx].2.clone());
                    if !copy.is_empty() {
                        let pos = (self.wall_round as usize + attempt as usize)
                            % copy.len();
                        copy[pos] ^= 0xA5;
                    }
                }
            }
            let mut clean = true;
            for (i, (_, _, payload)) in staged.iter().enumerate() {
                if dropped[i] {
                    clean = false;
                    continue;
                }
                let got = match &scratch[i] {
                    Some(c) => fnv64(c),
                    None => fnv64(payload),
                };
                if got != sums[i] {
                    clean = false;
                }
            }
            if clean {
                return Ok(attempt);
            }
            self.retry_count += 1;
            for (s, d, p) in staged {
                flows.push((*s, *d, p.len() as u64));
            }
        }
        Err(format!(
            "exchange failed verification {MAX_EXCHANGE_ATTEMPTS} times at \
             wall round {} — the fault plan exceeds the retry budget",
            self.wall_round
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_detects_every_single_byte_change() {
        let base = b"exchange payload bytes".to_vec();
        let h0 = fnv64(&base);
        for i in 0..base.len() {
            for flip in [0x01u8, 0xA5, 0xFF] {
                let mut m = base.clone();
                m[i] ^= flip;
                assert_ne!(fnv64(&m), h0, "byte {i} flip {flip:#x} undetected");
            }
        }
    }

    #[test]
    fn parse_presets_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan::parse("chaos", 4, 42).unwrap();
        let b = FaultPlan::parse("chaos", 4, 42).unwrap();
        assert_eq!(a, b, "same spec + seed must parse identically");
        assert!(!a.is_empty() && a.has_death());
        let c = FaultPlan::parse("gpu-death", 4, 1).unwrap();
        let d = FaultPlan::parse("gpu-death", 4, 2).unwrap();
        assert_eq!(c.events.len(), 1);
        assert_eq!(d.events.len(), 1);
        // Seeds place the death on a (generally) different GPU; both valid.
        for p in [&c, &d] {
            match p.events[0].kind {
                FaultKind::GpuDeath { gpu } => assert!(gpu < 4),
                k => panic!("expected GpuDeath, got {k:?}"),
            }
        }
    }

    #[test]
    fn parse_none_is_empty_and_combos_concatenate() {
        assert!(FaultPlan::parse("none", 4, 0).unwrap().is_empty());
        assert!(FaultPlan::parse("", 4, 0).unwrap().is_empty());
        let p = FaultPlan::parse("corrupt,drop,slow", 4, 7).unwrap();
        assert_eq!(p.events.len(), 5);
        assert!(!p.has_death());
        // Events come out sorted by round.
        for w in p.events.windows(2) {
            assert!(w[0].round <= w[1].round);
        }
    }

    #[test]
    fn parse_explicit_grammar() {
        let p = FaultPlan::parse(
            "gpu-death@3:1,corrupt@1:0-2x2,drop@2:1-3,slow@4:0-1x8",
            4,
            0,
        )
        .unwrap();
        assert_eq!(p.events.len(), 4);
        assert_eq!(
            p.events[0],
            FaultEvent { round: 1, kind: FaultKind::Corrupt { src: 0, dst: 2, times: 2 } }
        );
        assert_eq!(
            p.events[1],
            FaultEvent { round: 2, kind: FaultKind::Drop { src: 1, dst: 3, times: 1 } }
        );
        assert_eq!(
            p.events[2],
            FaultEvent { round: 3, kind: FaultKind::GpuDeath { gpu: 1 } }
        );
        assert_eq!(
            p.events[3],
            FaultEvent { round: 4, kind: FaultKind::Slow { src: 0, dst: 1, factor: 8 } }
        );
    }

    #[test]
    fn parse_errors_name_the_valid_forms() {
        for bad in ["bogus", "gpu-death@x:1", "corrupt@1:nope", "drop@1", "corrupt@0:0-1"] {
            let e = FaultPlan::parse(bad, 4, 0).unwrap_err();
            assert!(e.contains("gpu-death@R:G"), "{bad}: {e}");
            assert!(e.contains("chaos"), "{bad}: {e}");
        }
    }

    #[test]
    fn events_fire_at_or_after_their_round_exactly_once() {
        let plan = FaultPlan::parse("gpu-death@3:2", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round(); // 1
        assert_eq!(s.take_death(4), None);
        s.advance_round(); // 2
        assert_eq!(s.take_death(4), None);
        s.advance_round(); // 3
        assert_eq!(s.take_death(4), Some(2));
        assert_eq!(s.take_death(4), None, "consumed exactly once");
        s.advance_round();
        assert_eq!(s.take_death(4), None);
    }

    #[test]
    fn death_fires_late_if_its_round_was_skipped() {
        // A recovery can jump the wall round past an event's schedule; the
        // `>=` rule fires it at the next opportunity instead of losing it.
        let plan = FaultPlan::parse("gpu-death@2:0", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        s.advance_round();
        s.advance_round(); // round 3, event scheduled at 2
        assert_eq!(s.take_death(4), Some(0));
    }

    #[test]
    fn dead_gpu_id_wraps_to_live_count() {
        let plan = FaultPlan::parse("gpu-death@1:7", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        assert_eq!(s.take_death(3), Some(7 % 3));
    }

    fn staged_pair() -> Vec<(u32, u32, Vec<u8>)> {
        vec![
            (0, 1, vec![1, 2, 3, 4, 5, 6, 7, 8]),
            (2, 3, vec![9, 10, 11, 12]),
        ]
    }

    #[test]
    fn clean_exchange_takes_one_attempt_and_no_retries() {
        let mut s = FaultSession::new(&FaultPlan::none());
        s.advance_round();
        let mut flows = Vec::new();
        let attempts = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
        assert_eq!(attempts, 1);
        assert_eq!(s.retry_count, 0);
        assert!(flows.is_empty(), "no failed attempts, no extra flows");
    }

    #[test]
    fn corruption_is_detected_and_retried_off() {
        let plan = FaultPlan::parse("corrupt@1:0-1x2", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let mut flows = Vec::new();
        let attempts = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
        assert_eq!(attempts, 3, "2 corrupted attempts then a clean one");
        assert_eq!(s.retry_count, 2);
        // Each failed attempt re-priced both staged messages.
        assert_eq!(flows.len(), 4);
        assert_eq!(flows[0], (0, 1, 8));
        assert_eq!(flows[1], (2, 3, 4));
    }

    #[test]
    fn drops_are_detected_by_message_count() {
        let plan = FaultPlan::parse("drop@1:2-3x1", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let mut flows = Vec::new();
        let attempts = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
        assert_eq!(attempts, 2);
        assert_eq!(s.retry_count, 1);
    }

    #[test]
    fn fault_on_idle_link_redirects_to_first_message() {
        // Link 3->0 carries nothing this round; the fault must still fire.
        let plan = FaultPlan::parse("drop@1:3-0x1", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let mut flows = Vec::new();
        let attempts = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
        assert_eq!(attempts, 2, "redirected fault must cost a retry");
        assert_eq!(s.retry_count, 1);
    }

    #[test]
    fn empty_exchange_consumes_events_harmlessly() {
        let plan = FaultPlan::parse("corrupt@1:0-1x2,drop@1:0-1x9", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let mut flows = Vec::new();
        let attempts = s.exchange_guarded(4, &[], &mut flows).unwrap();
        assert_eq!(attempts, 1);
        assert_eq!(s.retry_count, 0);
        // Consumed: a later exchange with traffic sees no faults.
        s.advance_round();
        let attempts = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
        assert_eq!(attempts, 1);
    }

    #[test]
    fn unbounded_drop_exhausts_the_retry_budget_loudly() {
        let plan =
            FaultPlan::parse(&format!("drop@1:0-1x{}", MAX_EXCHANGE_ATTEMPTS), 4, 0)
                .unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let mut flows = Vec::new();
        let err = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap_err();
        assert!(err.contains("retry budget"), "{err}");
        assert_eq!(s.retry_count, MAX_EXCHANGE_ATTEMPTS as u64);
    }

    #[test]
    fn exchange_is_deterministic_across_replays() {
        let plan = FaultPlan::parse("corrupt@1:0-1x1", 4, 9).unwrap();
        let run = || {
            let mut s = FaultSession::new(&plan);
            s.advance_round();
            let mut flows = Vec::new();
            let a = s.exchange_guarded(4, &staged_pair(), &mut flows).unwrap();
            (a, s.retry_count, flows)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn stall_cycles_price_only_loaded_links() {
        let net = NetworkModel::cluster(2);
        let flows = vec![(0u32, 1u32, 1 << 20), (0, 2, 1 << 20)];
        // Intra-host link, 4x slowdown: 3 extra transfer times.
        let intra = net.stall_cycles(&flows, 0, 1, 4);
        let expect =
            (net.intra_alpha_cycles + ((1u64 << 20) as f64 / net.intra_bytes_per_cycle) as u64) * 3;
        assert_eq!(intra, expect);
        // Inter-host stalls cost more than intra for the same bytes/factor.
        assert!(net.stall_cycles(&flows, 0, 2, 4) > intra);
        // Idle link, degenerate factor, self link: all free.
        assert_eq!(net.stall_cycles(&flows, 1, 0, 4), 0);
        assert_eq!(net.stall_cycles(&flows, 0, 1, 1), 0);
        assert_eq!(net.stall_cycles(&flows, 0, 0, 4), 0);
    }

    #[test]
    fn slow_events_consume_through_take_stalls() {
        let plan = FaultPlan::parse("slow@1:0-1x4", 4, 0).unwrap();
        let mut s = FaultSession::new(&plan);
        s.advance_round();
        let net = NetworkModel::single_host();
        let flows = vec![(0u32, 1u32, 4096)];
        let extra = s.take_stalls(&net, 4, &flows);
        assert!(extra > 0);
        assert_eq!(s.take_stalls(&net, 4, &flows), 0, "consumed once");
    }
}
