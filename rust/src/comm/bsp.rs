//! The BSP superstep executor: dispatch one compute task per simulated GPU
//! onto the shared [`crate::exec::Pool`] and **barrier** before the
//! Gluon-style reduce / broadcast begins.
//!
//! This makes the bulk-synchronous structure of the coordinator explicit:
//! a round is `superstep_mut(per-GPU states) -> reduce -> broadcast`, and
//! [`superstep_mut`]'s return *is* the barrier separating local compute
//! from communication — the pool's job-completion wait guarantees no
//! partition's updates are reconciled while another partition is still
//! computing. Since ISSUE 4 the coordinator uses the in-place
//! [`superstep_mut`] (task `i` owns state `i` exclusively; no per-round
//! task vector, result slots, or payload Vecs — DESIGN.md §10);
//! [`superstep`] remains as the owned-results variant for callers whose
//! tasks *produce* values rather than mutate per-partition state.
//!
//! Since PR 3 the per-GPU tasks are pool tasks, not dedicated OS threads:
//! the coordinator owns ONE pool, GPU tasks run on it (the submitting
//! thread participates), and a GPU task's own intra-GPU parallel simulation
//! (`Simulator::simulate_into_pooled`, DESIGN.md §9) nests onto the *same*
//! pool — so a run never oversubscribes the host with per-GPU threads times
//! per-simulation workers.
//!
//! Determinism: results are collected **by partition index**, never by
//! completion order, and every reduction downstream folds them in that
//! order. [`ExecMode::Sequential`] runs the same closures inline on the
//! caller's thread — the reference the parallel path must match bit-for-bit
//! (asserted by `rust/tests/parity.rs`).

use std::sync::Mutex;

use crate::exec::Pool;

/// How per-round per-GPU tasks execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Tasks dispatched onto the shared worker pool (the default). With a
    /// 1-thread pool this degenerates to the sequential walk.
    #[default]
    Parallel,
    /// In partition order on the calling thread — the determinism reference.
    Sequential,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Parallel => "parallel",
            ExecMode::Sequential => "sequential",
        }
    }

    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "parallel" | "par" => Some(ExecMode::Parallel),
            "sequential" | "seq" => Some(ExecMode::Sequential),
            _ => None,
        }
    }

    /// [`parse`](Self::parse) with a CLI-grade error that echoes the bad
    /// value and lists every accepted spelling, so `alb run --exec bogus`
    /// fails with actionable output instead of a bare "unknown".
    pub fn parse_or_usage(s: &str) -> Result<ExecMode, String> {
        ExecMode::parse(s).ok_or_else(|| {
            format!(
                "unknown --exec value '{s}' (valid: parallel, par, \
                 sequential, seq)"
            )
        })
    }
}

/// Mutable base pointer of a slice whose elements are handed out to pool
/// tasks one per index. Sync because [`crate::exec::Pool::run`] claims each
/// index exactly once, so no element is ever aliased.
struct DisjointMut<S>(*mut S);

// SAFETY: see the claim-exactly-once argument on `superstep_mut`.
unsafe impl<S: Send> Sync for DisjointMut<S> {}

/// Run one compute task per partition **in place**: task `i` gets exclusive
/// `&mut` access to `states[i]` and writes its results there, so a warmed
/// round performs no allocation on the submitting thread (no task vector,
/// no result slots, no per-round payload Vecs — DESIGN.md §8/§10).
/// Returning is the BSP barrier, exactly as with [`superstep`].
///
/// Determinism: the caller folds `states` by index after the barrier, never
/// by completion order. [`ExecMode::Sequential`] (and a 1-lane pool, and a
/// single task) runs inline on the caller's thread in index order — the
/// bit-exact reference the parallel path must match.
pub fn superstep_mut<S: Send>(
    mode: ExecMode,
    pool: &Pool,
    states: &mut [S],
    f: &(dyn Fn(usize, &mut S) + Sync),
) {
    let n = states.len();
    if mode == ExecMode::Sequential || n <= 1 || pool.threads() <= 1 {
        for (i, s) in states.iter_mut().enumerate() {
            f(i, s);
        }
        return;
    }
    let base = DisjointMut(states.as_mut_ptr());
    pool.run(n, &|i| {
        // SAFETY: `Pool::run` hands out each index in `0..n` exactly once
        // (a single atomic claim counter; the end-of-job guard only claims
        // leftovers on unwind, without running them), so `states[i]` is
        // mutably borrowed by exactly one task, and the slice outlives the
        // call because the submitter blocks until every task finishes.
        let s = unsafe { &mut *base.0.add(i) };
        f(i, s);
    });
}

/// [`superstep_mut`] with a liveness mask (ISSUE 8): task `i` runs only
/// when `alive[i]` — a dead simulated GPU's slot is skipped entirely, its
/// state untouched. With every GPU alive this is exactly `superstep_mut`.
/// The fault-tolerant coordinator drives the death round through this and
/// then discards the round, so the masked superstep is where a GPU death
/// is "threaded into" the BSP structure.
pub fn superstep_mut_masked<S: Send>(
    mode: ExecMode,
    pool: &Pool,
    states: &mut [S],
    alive: &[bool],
    f: &(dyn Fn(usize, &mut S) + Sync),
) {
    let n = states.len();
    assert_eq!(n, alive.len(), "mask must cover every partition");
    if mode == ExecMode::Sequential || n <= 1 || pool.threads() <= 1 {
        for (i, s) in states.iter_mut().enumerate() {
            if alive[i] {
                f(i, s);
            }
        }
        return;
    }
    let base = DisjointMut(states.as_mut_ptr());
    pool.run(n, &|i| {
        if !alive[i] {
            return;
        }
        // SAFETY: identical to `superstep_mut` — each index claimed once.
        let s = unsafe { &mut *base.0.add(i) };
        f(i, s);
    });
}

/// One result slot of an in-flight superstep: the not-yet-run task, then
/// its output. Each slot's mutex is taken by exactly one pool task.
struct Slot<F, T> {
    task: Option<F>,
    result: Option<T>,
}

/// Run one compute task per partition and return their results indexed by
/// partition. Returning from this function is the BSP barrier: the pool's
/// completion wait has observed every task finish, so the caller may safely
/// reduce/broadcast shared state. The submitting thread participates in
/// executing tasks (see [`Pool::run`]).
///
/// The coordinator's round loop uses the allocation-free in-place
/// [`superstep_mut`] instead (ISSUE 4); this variant is kept for callers
/// whose tasks return owned values.
pub fn superstep<T, F>(mode: ExecMode, pool: &Pool, tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    // A single task has nobody to overlap with, and a 1-thread pool has
    // nobody to hand tasks to; inline either case. (Sequential mode is the
    // bit-exact reference for parity tests.)
    if mode == ExecMode::Sequential || tasks.len() <= 1 || pool.threads() <= 1 {
        return tasks.into_iter().map(|f| f()).collect();
    }
    let slots: Vec<Mutex<Slot<F, T>>> = tasks
        .into_iter()
        .map(|f| Mutex::new(Slot { task: Some(f), result: None }))
        .collect();
    pool.run(slots.len(), &|i| {
        let mut s = slots[i].lock().unwrap();
        if let Some(task) = s.task.take() {
            s.result = Some(task());
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("superstep slot lock cannot be poisoned")
                .result
                .expect("superstep task finished")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::{self, ThreadId};
    use std::time::Duration;

    fn tasks(n: usize) -> Vec<impl FnOnce() -> (usize, ThreadId) + Send> {
        (0..n)
            .map(|i| {
                move || {
                    thread::sleep(Duration::from_millis(1));
                    (i * i, thread::current().id())
                }
            })
            .collect()
    }

    #[test]
    fn results_are_ordered_by_partition_index() {
        let pool = Pool::new(4);
        for mode in [ExecMode::Parallel, ExecMode::Sequential] {
            let got = superstep(mode, &pool, tasks(16));
            for (i, (val, _)) in got.iter().enumerate() {
                assert_eq!(*val, i * i, "{mode:?}");
            }
        }
    }

    #[test]
    fn parallel_mode_uses_multiple_os_threads() {
        // With the caller participating, a 4-lane pool spreads 64 sleepy
        // tasks over >= 2 distinct threads.
        let pool = Pool::new(4);
        let got = superstep(ExecMode::Parallel, &pool, tasks(64));
        let ids: HashSet<ThreadId> = got.iter().map(|(_, id)| *id).collect();
        assert!(ids.len() >= 2, "expected >= 2 worker threads, saw {}", ids.len());
    }

    #[test]
    fn sequential_mode_stays_on_the_caller() {
        let pool = Pool::new(4);
        let got = superstep(ExecMode::Sequential, &pool, tasks(4));
        for (_, id) in &got {
            assert_eq!(*id, thread::current().id());
        }
    }

    #[test]
    fn single_task_runs_inline_even_in_parallel_mode() {
        let pool = Pool::new(4);
        let got = superstep(ExecMode::Parallel, &pool, tasks(1));
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1, thread::current().id());
    }

    #[test]
    fn one_thread_pool_runs_inline_even_in_parallel_mode() {
        let pool = Pool::new(1);
        let got = superstep(ExecMode::Parallel, &pool, tasks(4));
        for (_, id) in &got {
            assert_eq!(*id, thread::current().id());
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let pool = Pool::new(3);
        let a = superstep(ExecMode::Parallel, &pool, tasks(9));
        let b = superstep(ExecMode::Sequential, &pool, tasks(9));
        let va: Vec<usize> = a.into_iter().map(|(v, _)| v).collect();
        let vb: Vec<usize> = b.into_iter().map(|(v, _)| v).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn superstep_is_a_barrier() {
        // Every task increments before superstep returns.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..8)
            .map(|_| {
                let c = &counter;
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        let _ = superstep(ExecMode::Parallel, &pool, tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn superstep_mut_runs_every_state_in_place() {
        let pool = Pool::new(4);
        for mode in [ExecMode::Parallel, ExecMode::Sequential] {
            let mut states: Vec<(usize, ThreadId)> =
                (0..16).map(|_| (0, thread::current().id())).collect();
            superstep_mut(mode, &pool, &mut states, &|i, s| {
                thread::sleep(Duration::from_millis(1));
                *s = (i * i + 1, thread::current().id());
            });
            for (i, (val, _)) in states.iter().enumerate() {
                assert_eq!(*val, i * i + 1, "{mode:?}");
            }
        }
    }

    #[test]
    fn superstep_mut_parallel_spreads_over_threads_sequential_stays_inline() {
        let pool = Pool::new(4);
        let mut states: Vec<ThreadId> =
            (0..64).map(|_| thread::current().id()).collect();
        superstep_mut(ExecMode::Parallel, &pool, &mut states, &|_, s| {
            thread::sleep(Duration::from_millis(1));
            *s = thread::current().id();
        });
        let ids: HashSet<ThreadId> = states.iter().copied().collect();
        assert!(ids.len() >= 2, "expected >= 2 threads, saw {}", ids.len());

        superstep_mut(ExecMode::Sequential, &pool, &mut states, &|_, s| {
            *s = thread::current().id();
        });
        assert!(states.iter().all(|&id| id == thread::current().id()));
    }

    #[test]
    fn superstep_mut_is_a_barrier() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        let mut states = vec![(); 8];
        superstep_mut(ExecMode::Parallel, &pool, &mut states, &|_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn masked_superstep_skips_dead_slots_only() {
        let pool = Pool::new(4);
        for mode in [ExecMode::Parallel, ExecMode::Sequential] {
            let mut states: Vec<usize> = vec![0; 16];
            let alive: Vec<bool> = (0..16).map(|i| i != 3 && i != 11).collect();
            superstep_mut_masked(mode, &pool, &mut states, &alive, &|i, s| {
                *s = i + 1;
            });
            for (i, &v) in states.iter().enumerate() {
                if alive[i] {
                    assert_eq!(v, i + 1, "{mode:?}");
                } else {
                    assert_eq!(v, 0, "{mode:?}: dead slot {i} must stay untouched");
                }
            }
        }
    }

    #[test]
    fn masked_superstep_all_alive_matches_plain() {
        let pool = Pool::new(4);
        let mut a: Vec<usize> = vec![0; 8];
        let mut b: Vec<usize> = vec![0; 8];
        superstep_mut(ExecMode::Parallel, &pool, &mut a, &|i, s| *s = i * 7);
        let alive = vec![true; 8];
        superstep_mut_masked(ExecMode::Parallel, &pool, &mut b, &alive, &|i, s| {
            *s = i * 7;
        });
        assert_eq!(a, b);
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        for m in [ExecMode::Parallel, ExecMode::Sequential] {
            assert_eq!(ExecMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("nope"), None);
    }

    #[test]
    fn exec_mode_parse_or_usage_names_valid_values() {
        assert_eq!(ExecMode::parse_or_usage("par"), Ok(ExecMode::Parallel));
        let e = ExecMode::parse_or_usage("bogus").unwrap_err();
        assert!(e.contains("bogus"), "{e}");
        assert!(e.contains("parallel"), "{e}");
        assert!(e.contains("sequential"), "{e}");
    }
}
