//! The BSP superstep executor: fork one task per simulated GPU, run them on
//! their own OS threads, and **barrier** before the Gluon-style reduce /
//! broadcast begins.
//!
//! This makes the bulk-synchronous structure of the coordinator explicit:
//! a round is `superstep(compute tasks) -> reduce -> broadcast`, and the
//! join performed by [`superstep`] *is* the barrier separating local compute
//! from communication — no partition's updates are reconciled while another
//! partition is still computing.
//!
//! Determinism: results are collected **by partition index**, never by
//! completion order, and every reduction downstream folds them in that
//! order. [`ExecMode::Sequential`] runs the same closures inline on the
//! caller's thread — the reference the parallel path must match bit-for-bit
//! (asserted by `rust/tests/parity.rs`).

use std::thread;

/// How per-round per-GPU tasks execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// One scoped OS thread per simulated GPU (the default).
    #[default]
    Parallel,
    /// In partition order on the calling thread — the determinism reference.
    Sequential,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Parallel => "parallel",
            ExecMode::Sequential => "sequential",
        }
    }

    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "parallel" | "par" => Some(ExecMode::Parallel),
            "sequential" | "seq" => Some(ExecMode::Sequential),
            _ => None,
        }
    }
}

/// Run one compute task per partition and return their results indexed by
/// partition. Returning from this function is the BSP barrier: every worker
/// thread has been joined (scoped threads cannot outlive the scope), so the
/// caller may safely reduce/broadcast shared state.
pub fn superstep<T, F>(mode: ExecMode, tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    // A single task has nobody to overlap with; inline it to spare the
    // spawn. (Sequential mode is the bit-exact reference for parity tests.)
    if mode == ExecMode::Sequential || tasks.len() <= 1 {
        return tasks.into_iter().map(|f| f()).collect();
    }
    let mut out: Vec<Option<T>> = (0..tasks.len()).map(|_| None).collect();
    thread::scope(|s| {
        for (task, slot) in tasks.into_iter().zip(out.iter_mut()) {
            s.spawn(move || *slot = Some(task()));
        }
        // scope join == barrier
    });
    out.into_iter()
        .map(|r| r.expect("superstep worker finished"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    fn tasks(n: usize) -> Vec<impl FnOnce() -> (usize, ThreadId) + Send> {
        (0..n)
            .map(|i| move || (i * i, thread::current().id()))
            .collect()
    }

    #[test]
    fn results_are_ordered_by_partition_index() {
        for mode in [ExecMode::Parallel, ExecMode::Sequential] {
            let got = superstep(mode, tasks(16));
            for (i, (val, _)) in got.iter().enumerate() {
                assert_eq!(*val, i * i, "{mode:?}");
            }
        }
    }

    #[test]
    fn parallel_mode_uses_distinct_os_threads() {
        let got = superstep(ExecMode::Parallel, tasks(4));
        let ids: HashSet<ThreadId> = got.iter().map(|(_, id)| *id).collect();
        assert!(ids.len() >= 2, "expected >= 2 worker threads, saw {}", ids.len());
        assert!(!ids.contains(&thread::current().id()));
    }

    #[test]
    fn sequential_mode_stays_on_the_caller() {
        let got = superstep(ExecMode::Sequential, tasks(4));
        for (_, id) in &got {
            assert_eq!(*id, thread::current().id());
        }
    }

    #[test]
    fn single_task_runs_inline_even_in_parallel_mode() {
        let got = superstep(ExecMode::Parallel, tasks(1));
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1, thread::current().id());
    }

    #[test]
    fn parallel_matches_sequential() {
        let a = superstep(ExecMode::Parallel, tasks(9));
        let b = superstep(ExecMode::Sequential, tasks(9));
        let va: Vec<usize> = a.into_iter().map(|(v, _)| v).collect();
        let vb: Vec<usize> = b.into_iter().map(|(v, _)| v).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn superstep_is_a_barrier() {
        // Every worker increments before superstep returns.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..8)
            .map(|_| {
                let c = &counter;
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        let _ = superstep(ExecMode::Parallel, tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn exec_mode_parse_roundtrip() {
        for m in [ExecMode::Parallel, ExecMode::Sequential] {
            assert_eq!(ExecMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("nope"), None);
    }
}
