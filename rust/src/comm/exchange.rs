//! Precomputed mirror/master exchange schedules (paper §5; ISSUE 4).
//!
//! The Gluon substrate's whole point is that boundary synchronization is
//! *structured*: which vertices a partition mirrors, and where each mirror's
//! master lives, is fixed at partition time. This module materializes that
//! structure once — dense index lists per (mirror-holder, owner) pair plus a
//! master-side fan-out CSR — so every BSP round drives reduce / broadcast by
//! walking flat arrays instead of the per-round `g2l` HashMap lookups and
//! freshly-allocated `changed: Vec<(u32, f32)>` payloads the coordinator
//! used before.
//!
//! Round protocol for the min-reduce apps (bfs / sssp / cc):
//!
//! 1. **Compute** — each partition relaxes locally; the bitmap frontier
//!    drains the changed local ids into its persistent
//!    [`PartState::changed`] buffer.
//! 2. **Reduce** ([`ExchangePlan::reduce_min`]) — changed ids seed an
//!    updated-bitmask; for every pair schedule, the *set* mirror positions
//!    ship their value to the master side (min-applied), and every shipped
//!    position marks the master's `master_updated` bit. Only touched
//!    boundary vertices cross the barrier — one `(local index, f32)` update
//!    each, [`BYTES_PER_UPDATE`] on the wire.
//! 3. **Broadcast** ([`ExchangePlan::broadcast_min`]) — updated masters
//!    push their value back along the same schedules; a mirror copy that is
//!    already current costs nothing. The same pass computes next round's
//!    frontier: every copy of an updated master with local out-edges.
//!
//! Determinism: schedules are walked in (partition, peer, position) order
//! and min is order-independent, so the exchange is bit-identical to the
//! pre-rebuild central-master reconciliation — asserted against the
//! preserved [`crate::coordinator::run_distributed_reference`] across every
//! input × policy × app by `rust/tests/parity.rs`.
//!
//! Zero allocation (DESIGN.md §8): plans are immutable after construction;
//! all per-round state ([`PartState`] buffers, bitmasks) is persistent and
//! capacity-reusing, so steady-state supersteps allocate nothing on the
//! submitting thread (`rust/tests/alloc.rs`).

use crate::partition::DistGraph;

use super::BYTES_PER_UPDATE;

/// A (src, dst, bytes) traffic flow, priced by [`super::NetworkModel`].
pub type Flow = (u32, u32, u64);

/// One (mirror-holder, owner) pair's dense exchange schedule: position `p`
/// pairs the holder-side mirror `mirror_locals[p]` with its master's local
/// id `master_locals[p]` on partition `peer`.
#[derive(Debug, Clone)]
pub struct MirrorSchedule {
    /// The owner partition these mirrors reduce to / refresh from.
    pub peer: u32,
    /// Holder-side local ids, ascending (mirrors sort by global id).
    pub mirror_locals: Vec<u32>,
    /// Owner-side master local ids, matching `mirror_locals` by position.
    pub master_locals: Vec<u32>,
}

impl MirrorSchedule {
    pub fn len(&self) -> usize {
        self.mirror_locals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mirror_locals.is_empty()
    }
}

/// One partition's precomputed exchange metadata.
#[derive(Debug, Clone)]
pub struct PartPlan {
    pub num_masters: usize,
    pub num_locals: usize,
    /// Mirrors this partition holds, grouped by owner, ascending peer id;
    /// under CVC the group count is bounded by the grid row/column sizes.
    pub mirrors: Vec<MirrorSchedule>,
    /// Bit `l` set when local vertex `l` has out-edges (activation filter).
    has_out: Vec<u64>,
    /// Master-side fan-out CSR: `fan_prefix[m]..fan_prefix[m + 1]` indexes
    /// `fan_peer` / `fan_mirror_local` — every remote copy of master `m`.
    fan_prefix: Vec<u32>,
    fan_peer: Vec<u32>,
    fan_mirror_local: Vec<u32>,
}

impl PartPlan {
    /// Remote copies of master local `m`, as (holder partition, local id
    /// there) pairs.
    pub fn fan_of(&self, m: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.fan_prefix[m as usize] as usize;
        let hi = self.fan_prefix[m as usize + 1] as usize;
        (lo..hi).map(move |i| (self.fan_peer[i], self.fan_mirror_local[i]))
    }
}

/// The whole cluster's exchange schedules, fixed at partition time.
#[derive(Debug, Clone)]
pub struct ExchangePlan {
    pub parts: Vec<PartPlan>,
    /// Global id -> local id within the owner partition.
    pub master_local: Vec<u32>,
    /// Owner partition of each global vertex.
    pub owner: Vec<u32>,
}

/// One partition's persistent exchange-side state: local labels plus the
/// reusable buffers and bitmasks each round's sync walks. All buffers keep
/// their capacity across rounds.
#[derive(Debug, Clone)]
pub struct PartState {
    /// Local labels, masters first (the authoritative values), mirrors
    /// after.
    pub labels: Vec<f32>,
    /// Current frontier (sorted local ids), rebuilt by the broadcast.
    pub active: Vec<u32>,
    /// Local ids whose label changed this round (sorted; filled by the
    /// compute task's bitmap-frontier drain).
    pub changed: Vec<u32>,
    /// Bitmask over locals: changed this round (reduce input).
    updated: Vec<u64>,
    /// Bitmask over masters: master value touched this round (broadcast
    /// input; the equivalent of the old coordinator's `touched` set).
    master_updated: Vec<u64>,
}

/// Anything that can hand the exchange its [`PartState`] — the coordinator
/// stores per-GPU compute scratch next to the exchange state in one struct
/// and implements this; plain `Vec<PartState>` works too (tests).
pub trait HasPartState {
    fn part_state(&mut self) -> &mut PartState;
}

impl HasPartState for PartState {
    fn part_state(&mut self) -> &mut PartState {
        self
    }
}

#[inline]
fn set_bit(words: &mut [u64], i: u32) {
    words[(i >> 6) as usize] |= 1u64 << (i & 63);
}

#[inline]
fn test_bit(words: &[u64], i: u32) -> bool {
    words[(i >> 6) as usize] & (1u64 << (i & 63)) != 0
}

/// Disjoint `&mut` access to two distinct slice elements.
fn pair_mut<S>(states: &mut [S], a: usize, b: usize) -> (&mut S, &mut S) {
    assert!(a != b, "exchange pair must span two partitions");
    if a < b {
        let (lo, hi) = states.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = states.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

impl ExchangePlan {
    /// Precompute every pair schedule and fan-out list from the partitioned
    /// graph. Runs once per distributed run, at partition time.
    pub fn new(dg: &DistGraph) -> ExchangePlan {
        let n = dg.num_global as usize;
        let k = dg.parts.len();
        let mut master_local = vec![0u32; n];
        for p in &dg.parts {
            for (l, &gid) in p.l2g[..p.num_masters].iter().enumerate() {
                master_local[gid as usize] = l as u32;
            }
        }
        let mut parts = Vec::with_capacity(k);
        // One k-sized grouping buffer shared by all partitions (a fresh one
        // per partition would cost O(k^2) Vec setups at the degenerate
        // k ~ |V| partition counts); entries are moved out per partition
        // and only the touched owners are visited.
        let mut by_owner: Vec<(Vec<u32>, Vec<u32>)> =
            vec![(Vec::new(), Vec::new()); k];
        let mut touched: Vec<usize> = Vec::new();
        for p in &dg.parts {
            // Group this partition's mirrors by owner; the l2g mirror
            // section is sorted by global id, so each group's locals come
            // out ascending.
            for (off, &gid) in p.l2g[p.num_masters..].iter().enumerate() {
                let l = (p.num_masters + off) as u32;
                let o = dg.owner[gid as usize] as usize;
                if by_owner[o].0.is_empty() {
                    touched.push(o);
                }
                by_owner[o].0.push(l);
                by_owner[o].1.push(master_local[gid as usize]);
            }
            touched.sort_unstable(); // schedules in ascending peer order
            let mirrors: Vec<MirrorSchedule> = touched
                .drain(..)
                .map(|o| {
                    let (mirror_locals, master_locals) =
                        std::mem::take(&mut by_owner[o]);
                    MirrorSchedule {
                        peer: o as u32,
                        mirror_locals,
                        master_locals,
                    }
                })
                .collect();
            let nl = p.l2g.len();
            let mut has_out = vec![0u64; nl.div_ceil(64)];
            for l in 0..nl as u32 {
                if p.graph.out_degree(l) > 0 {
                    set_bit(&mut has_out, l);
                }
            }
            parts.push(PartPlan {
                num_masters: p.num_masters,
                num_locals: nl,
                mirrors,
                has_out,
                fan_prefix: vec![0],
                fan_peer: Vec::new(),
                fan_mirror_local: Vec::new(),
            });
        }
        // Master-side fan-out CSR per owner, inverted from the schedules.
        // One bucketing pass groups each (holder, schedule) pair under its
        // owner, so construction is O(total mirrors + k), not a per-owner
        // rescan of every partition's schedule list (which would go
        // quadratic at the k ~ |V| degenerate partition counts).
        let mut scheds_by_owner: Vec<Vec<(u32, usize)>> = vec![Vec::new(); k];
        for (i, part) in parts.iter().enumerate() {
            for (si, sched) in part.mirrors.iter().enumerate() {
                scheds_by_owner[sched.peer as usize].push((i as u32, si));
            }
        }
        for (j, owner_scheds) in scheds_by_owner.into_iter().enumerate() {
            let nm = parts[j].num_masters;
            let mut prefix = vec![0u32; nm + 1];
            for &(i, si) in &owner_scheds {
                for &ml in &parts[i as usize].mirrors[si].master_locals {
                    prefix[ml as usize + 1] += 1;
                }
            }
            for m in 0..nm {
                prefix[m + 1] += prefix[m];
            }
            let total = prefix[nm] as usize;
            let mut fan_peer = vec![0u32; total];
            let mut fan_mirror_local = vec![0u32; total];
            let mut cursor = prefix.clone();
            // Holder partitions arrive in ascending order (the bucketing
            // pass runs i ascending), preserving the fan order the k-core
            // scatter's cycle parity relies on.
            for &(i, si) in &owner_scheds {
                let sched = &parts[i as usize].mirrors[si];
                for (p2, &ml) in sched.master_locals.iter().enumerate() {
                    let c = cursor[ml as usize] as usize;
                    fan_peer[c] = i;
                    fan_mirror_local[c] = sched.mirror_locals[p2];
                    cursor[ml as usize] += 1;
                }
            }
            parts[j].fan_prefix = prefix;
            parts[j].fan_peer = fan_peer;
            parts[j].fan_mirror_local = fan_mirror_local;
        }
        ExchangePlan {
            parts,
            master_local,
            owner: dg.owner.clone(),
        }
    }

    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Fresh per-partition exchange states with correctly-sized labels and
    /// bitmasks (labels start at 0.0; callers seed them).
    pub fn new_states(&self) -> Vec<PartState> {
        self.parts
            .iter()
            .map(|p| PartState {
                labels: vec![0.0; p.num_locals],
                active: Vec::new(),
                changed: Vec::new(),
                updated: vec![0; p.num_locals.div_ceil(64)],
                master_updated: vec![0; p.num_masters.div_ceil(64)],
            })
            .collect()
    }

    /// Reduce phase: ship every *changed* mirror value to its master and
    /// min it in; mark every touched master. Appends one flow per
    /// (holder, owner) pair with traffic and returns the total bytes.
    pub fn reduce_min<S: HasPartState>(
        &self,
        states: &mut [S],
        flows: &mut Vec<Flow>,
    ) -> u64 {
        // Seed the per-round bitmasks from the changed lists.
        for (i, s) in states.iter_mut().enumerate() {
            let nm = self.parts[i].num_masters as u32;
            let st = s.part_state();
            st.updated.fill(0);
            st.master_updated.fill(0);
            for &l in &st.changed {
                set_bit(&mut st.updated, l);
                if l < nm {
                    set_bit(&mut st.master_updated, l);
                }
            }
        }
        let mut total = 0u64;
        for i in 0..states.len() {
            for sched in &self.parts[i].mirrors {
                let j = sched.peer as usize;
                let (holder, owner) = pair_mut(states, i, j);
                let src = holder.part_state();
                let dst = owner.part_state();
                let mut count = 0u64;
                for (p, &ml) in sched.mirror_locals.iter().enumerate() {
                    if test_bit(&src.updated, ml) {
                        count += 1;
                        let val = src.labels[ml as usize];
                        let tl = sched.master_locals[p];
                        if val < dst.labels[tl as usize] {
                            dst.labels[tl as usize] = val;
                        }
                        // Touched even without improvement: every copy of a
                        // changed vertex must re-sync and re-activate.
                        set_bit(&mut dst.master_updated, tl);
                    }
                }
                if count > 0 {
                    let bytes = count * BYTES_PER_UPDATE;
                    flows.push((i as u32, sched.peer, bytes));
                    total += bytes;
                }
            }
        }
        total
    }

    /// Broadcast phase: updated masters push their value to every stale
    /// mirror copy (a copy that is already current costs nothing on the
    /// wire), and every copy of an updated master with local out-edges
    /// enters the next frontier. Fills each partition's sorted
    /// [`PartState::active`], appends per-pair flows, returns total bytes.
    pub fn broadcast_min<S: HasPartState>(
        &self,
        states: &mut [S],
        flows: &mut Vec<Flow>,
    ) -> u64 {
        // Masters re-activate themselves first (ascending bit scan).
        for (i, s) in states.iter_mut().enumerate() {
            let plan = &self.parts[i];
            let st = s.part_state();
            st.active.clear();
            for wi in 0..st.master_updated.len() {
                let mut word = st.master_updated[wi];
                let base = (wi as u32) << 6;
                while word != 0 {
                    let l = base + word.trailing_zeros();
                    if test_bit(&plan.has_out, l) {
                        st.active.push(l);
                    }
                    word &= word - 1;
                }
            }
        }
        let mut total = 0u64;
        for i in 0..states.len() {
            for sched in &self.parts[i].mirrors {
                let j = sched.peer as usize;
                let (holder, owner) = pair_mut(states, i, j);
                let hs = holder.part_state();
                let os = owner.part_state();
                let mut count = 0u64;
                for (p, &tl) in sched.master_locals.iter().enumerate() {
                    if test_bit(&os.master_updated, tl) {
                        let val = os.labels[tl as usize];
                        let m = sched.mirror_locals[p];
                        if val < hs.labels[m as usize] {
                            hs.labels[m as usize] = val;
                            count += 1;
                        }
                        if test_bit(&self.parts[i].has_out, m) {
                            hs.active.push(m);
                        }
                    }
                }
                if count > 0 {
                    let bytes = count * BYTES_PER_UPDATE;
                    flows.push((sched.peer, i as u32, bytes));
                    total += bytes;
                }
            }
            // Masters arrived ascending, then one ascending run per peer;
            // one sort restores global order (the sets are disjoint, so no
            // dedup is needed).
            states[i].part_state().active.sort_unstable();
        }
        total
    }

    /// Stage the reduce phase's per-pair messages **read-only** (ISSUE 8):
    /// for every (holder, owner) pair with traffic this round, the batch of
    /// `(mirror local id, label bits)` updates [`reduce_min`] would ship,
    /// serialized little-endian at [`BYTES_PER_UPDATE`] bytes per update.
    /// Partition state is untouched — `changed` is sorted (the compute
    /// task's bitmap-frontier drain), so membership is a binary search
    /// instead of seeding the `updated` bitmask. The guarded exchange
    /// checksums these payloads, injects link faults into scratch copies,
    /// and only after a clean attempt applies the real `reduce_min` /
    /// `broadcast_min` — which is why faulty runs stay bit-identical to
    /// fault-free ones.
    pub fn stage_reduce_messages<S: HasPartState>(
        &self,
        states: &mut [S],
    ) -> Vec<(u32, u32, Vec<u8>)> {
        let mut staged = Vec::new();
        for i in 0..states.len() {
            for sched in &self.parts[i].mirrors {
                let st = states[i].part_state();
                let mut payload = Vec::new();
                for &ml in &sched.mirror_locals {
                    if st.changed.binary_search(&ml).is_ok() {
                        payload.extend_from_slice(&ml.to_le_bytes());
                        payload.extend_from_slice(
                            &st.labels[ml as usize].to_bits().to_le_bytes(),
                        );
                    }
                }
                if !payload.is_empty() {
                    staged.push((i as u32, sched.peer, payload));
                }
            }
        }
        staged
    }

    /// Scatter a master-side event list (ascending global ids) to every
    /// local copy: the owner's master local plus each fan-out mirror.
    /// `out[i]` receives partition `i`'s local ids in `gids` order — the
    /// k-core driver's dense replacement for per-round `g2l` filtering.
    pub fn scatter_globals(&self, gids: &[u32], out: &mut [Vec<u32>]) {
        for o in out.iter_mut() {
            o.clear();
        }
        for &gid in gids {
            let j = self.owner[gid as usize] as usize;
            let ml = self.master_local[gid as usize];
            out[j].push(ml);
            for (peer, mirror_l) in self.parts[j].fan_of(ml) {
                out[peer as usize].push(mirror_l);
            }
        }
    }

    /// Constant per-pair flows of a topology-driven full mirror refresh
    /// (pagerank's broadcast: every mirror re-reads its owner's rank each
    /// round). Returns total bytes.
    pub fn mirror_refresh_flows(&self, flows: &mut Vec<Flow>) -> u64 {
        let mut total = 0u64;
        for (i, p) in self.parts.iter().enumerate() {
            for sched in &p.mirrors {
                let bytes = sched.len() as u64 * BYTES_PER_UPDATE;
                flows.push((sched.peer, i as u32, bytes));
                total += bytes;
            }
        }
        total
    }

    /// Total mirrors across the cluster (the full-refresh upper bound the
    /// updated-only exchange must never exceed per phase).
    pub fn total_mirrors(&self) -> usize {
        self.parts
            .iter()
            .map(|p| p.mirrors.iter().map(MirrorSchedule::len).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat::{self, RmatConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::{partition, Policy};

    fn test_graph() -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(8, 77)))
    }

    fn policies() -> [Policy; 3] {
        [Policy::Oec, Policy::Iec, Policy::Cvc]
    }

    #[test]
    fn every_mirror_scheduled_exactly_once_with_correct_master() {
        let g = test_graph();
        for policy in policies() {
            for k in [2u32, 3, 5] {
                let dg = partition(&g, k, policy);
                let plan = ExchangePlan::new(&dg);
                for (i, p) in dg.parts.iter().enumerate() {
                    let mut seen = vec![false; p.l2g.len()];
                    for sched in &plan.parts[i].mirrors {
                        let owner_part = &dg.parts[sched.peer as usize];
                        for (pos, &ml) in
                            sched.mirror_locals.iter().enumerate()
                        {
                            assert!(
                                !seen[ml as usize],
                                "{policy:?} k={k}: mirror scheduled twice"
                            );
                            seen[ml as usize] = true;
                            let gid = p.l2g[ml as usize];
                            assert_eq!(dg.owner[gid as usize], sched.peer);
                            // Matching master local resolves the same gid.
                            let tl = sched.master_locals[pos] as usize;
                            assert_eq!(owner_part.l2g[tl], gid);
                            assert!(tl < owner_part.num_masters);
                        }
                    }
                    let scheduled =
                        seen.iter().filter(|&&b| b).count();
                    assert_eq!(
                        scheduled,
                        p.num_mirrors(),
                        "{policy:?} k={k}: mirrors missed by the schedules"
                    );
                    assert!(
                        seen[..p.num_masters].iter().all(|&b| !b),
                        "{policy:?} k={k}: a master leaked into a schedule"
                    );
                }
            }
        }
    }

    #[test]
    fn fan_out_inverts_the_schedules() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Cvc);
        let plan = ExchangePlan::new(&dg);
        for (j, p) in dg.parts.iter().enumerate() {
            for m in 0..p.num_masters as u32 {
                let gid = p.l2g[m as usize];
                for (peer, mirror_l) in plan.parts[j].fan_of(m) {
                    assert_eq!(
                        dg.parts[peer as usize].l2g[mirror_l as usize],
                        gid
                    );
                }
                // Fan size equals the number of partitions mirroring gid.
                let holders = dg
                    .parts
                    .iter()
                    .filter(|q| {
                        q.id as usize != j
                            && q.mirror_globals().binary_search(&gid).is_ok()
                    })
                    .count();
                assert_eq!(plan.parts[j].fan_of(m).count(), holders);
            }
        }
    }

    #[test]
    fn scatter_globals_matches_g2l_filtering_in_order() {
        // The dense scatter must reproduce the old per-round g2l walk
        // EXACTLY, order included: for each partition, the local ids of
        // the listed globals in list order. The k-core driver's cycle
        // parity with the pre-rebuild reference depends on that order
        // (schedules are order-sensitive), so this compares unsorted.
        let g = test_graph();
        for policy in policies() {
            let dg = partition(&g, 3, policy);
            let plan = ExchangePlan::new(&dg);
            let n = g.num_vertices() as u32;
            let gids: Vec<u32> = (0..n).filter(|v| v % 7 == 0).collect();
            let mut out: Vec<Vec<u32>> = vec![Vec::new(); 3];
            plan.scatter_globals(&gids, &mut out);
            for (pi, got) in out.iter().enumerate() {
                let want: Vec<u32> = gids
                    .iter()
                    .filter_map(|gv| dg.g2l[pi].get(gv).copied())
                    .collect();
                assert_eq!(*got, want, "{policy:?} part {pi}");
            }
        }
    }

    #[test]
    fn reduce_broadcast_syncs_all_copies_to_the_minimum() {
        // Two-partition line graph under OEC: vertex in the middle is
        // mirrored; a lower mirror value must flow to the master and back
        // out to every copy, activating copies with out-edges.
        let mut el = EdgeList::new(8);
        for v in 0..7u32 {
            el.push(v, v + 1, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let dg = partition(&g, 2, Policy::Oec);
        let plan = ExchangePlan::new(&dg);
        assert!(plan.total_mirrors() > 0, "line graph must create mirrors");
        let mut states = plan.new_states();
        for (pi, st) in states.iter_mut().enumerate() {
            for (l, &gid) in dg.parts[pi].l2g.iter().enumerate() {
                st.labels[l] = 100.0 + gid as f32;
            }
        }
        // Pick any mirror on partition 0 or 1 and improve it locally.
        let (pi, sched) = (0..2)
            .find_map(|i| {
                plan.parts[i].mirrors.first().map(|s| (i, s.clone()))
            })
            .expect("some partition holds a mirror");
        let ml = sched.mirror_locals[0];
        let owner = sched.peer as usize;
        let tl = sched.master_locals[0] as usize;
        let gid = dg.parts[pi].l2g[ml as usize];
        states[pi].labels[ml as usize] = 5.0;
        states[pi].changed.push(ml);
        let mut flows = Vec::new();
        let reduced = plan.reduce_min(&mut states, &mut flows);
        assert_eq!(reduced, BYTES_PER_UPDATE);
        assert_eq!(states[owner].labels[tl], 5.0, "master must take the min");
        let bcast = plan.broadcast_min(&mut states, &mut flows);
        // Every copy of gid now reads 5.0; only stale copies paid bytes.
        for (qi, q) in dg.parts.iter().enumerate() {
            if let Some(l) = q.local_of(gid) {
                assert_eq!(states[qi].labels[l as usize], 5.0, "part {qi}");
                // Copies with out-edges are (exactly the) next frontier.
                let in_frontier =
                    states[qi].active.binary_search(&l).is_ok();
                assert_eq!(
                    in_frontier,
                    q.graph.out_degree(l) > 0,
                    "part {qi} activation"
                );
            } else {
                assert!(states[qi].active.is_empty());
            }
        }
        // The improving mirror is already current, so the updated-only
        // broadcast ships nothing back (the old full reconciliation also
        // charged zero here — only stale copies ever pay).
        assert_eq!(bcast, 0);
        // Per-phase traffic stays under the full-refresh volume.
        let full = plan.total_mirrors() as u64 * BYTES_PER_UPDATE;
        assert!(reduced <= full && bcast <= full);
    }

    #[test]
    fn staged_messages_mirror_reduce_flows_without_touching_state() {
        // The read-only staging pass must name exactly the pairs and byte
        // counts reduce_min will ship, and leave labels/frontiers alone.
        let g = test_graph();
        for policy in policies() {
            let dg = partition(&g, 3, policy);
            let plan = ExchangePlan::new(&dg);
            let mut states = plan.new_states();
            for (pi, st) in states.iter_mut().enumerate() {
                for (l, &gid) in dg.parts[pi].l2g.iter().enumerate() {
                    st.labels[l] = 50.0 + gid as f32;
                }
                // Mark every 5th local changed (sorted by construction).
                st.changed =
                    (0..dg.parts[pi].l2g.len() as u32).filter(|l| l % 5 == 0).collect();
            }
            let before: Vec<Vec<f32>> =
                states.iter().map(|s| s.labels.clone()).collect();
            let staged = plan.stage_reduce_messages(&mut states);
            for (pi, s) in states.iter().enumerate() {
                assert_eq!(s.labels, before[pi], "{policy:?}: staging mutated");
            }
            let mut flows = Vec::new();
            plan.reduce_min(&mut states, &mut flows);
            let reduce_pairs: Vec<(u32, u32, u64)> = flows.clone();
            let staged_pairs: Vec<(u32, u32, u64)> = staged
                .iter()
                .map(|(s, d, p)| (*s, *d, p.len() as u64))
                .collect();
            assert_eq!(staged_pairs, reduce_pairs, "{policy:?}");
            // Payloads decode back to the exact (local, label) updates.
            for (src, _, payload) in &staged {
                assert_eq!(payload.len() % BYTES_PER_UPDATE as usize, 0);
                for upd in payload.chunks_exact(8) {
                    let ml = u32::from_le_bytes(upd[..4].try_into().unwrap());
                    let bits = u32::from_le_bytes(upd[4..].try_into().unwrap());
                    assert_eq!(
                        f32::from_bits(bits),
                        before[*src as usize][ml as usize],
                        "{policy:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn unchanged_rounds_exchange_nothing() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Cvc);
        let plan = ExchangePlan::new(&dg);
        let mut states = plan.new_states();
        let mut flows = Vec::new();
        assert_eq!(plan.reduce_min(&mut states, &mut flows), 0);
        assert_eq!(plan.broadcast_min(&mut states, &mut flows), 0);
        assert!(flows.is_empty());
        assert!(states.iter().all(|s| s.active.is_empty()));
    }

    #[test]
    fn single_partition_plan_is_trivial() {
        let g = test_graph();
        let dg = partition(&g, 1, Policy::Cvc);
        let plan = ExchangePlan::new(&dg);
        assert_eq!(plan.num_parts(), 1);
        assert_eq!(plan.total_mirrors(), 0);
        assert!(plan.parts[0].mirrors.is_empty());
        let mut flows = Vec::new();
        assert_eq!(plan.mirror_refresh_flows(&mut flows), 0);
        assert!(flows.is_empty());
    }

    #[test]
    fn mirror_refresh_flows_cover_every_pair_once() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Cvc);
        let plan = ExchangePlan::new(&dg);
        let mut flows = Vec::new();
        let total = plan.mirror_refresh_flows(&mut flows);
        assert_eq!(
            total,
            plan.total_mirrors() as u64 * BYTES_PER_UPDATE
        );
        for &(src, dst, bytes) in &flows {
            assert_ne!(src, dst);
            assert!(bytes > 0);
        }
    }
}
