//! Gluon-like BSP communication substrate (paper §5; Dathathri et al. [8]).
//!
//! After each compute round the coordinator reconciles boundary vertices:
//! **reduce** — changed mirror values flow to the master (min for the
//! distance apps, sum for pagerank partials / kcore decrements) — then
//! **broadcast** — updated master values flow back to every mirror.
//!
//! The substrate also prices each round's traffic on a latency+bandwidth
//! network model with distinct intra-host (PCIe/NVLink-class) and
//! inter-host (Omni-Path-class) links, reproducing the Momentum (single
//! host) and Bridges (8 hosts x 2 GPUs) testbeds.
//!
//! [`bsp`] holds the superstep executor: per-GPU compute tasks dispatched
//! onto the shared [`crate::exec::Pool`] with an explicit barrier (the
//! pool's job-completion wait) before the reduce / broadcast phases run.
//!
//! [`exchange`] holds the precomputed mirror/master schedules (ISSUE 4):
//! dense per-pair index lists fixed at partition time that drive the
//! reduce / broadcast phases through persistent buffers and an
//! updated-only bitmask — no per-round `g2l` HashMap lookups, no per-round
//! payload allocation, and only touched boundary vertices on the wire.
//!
//! [`fault`] holds the deterministic fault-injection layer (ISSUE 8): a
//! seedable schedule of GPU deaths, checksummed-and-retried message
//! corruption/drops, and slow-link stalls, threaded through the
//! coordinator's faulty round loop.

pub mod bsp;
pub mod exchange;
pub mod fault;

pub use bsp::{superstep, superstep_mut, superstep_mut_masked, ExecMode};
pub use fault::{FaultEvent, FaultKind, FaultPlan, FaultSession};

/// Reduction operator applied at the master.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Keep the minimum (bfs/sssp/cc labels).
    Min,
    /// Accumulate (pagerank partial sums, kcore degree decrements).
    Sum,
}

/// Latency/bandwidth model per link class, in simulated GPU cycles.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// GPUs per host: pairs within a host use the intra link.
    pub gpus_per_host: u32,
    /// Per-round fixed latency for any intra-host exchange.
    pub intra_alpha_cycles: u64,
    /// Bytes per cycle on the intra-host link.
    pub intra_bytes_per_cycle: f64,
    pub inter_alpha_cycles: u64,
    pub inter_bytes_per_cycle: f64,
}

impl NetworkModel {
    /// Momentum-like: 6 GPUs in one box (PCIe-class links only).
    ///
    /// Per-round fixed latencies (alpha) are scaled down by the same factor
    /// as the bundled inputs, exactly like `CostModel::cycles_launch`
    /// (DESIGN.md §5): what must be preserved is the latency:work ratio,
    /// else round-synchronization cost swamps the scaled-down compute and
    /// hides the comp-side effects Figures 7/10/11 exist to show.
    /// Bandwidth terms are left unscaled — traffic volume shrinks with the
    /// inputs by itself.
    pub fn single_host() -> Self {
        NetworkModel {
            gpus_per_host: u32::MAX,
            intra_alpha_cycles: 100,
            intra_bytes_per_cycle: 12.0,
            inter_alpha_cycles: 0,
            inter_bytes_per_cycle: f64::INFINITY,
        }
    }

    /// Bridges-like: 2 GPUs per host, Omni-Path between hosts.
    pub fn cluster(gpus_per_host: u32) -> Self {
        NetworkModel {
            gpus_per_host,
            intra_alpha_cycles: 100,
            intra_bytes_per_cycle: 12.0,
            inter_alpha_cycles: 500,
            inter_bytes_per_cycle: 3.0,
        }
    }

    /// Are GPUs `a` and `b` on the same host?
    #[inline]
    pub fn same_host(&self, a: u32, b: u32) -> bool {
        a / self.gpus_per_host == b / self.gpus_per_host
    }

    /// Split a flow list's traffic into (intra-host, inter-host) byte
    /// totals — the wire-volume view of a round, surfaced per round in
    /// `DistRoundRecord` and totaled in `DistRunResult` / the CLI JSON.
    /// Self-flows and empty flows carry nothing, exactly as
    /// [`round_cycles`](Self::round_cycles) prices them.
    pub fn split_bytes(&self, flows: &[(u32, u32, u64)]) -> (u64, u64) {
        let (mut intra, mut inter) = (0u64, 0u64);
        for &(src, dst, bytes) in flows {
            if src == dst || bytes == 0 {
                continue;
            }
            if self.same_host(src, dst) {
                intra += bytes;
            } else {
                inter += bytes;
            }
        }
        (intra, inter)
    }

    /// Price one BSP exchange described by per-(src, dst) byte counts.
    /// The round's comm time is the bottleneck GPU's traffic per class,
    /// plus one latency term per class in use (messages within a round are
    /// batched, as Gluon does).
    pub fn round_cycles(&self, flows: &[(u32, u32, u64)]) -> u64 {
        if flows.is_empty() {
            return 0;
        }
        let ngpu = flows
            .iter()
            .map(|&(a, b, _)| a.max(b) + 1)
            .max()
            .unwrap_or(1) as usize;
        let mut intra = vec![0u64; ngpu]; // per-GPU intra-host bytes
        let mut inter = vec![0u64; ngpu];
        let (mut any_intra, mut any_inter) = (false, false);
        for &(src, dst, bytes) in flows {
            if src == dst || bytes == 0 {
                continue;
            }
            if self.same_host(src, dst) {
                intra[src as usize] += bytes;
                intra[dst as usize] += bytes;
                any_intra = true;
            } else {
                inter[src as usize] += bytes;
                inter[dst as usize] += bytes;
                any_inter = true;
            }
        }
        let mut cycles = 0u64;
        if any_intra {
            let worst = *intra.iter().max().unwrap();
            cycles += self.intra_alpha_cycles
                + (worst as f64 / self.intra_bytes_per_cycle) as u64;
        }
        if any_inter {
            let worst = *inter.iter().max().unwrap();
            cycles += self.inter_alpha_cycles
                + (worst as f64 / self.inter_bytes_per_cycle) as u64;
        }
        cycles
    }
}

/// Apply the reduce operator.
#[inline]
pub fn reduce(op: ReduceOp, master: f32, mirror: f32) -> f32 {
    match op {
        ReduceOp::Min => master.min(mirror),
        ReduceOp::Sum => master + mirror,
    }
}

/// Bytes on the wire for one vertex update (global id + f32 value).
pub const BYTES_PER_UPDATE: u64 = 8;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_ops() {
        assert_eq!(reduce(ReduceOp::Min, 3.0, 5.0), 3.0);
        assert_eq!(reduce(ReduceOp::Min, 5.0, 3.0), 3.0);
        assert_eq!(reduce(ReduceOp::Sum, 2.0, 3.5), 5.5);
    }

    #[test]
    fn same_host_classification() {
        let net = NetworkModel::cluster(2);
        assert!(net.same_host(0, 1));
        assert!(!net.same_host(1, 2));
        assert!(net.same_host(14, 15));
        let single = NetworkModel::single_host();
        assert!(single.same_host(0, 5));
    }

    #[test]
    fn empty_round_is_free() {
        assert_eq!(NetworkModel::cluster(2).round_cycles(&[]), 0);
        assert_eq!(NetworkModel::cluster(2).round_cycles(&[(0, 0, 100)]), 0);
    }

    #[test]
    fn inter_host_costs_more_than_intra() {
        let net = NetworkModel::cluster(2);
        let intra = net.round_cycles(&[(0, 1, 1 << 20)]);
        let inter = net.round_cycles(&[(0, 2, 1 << 20)]);
        assert!(inter > 2 * intra, "inter {inter} intra {intra}");
    }

    #[test]
    fn bottleneck_gpu_sets_the_time() {
        let net = NetworkModel::cluster(8);
        // GPU 0 receives from 3 peers; spread vs concentrated.
        let spread = net.round_cycles(&[(1, 0, 1000), (2, 3, 1000), (4, 5, 1000)]);
        let hot = net.round_cycles(&[(1, 0, 1000), (2, 0, 1000), (3, 0, 1000)]);
        assert!(hot > spread);
    }

    #[test]
    fn split_bytes_classifies_by_host() {
        let net = NetworkModel::cluster(2);
        let flows = [
            (0u32, 1u32, 100u64), // same host
            (0, 2, 40),           // cross host
            (3, 3, 999),          // self: free
            (1, 0, 0),            // empty: free
        ];
        assert_eq!(net.split_bytes(&flows), (100, 40));
        let single = NetworkModel::single_host();
        assert_eq!(single.split_bytes(&flows), (140, 0));
    }

    #[test]
    fn more_bytes_more_cycles() {
        let net = NetworkModel::single_host();
        let a = net.round_cycles(&[(0, 1, 1 << 10)]);
        let b = net.round_cycles(&[(0, 1, 1 << 24)]);
        assert!(b > a);
    }
}
