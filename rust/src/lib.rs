//! # alb-graph — Adaptive Load Balancer for Graph Analytics
//!
//! A from-scratch reproduction of *"An Adaptive Load Balancer For Graph
//! Analytical Applications on GPUs"* (Jatala et al., 2019) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's contribution — the **ALB** inspector/executor that detects
//! thread-block load imbalance at runtime and redistributes the edges of
//! *huge*-degree vertices cyclically across all thread blocks — lives in
//! [`lb::alb`]. Everything it needs is built here too:
//!
//! * [`graph`] — CSR substrate, RMAT / road / power-law generators, props, I/O;
//! * [`gpu`] — the SIMT execution-model simulator (blocks, warps, threads,
//!   set-associative cache, cycle cost model) that substitutes for the
//!   paper's K80/GTX1080/P100 GPUs;
//! * [`lb`] — every load-balancing strategy the paper evaluates (vertex,
//!   edge, TWC, Gunrock-style static LB) plus ALB itself;
//! * [`apps`] — bfs, sssp, cc, pagerank, k-core with the round engine;
//! * [`campaign`] — the scenario-matrix campaign runner behind `alb sweep`:
//!   declarative spec, deterministic cell enumeration, resumable execution,
//!   and the `CAMPAIGN.json` artifact with per-cell labels-hashes;
//! * [`partition`] — CuSP-like OEC / IEC / CVC partitioning;
//! * [`exec`] — the shared worker pool (std-only) that parallelizes the
//!   simulation itself: kernel block/warp walks, the ALB inspector's probe
//!   pass, and the per-GPU BSP tasks all run as chunked tasks on one pool;
//! * [`comm`] — Gluon-like BSP reduce/broadcast with a network cost model,
//!   the superstep executor ([`comm::bsp`]) that dispatches one task per
//!   simulated GPU onto the shared pool and barriers before each sync
//!   phase, and the precomputed mirror/master exchange schedules
//!   ([`comm::exchange`]) that drive reduce/broadcast through persistent
//!   buffers with an updated-only bitmask;
//! * [`coordinator`] — the multi-GPU (and multi-host) driver: parallel per
//!   round, bit-identical to its sequential reference mode;
//! * [`runtime`] — the PJRT client that loads the AOT-compiled JAX/Pallas
//!   kernels (`artifacts/*.hlo.txt`) onto the request path (behind the
//!   `xla` cargo feature; an API-identical stub is built otherwise);
//! * [`session`] — the unified execution API: a [`Session`] owns graph +
//!   pool + scratch arenas and serves typed [`RunRequest`]/[`RunReply`]
//!   queries; the CLI, the campaign runner, and the serve daemon all
//!   execute through it (DESIGN.md §16);
//! * [`serve`] — the `alb serve` daemon: concurrent analytics queries over
//!   line-delimited JSON on TCP, with admission control, same-key request
//!   coalescing, and an LRU result cache;
//! * [`analysis`] — the `alb lint` static analyzer: machine-checked repo
//!   invariants (determinism, unsafe discipline, twin coverage, message
//!   consistency) enforced in tier-1 and in CI;
//! * [`metrics`], [`config`] — reporting and run configuration.
//!
//! The crate builds from the repository-root `Cargo.toml` (library and
//! `alb` binary here under `rust/`, benches under `benches/`, examples
//! under `examples/`, with the offline `anyhow` shim in `vendor/`).
//!
//! See `DESIGN.md` (repository root) for the paper → module map and
//! build/run instructions, and `EXPERIMENTS.md` for how every table and
//! figure is regenerated and recorded.

pub mod analysis;
pub mod apps;
pub mod campaign;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod gpu;
pub mod graph;
pub mod lb;
pub mod metrics;
pub mod partition;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod session;

// The documented public request surface (DESIGN.md §16): construct a
// `Session`, describe a query as a `RunRequest`, get a `RunReply` whose
// `labels_hash` is bit-identical across transports (library call, `alb
// run`, `alb serve`).
pub use session::{ClusterRequest, DistReply, RunReply, RunRequest, Session};
