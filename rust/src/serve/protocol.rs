//! The `alb serve` wire protocol: line-delimited JSON over TCP
//! (DESIGN.md §16).
//!
//! Each request is one JSON object on one line; each reply is one JSON
//! object on one line ([`crate::metrics::Json::to_string_compact`], whose
//! sorted-key output makes replies byte-deterministic — the property the
//! cache byte-identity test in `rust/tests/serve.rs` pins). The vendored
//! crate set has no serde, so this module carries a small recursive-descent
//! JSON reader for *inbound* text (the outbound side reuses
//! [`crate::metrics::Json`]). Malformed input is a structured error reply,
//! never a panic: the daemon's shared session must survive any byte
//! sequence a client sends.

use std::collections::BTreeMap;

use crate::apps::{App, APP_NAMES};
use crate::lb::{Balancer, BALANCER_NAMES};
use crate::metrics::Json;

/// Hard cap on one request line. Longer lines get a structured error and
/// the connection is closed (the stream cannot be resynchronized once a
/// line is abandoned mid-read).
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Nesting depth cap for inbound JSON — requests are flat objects, so any
/// deeply nested payload is hostile; the cap keeps the recursive reader off
/// unbounded stacks.
const MAX_DEPTH: usize = 16;

/// Every field a query request may carry, for error messages that name the
/// full valid set (lint rule C001's contract, applied to the wire).
pub const REQUEST_FIELDS: &str =
    "op, app, source, balancer, direction_opt, delta, pr_tol, kcore_k, \
     max_rounds, k, vertex, id";

/// A parsed JSON value (inbound only).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Re-encode for echoing (request `id`s ride back on the reply).
    pub fn to_json(&self) -> Json {
        match self {
            Value::Null => Json::Null,
            Value::Bool(b) => Json::Bool(*b),
            Value::Num(x) => Json::Num(*x),
            Value::Str(s) => Json::Str(s.clone()),
            Value::Arr(xs) => Json::Arr(xs.iter().map(Value::to_json).collect()),
            Value::Obj(m) => Json::Obj(
                m.iter().map(|(k, v)| (k.clone(), v.to_json())).collect(),
            ),
        }
    }
}

/// Parse one line of JSON. Errors are short human-readable strings that the
/// server wraps into structured error replies.
pub fn parse_json(s: &str) -> Result<Value, String> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos, 0)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing bytes after JSON value at offset {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\r' | b'\n') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Value, String> {
    if depth > MAX_DEPTH {
        return Err(format!("JSON nested deeper than {MAX_DEPTH} levels"));
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Value::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let Value::Str(key) = parse_value(b, pos, depth + 1)? else {
                    return Err(format!("object key at offset {} is not a string", *pos));
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at offset {}", *pos));
                }
                *pos += 1;
                let v = parse_value(b, pos, depth + 1)?;
                m.insert(key, v);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Value::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at offset {}", *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Value::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Value::Arr(xs));
                    }
                    _ => return Err(format!("expected ',' or ']' at offset {}", *pos)),
                }
            }
        }
        Some(b'"') => parse_string(b, pos).map(Value::Str),
        Some(b't') if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Value::Bool(true))
        }
        Some(b'f') if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Value::Bool(false))
        }
        Some(b'n') if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Value::Null)
        }
        Some(_) => parse_number(b, pos).map(Value::Num),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex)
                            .map_err(|_| "non-ascii \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape \\u{hex}"))?;
                        // Surrogates are rejected rather than paired — no
                        // request field legitimately needs astral-plane
                        // escapes, and a wrong pairing would corrupt ids.
                        let c = char::from_u32(cp)
                            .ok_or_else(|| format!("invalid codepoint \\u{hex}"))?;
                        out.push(c);
                        *pos += 4;
                    }
                    _ => return Err("bad escape in string".to_string()),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => {
                return Err("unescaped control byte in string".to_string())
            }
            Some(_) => {
                // Multi-byte UTF-8 passes through verbatim; the line was
                // already validated as UTF-8 before parsing.
                let start = *pos;
                while *pos < b.len()
                    && b[*pos] != b'"'
                    && b[*pos] != b'\\'
                    && b[*pos] >= 0x20
                {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?,
                );
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<f64, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    if start == *pos {
        return Err(format!("expected a JSON value at offset {start}"));
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number".to_string())?;
    let x: f64 = s.parse().map_err(|_| format!("bad number {s}"))?;
    if !x.is_finite() {
        return Err(format!("non-finite number {s}"));
    }
    Ok(x)
}

// ------------------------------------------------------------- requests

/// One decoded request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query(Box<QueryRequest>),
    /// Server counter snapshot (`{"op":"stats"}`) — how the soak test
    /// observes coalescing and cache hits.
    Stats,
}

/// A decoded analytics query. `None` fields defer to the session defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRequest {
    pub app: App,
    pub source: Option<u32>,
    pub balancer: Option<Balancer>,
    pub direction_opt: Option<bool>,
    pub delta: Option<f32>,
    pub pr_tol: Option<f32>,
    pub kcore_k: Option<u32>,
    pub max_rounds: Option<u32>,
    /// PageRank top-k size (presentation only — not part of the result
    /// cache key).
    pub topk: u32,
    /// Optional per-vertex lookup (distance / rank / membership).
    pub vertex: Option<u32>,
    /// Opaque client correlation id, echoed on the reply.
    pub id: Option<Value>,
}

/// Default / maximum PageRank top-k sizes.
pub const DEFAULT_TOPK: u32 = 10;
pub const MAX_TOPK: u32 = 1024;

fn get_u32(v: &Value, field: &str, max: u32) -> Result<u32, String> {
    match v {
        Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= max as f64 => {
            Ok(*x as u32)
        }
        _ => Err(format!(
            "bad {field} {}; valid values: integers in 0..={max}",
            describe(v)
        )),
    }
}

fn get_f32_pos(v: &Value, field: &str) -> Result<f32, String> {
    match v {
        Value::Num(x) if *x > 0.0 && (*x as f32).is_finite() => Ok(*x as f32),
        _ => Err(format!(
            "bad {field} {}; valid values: finite numbers > 0",
            describe(v)
        )),
    }
}

fn describe(v: &Value) -> String {
    match v {
        Value::Null => "null".to_string(),
        Value::Bool(b) => b.to_string(),
        Value::Num(x) => x.to_string(),
        Value::Str(s) => format!("{s:?}"),
        Value::Arr(_) => "<array>".to_string(),
        Value::Obj(_) => "<object>".to_string(),
    }
}

/// Decode one request line into a [`Request`]. Every rejection names the
/// full valid set for the offending field.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let Value::Obj(m) = parse_json(line)? else {
        return Err(format!(
            "request must be a JSON object; valid fields: {REQUEST_FIELDS}"
        ));
    };
    match m.get("op") {
        None => {}
        Some(Value::Str(op)) if op == "query" => {}
        Some(Value::Str(op)) if op == "stats" => {
            return Ok(Request::Stats);
        }
        Some(v) => {
            return Err(format!(
                "unknown op {}; valid values: query, stats",
                describe(v)
            ))
        }
    }
    // Strict field set: a typo'd key must fail loudly, not silently run a
    // different query than the client intended.
    for key in m.keys() {
        if !matches!(
            key.as_str(),
            "op" | "app"
                | "source"
                | "balancer"
                | "direction_opt"
                | "delta"
                | "pr_tol"
                | "kcore_k"
                | "max_rounds"
                | "k"
                | "vertex"
                | "id"
        ) {
            return Err(format!(
                "unknown request field {key:?}; valid fields: {REQUEST_FIELDS}"
            ));
        }
    }
    let app = match m.get("app") {
        Some(Value::Str(name)) => App::parse(name).ok_or_else(|| {
            format!("unknown app {name:?}; valid values: {APP_NAMES}")
        })?,
        Some(v) => {
            return Err(format!(
                "bad app {}; valid values: {APP_NAMES}",
                describe(v)
            ))
        }
        None => return Err(format!("missing app; valid values: {APP_NAMES}")),
    };
    let balancer = match m.get("balancer") {
        None => None,
        Some(Value::Str(name)) => Some(Balancer::parse(name).ok_or_else(|| {
            format!(
                "unknown balancer {name:?}; valid values: {}",
                BALANCER_NAMES.join(", ")
            )
        })?),
        Some(v) => {
            return Err(format!(
                "bad balancer {}; valid values: {}",
                describe(v),
                BALANCER_NAMES.join(", ")
            ))
        }
    };
    let direction_opt = match m.get("direction_opt") {
        None => None,
        Some(Value::Bool(b)) => Some(*b),
        Some(v) => {
            return Err(format!(
                "bad direction_opt {}; valid values: true, false",
                describe(v)
            ))
        }
    };
    let q = QueryRequest {
        app,
        source: m.get("source").map(|v| get_u32(v, "source", u32::MAX - 1)).transpose()?,
        balancer,
        direction_opt,
        delta: m.get("delta").map(|v| get_f32_pos(v, "delta")).transpose()?,
        pr_tol: m.get("pr_tol").map(|v| get_f32_pos(v, "pr_tol")).transpose()?,
        kcore_k: m.get("kcore_k").map(|v| get_u32(v, "kcore_k", u32::MAX - 1)).transpose()?,
        max_rounds: m
            .get("max_rounds")
            .map(|v| get_u32(v, "max_rounds", u32::MAX - 1))
            .transpose()?,
        topk: match m.get("k") {
            None => DEFAULT_TOPK,
            Some(v) => {
                let k = get_u32(v, "k", MAX_TOPK)?;
                if k == 0 {
                    return Err(format!(
                        "bad k 0; valid values: integers in 1..={MAX_TOPK}"
                    ));
                }
                k
            }
        },
        vertex: m.get("vertex").map(|v| get_u32(v, "vertex", u32::MAX - 1)).transpose()?,
        id: m.get("id").cloned(),
    };
    if q.max_rounds == Some(0) {
        return Err("bad max_rounds 0; valid values: integers >= 1".to_string());
    }
    Ok(Request::Query(Box::new(q)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_query() {
        let r = parse_request(r#"{"app":"bfs","source":5,"max_rounds":100}"#).unwrap();
        let Request::Query(q) = r else { panic!("not a query") };
        assert_eq!(q.app, App::Bfs);
        assert_eq!(q.source, Some(5));
        assert_eq!(q.max_rounds, Some(100));
        assert_eq!(q.topk, DEFAULT_TOPK);
    }

    #[test]
    fn stats_op() {
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
    }

    #[test]
    fn rejections_name_the_valid_set() {
        for (line, needle) in [
            (r#"{"app":"zzz"}"#, "valid values"),
            (r#"{"source":1}"#, "missing app"),
            (r#"{"app":"bfs","wat":1}"#, "valid fields"),
            (r#"{"app":"bfs","source":-1}"#, "valid values"),
            (r#"{"app":"bfs","balancer":"nope"}"#, "valid values"),
            (r#"{"app":"pr","k":0}"#, "1..="),
            (r#"{"app":"bfs","max_rounds":0}"#, ">= 1"),
            (r#"[1,2]"#, "valid fields"),
            (r#"{"op":"frobnicate"}"#, "query, stats"),
        ] {
            let err = parse_request(line).unwrap_err();
            assert!(err.contains(needle), "{line} -> {err}");
        }
    }

    #[test]
    fn malformed_json_is_an_error_not_a_panic() {
        for line in [
            "",
            "{",
            "{\"a\"",
            "nope",
            "{\"a\":}",
            "\u{1}",
            "{\"s\":\"unterminated",
            "[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[[1]]]]]",
            "{\"x\":1e999}",
        ] {
            assert!(parse_json(line).is_err(), "{line:?} must not parse");
        }
    }

    #[test]
    fn value_roundtrips_to_json() {
        let v = parse_json(r#"{"id":[1,"a",true,null]}"#).unwrap();
        assert_eq!(
            v.to_json().to_string_compact(),
            r#"{"id":[1,"a",true,null]}"#
        );
    }
}
