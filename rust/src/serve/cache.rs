//! The serve-layer result cache (DESIGN.md §16): a small LRU keyed by the
//! canonical query identity string, storing `Arc`-shared [`RunReply`]s
//! (crate::session::RunReply).
//!
//! std has no LRU container, so this one is built on a `BTreeMap` plus a
//! logical tick counter: every hit/insert stamps the entry with the next
//! tick, and eviction removes the minimum-tick entry. `BTreeMap` keeps
//! iteration order deterministic (lint rule D002 bans `HashMap` iteration
//! in `rust/src/`), and the tick is logical time, not wall time — rule D001
//! bans `Instant` here, and the cache stays bit-deterministic under replay.

use std::collections::BTreeMap;

/// LRU with a fixed capacity. `capacity == 0` disables caching entirely
/// (every `get` misses, every `insert` is dropped) — the serve flag
/// `--cache-entries 0` maps to this.
#[derive(Debug)]
pub struct LruCache<V> {
    capacity: usize,
    tick: u64,
    map: BTreeMap<String, (u64, V)>,
}

impl<V: Clone> LruCache<V> {
    pub fn new(capacity: usize) -> LruCache<V> {
        LruCache { capacity, tick: 0, map: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<V> {
        let entry = self.map.get_mut(key)?;
        self.tick += 1;
        entry.0 = self.tick;
        Some(entry.1.clone())
    }

    /// Insert (or refresh) `key`, evicting the least-recently-used entry if
    /// the cache is full.
    pub fn insert(&mut self, key: &str, value: V) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if let Some(entry) = self.map.get_mut(key) {
            *entry = (self.tick, value);
            return;
        }
        if self.map.len() >= self.capacity {
            // Evict the stalest entry. Ties are impossible: ticks are
            // unique per stamp.
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = victim {
                self.map.remove(&k);
            }
        }
        self.map.insert(key.to_string(), (self.tick, value));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_refreshes_recency() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert_eq!(c.get("a"), Some(1)); // a is now fresher than b
        c.insert("c", 3); // evicts b
        assert_eq!(c.get("b"), None);
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.get("c"), Some(3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn insert_existing_key_updates_value_without_evicting() {
        let mut c = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        c.insert("a", 10);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get("a"), Some(10));
        assert_eq!(c.get("b"), Some(2));
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = LruCache::new(0);
        c.insert("a", 1);
        assert!(c.is_empty());
        assert_eq!(c.get("a"), None::<i32>);
    }

    #[test]
    fn eviction_order_is_strict_lru() {
        let mut c = LruCache::new(3);
        for (k, v) in [("a", 1), ("b", 2), ("c", 3)] {
            c.insert(k, v);
        }
        c.get("a");
        c.get("b");
        c.insert("d", 4); // c is stalest
        assert_eq!(c.get("c"), None);
        assert_eq!(c.len(), 3);
    }
}
