//! `alb serve` — a multi-tenant graph-query daemon (DESIGN.md §16).
//!
//! One [`Server`] owns one [`Session`] (one immutable prepared graph + the
//! shared worker pool) and answers concurrent analytics queries — BFS/SSSP
//! from arbitrary sources, PageRank top-k, k-core membership — over
//! line-delimited JSON on TCP ([`protocol`]). Three mechanisms sit between
//! the socket and the session:
//!
//! * **Admission control** — at most `max_inflight` queries execute at
//!   once; later arrivals block on a condvar-guarded counter (a semaphore;
//!   std has none). A per-query `max_rounds` budget bounds each admitted
//!   run, so one runaway query cannot wedge a slot forever.
//! * **Coalescing** — requests that resolve to the same canonical identity
//!   while one is already executing join its in-flight *flight* and all
//!   receive the one result, so a thundering herd on a hot source costs
//!   one execution.
//! * **Result cache** — an LRU ([`cache::LruCache`]) keyed by the same
//!   identity string serves repeats without touching the pool at all.
//!
//! The identity key is derived from the *effective* engine configuration
//! (after session defaults and `Balancer::Auto` resolution), never from the
//! raw request text — two spellings of the same query share one cache line.
//! Presentation fields (`k`, `vertex`, `id`) are rendered from the cached
//! labels and are deliberately not part of the key.
//!
//! Determinism: replies are rendered with sorted-key compact JSON, so a
//! cache hit is byte-identical to the cold reply except for the `cache`
//! status field, and a served `labels_hash` is bit-identical to `alb run`
//! on the same query — both properties are pinned by `rust/tests/serve.rs`.
//! The module uses no wall clock and no `unsafe` (lint rules D001/U002).

pub mod cache;
pub mod protocol;

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::apps::{App, INF};
use crate::metrics::Json;
use crate::session::{RunReply, RunRequest, Session, SCHEMA_VERSION};

use cache::LruCache;
use protocol::{QueryRequest, Request, Value, MAX_LINE_BYTES};

/// Serving knobs; the graph itself arrives as a prepared [`Session`].
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Maximum queries executing concurrently (admission slots).
    pub max_inflight: usize,
    /// LRU result-cache capacity; 0 disables the cache.
    pub cache_entries: usize,
    /// Per-query round-budget ceiling: requests may ask for less, never
    /// more, and requests that omit `max_rounds` get exactly this.
    pub max_rounds: u32,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { max_inflight: 4, cache_entries: 64, max_rounds: 1_000_000 }
    }
}

/// Monotonic service counters, exposed on the `stats` op. `queries` counts
/// well-formed query requests; exactly one of `executed` / `cache_hits` /
/// `coalesced` is incremented per successful query, so
/// `executed + cache_hits + coalesced == queries - failed` always holds —
/// the soak test's core invariant.
#[derive(Debug, Default)]
pub struct Counters {
    pub queries: AtomicU64,
    pub executed: AtomicU64,
    pub cache_hits: AtomicU64,
    pub coalesced: AtomicU64,
    pub errors: AtomicU64,
}

/// One in-flight execution that same-key arrivals can join.
struct Flight {
    slot: Mutex<Option<Result<Arc<RunReply>, String>>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, r: Result<Arc<RunReply>, String>) {
        *self.slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<Arc<RunReply>, String> {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        while slot.is_none() {
            slot = self.cv.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
        slot.clone().expect("flight published")
    }
}

/// The daemon: session + cache + flights + admission state. All methods
/// take `&self`; one `Server` is shared by every connection thread.
pub struct Server {
    session: Session,
    opts: ServeOpts,
    cache: Mutex<LruCache<Arc<RunReply>>>,
    flights: Mutex<BTreeMap<String, Arc<Flight>>>,
    inflight: Mutex<usize>,
    admit_cv: Condvar,
    counters: Counters,
    stop: AtomicBool,
}

impl Server {
    pub fn new(session: Session, opts: ServeOpts) -> Server {
        let cache = Mutex::new(LruCache::new(opts.cache_entries));
        Server {
            session,
            opts,
            cache,
            flights: Mutex::new(BTreeMap::new()),
            inflight: Mutex::new(0),
            admit_cv: Condvar::new(),
            counters: Counters::default(),
            stop: AtomicBool::new(false),
        }
    }

    pub fn session(&self) -> &Session {
        &self.session
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Bind `127.0.0.1:port` (0 = ephemeral) and serve on a background
    /// accept thread. The returned handle owns shutdown.
    pub fn spawn(session: Session, opts: ServeOpts, port: u16) -> Result<ServerHandle> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("failed to bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr()?;
        let server = Arc::new(Server::new(session, opts));
        let srv = Arc::clone(&server);
        let accept = std::thread::spawn(move || accept_loop(&srv, &listener));
        Ok(ServerHandle { addr, server, accept: Some(accept) })
    }

    /// Process one request line into one reply line (no trailing newline).
    /// This is the whole protocol: the TCP layer above only frames lines.
    pub fn handle_line(&self, line: &str) -> String {
        match protocol::parse_request(line) {
            Err(e) => {
                self.counters.errors.fetch_add(1, Ordering::SeqCst);
                render_error(&e, None)
            }
            Ok(Request::Stats) => self.render_stats(),
            Ok(Request::Query(q)) => {
                self.counters.queries.fetch_add(1, Ordering::SeqCst);
                match self.run_query(&q) {
                    Ok((reply, status)) => self.render_reply(&q, &reply, status),
                    Err(e) => {
                        self.counters.errors.fetch_add(1, Ordering::SeqCst);
                        render_error(&e, q.id.as_ref())
                    }
                }
            }
        }
    }

    /// Resolve, admit, and execute (or short-circuit) one query. The
    /// returned status is the reply's `cache` field: `miss` | `hit` |
    /// `coalesced`.
    fn run_query(&self, q: &QueryRequest) -> Result<(Arc<RunReply>, &'static str), String> {
        if let Some(m) = q.max_rounds {
            if m > self.opts.max_rounds {
                return Err(format!(
                    "max_rounds {m} exceeds the per-query budget; \
                     valid values: 1..={}",
                    self.opts.max_rounds
                ));
            }
        }
        let n = self.session.num_vertices() as u32;
        if let Some(v) = q.vertex {
            if v >= n {
                return Err(format!(
                    "vertex {v} is out of range for {} ({n} vertices); \
                     valid values: 0..={}",
                    self.session.input(),
                    n.saturating_sub(1)
                ));
            }
        }
        let req = self.to_run_request(q);
        let source = self.session.resolve_source(&req).map_err(|e| e.to_string())?;
        let key = self.query_key(&req, source);

        if let Some(hit) =
            self.cache.lock().unwrap_or_else(|e| e.into_inner()).get(&key)
        {
            self.counters.cache_hits.fetch_add(1, Ordering::SeqCst);
            return Ok((hit, "hit"));
        }

        // Join or found the flight for this key. Registration happens
        // *before* admission, so a blocked-at-admission leader still
        // absorbs same-key arrivals.
        let (flight, leader) = {
            let mut fl = self.flights.lock().unwrap_or_else(|e| e.into_inner());
            match fl.get(&key) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight::new());
                    fl.insert(key.clone(), Arc::clone(&f));
                    (f, true)
                }
            }
        };
        if !leader {
            self.counters.coalesced.fetch_add(1, Ordering::SeqCst);
            return flight.wait().map(|r| (r, "coalesced"));
        }

        // Admission: block until a slot frees.
        {
            let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
            while *inflight >= self.opts.max_inflight.max(1) {
                inflight =
                    self.admit_cv.wait(inflight).unwrap_or_else(|e| e.into_inner());
            }
            *inflight += 1;
        }
        let result = self.session.run(&req, None).map(Arc::new).map_err(|e| e.to_string());
        {
            let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
            *inflight -= 1;
            self.admit_cv.notify_one();
        }

        if let Ok(r) = &result {
            self.counters.executed.fetch_add(1, Ordering::SeqCst);
            // Cache-insert strictly before retiring the flight: a new
            // same-key arrival then either hits the cache or still finds
            // the flight — never re-executes a just-finished query.
            self.cache
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert(&key, Arc::clone(r));
        }
        flight.publish(result.clone());
        self.flights.lock().unwrap_or_else(|e| e.into_inner()).remove(&key);
        result.map(|r| (r, "miss"))
    }

    fn to_run_request(&self, q: &QueryRequest) -> RunRequest {
        RunRequest {
            app: q.app,
            source: q.source,
            balancer: q.balancer.clone(),
            direction_opt: q.direction_opt,
            sssp_delta: q.delta,
            pr_tol: q.pr_tol,
            kcore_k: q.kcore_k,
            max_rounds: Some(q.max_rounds.unwrap_or(self.opts.max_rounds)),
            record_blocks: false,
            cluster: None,
            fault: None,
        }
    }

    /// The canonical cache/coalesce identity: app + resolved source + the
    /// *effective* engine configuration, so requests that spell the same
    /// run differently (e.g. omitted vs explicit default fields, or
    /// `auto` vs its resolution) share one identity.
    fn query_key(&self, req: &RunRequest, source: u32) -> String {
        let cfg = self.session.effective_config(req);
        format!(
            "{}|s{source}|b{:?}|d{}|sd{:?}|pt{:08x}|kc{}|mr{}",
            req.app.name(),
            cfg.balancer,
            cfg.bfs_direction_opt,
            cfg.sssp_delta.map(f32::to_bits),
            cfg.pr_tol.to_bits(),
            cfg.kcore_k,
            cfg.max_rounds,
        )
    }

    fn render_reply(&self, q: &QueryRequest, r: &RunReply, status: &str) -> String {
        let mut j = Json::obj()
            .set("schema_version", SCHEMA_VERSION)
            .set("status", "ok")
            .set("graph", self.session.input())
            .set("app", r.app.name())
            .set("source", r.source)
            .set("labels_hash", r.labels_hash.clone())
            .set("rounds", r.rounds)
            .set("total_cycles", r.total_cycles)
            .set("simulated_ms", r.simulated_ms)
            .set("converged", r.converged)
            .set("cache", status)
            .set("result", result_json(q, r));
        if let Some(id) = &q.id {
            j = j.set("id", id.to_json());
        }
        j.to_string_compact()
    }

    fn render_stats(&self) -> String {
        let c = &self.counters;
        Json::obj()
            .set("schema_version", SCHEMA_VERSION)
            .set("status", "ok")
            .set("op", "stats")
            .set("graph", self.session.input())
            .set("vertices", self.session.num_vertices() as u64)
            .set("edges", self.session.graph().num_edges() as u64)
            .set("queries", c.queries.load(Ordering::SeqCst))
            .set("executed", c.executed.load(Ordering::SeqCst))
            .set("cache_hits", c.cache_hits.load(Ordering::SeqCst))
            .set("coalesced", c.coalesced.load(Ordering::SeqCst))
            .set("errors", c.errors.load(Ordering::SeqCst))
            .set(
                "pending",
                self.flights.lock().unwrap_or_else(|e| e.into_inner()).len() as u64,
            )
            .set(
                "cached",
                self.cache.lock().unwrap_or_else(|e| e.into_inner()).len() as u64,
            )
            .set("max_inflight", self.opts.max_inflight as u64)
            .to_string_compact()
    }
}

/// App-specific result summary, rendered from the (possibly cached) labels.
fn result_json(q: &QueryRequest, r: &RunReply) -> Json {
    let mut res = Json::obj();
    match r.app {
        App::Bfs | App::Sssp => {
            res = res.set(
                "reached",
                r.labels.iter().filter(|&&x| x < INF).count() as u64,
            );
        }
        App::Cc => {
            let comps: BTreeSet<u32> = r.labels.iter().map(|x| x.to_bits()).collect();
            res = res.set("components", comps.len() as u64);
        }
        App::Pr => {
            let mut idx: Vec<u32> = (0..r.labels.len() as u32).collect();
            idx.sort_by(|&a, &b| {
                r.labels[b as usize]
                    .total_cmp(&r.labels[a as usize])
                    .then(a.cmp(&b))
            });
            let top: Vec<Json> = idx
                .iter()
                .take(q.topk as usize)
                .map(|&v| {
                    Json::obj()
                        .set("vertex", v)
                        .set("rank", r.labels[v as usize] as f64)
                })
                .collect();
            res = res.set("top", Json::Arr(top));
        }
        App::Kcore => {
            res = res.set(
                "members",
                r.labels.iter().filter(|&&x| x > 0.5).count() as u64,
            );
        }
    }
    if let Some(v) = q.vertex {
        let x = r.labels[v as usize];
        let value = match r.app {
            App::Kcore => Json::Bool(x > 0.5),
            App::Bfs | App::Sssp if x >= INF => Json::Null,
            _ => Json::Num(x as f64),
        };
        res = res.set("vertex", v).set("value", value);
    }
    res
}

fn render_error(msg: &str, id: Option<&Value>) -> String {
    let mut j = Json::obj()
        .set("schema_version", SCHEMA_VERSION)
        .set("status", "error")
        .set("error", msg);
    if let Some(id) = id {
        j = j.set("id", id.to_json());
    }
    j.to_string_compact()
}

/// Owns the accept thread; dropping (or [`stop`](ServerHandle::stop)-ping)
/// shuts the listener down.
pub struct ServerHandle {
    addr: SocketAddr,
    server: Arc<Server>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Signal shutdown and join the accept thread. Connection threads for
    /// already-open sockets drain on their own as clients disconnect.
    pub fn stop(mut self) {
        self.signal_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block on the accept thread forever (the CLI foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    fn signal_stop(&self) {
        self.server.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.signal_stop();
        }
    }
}

fn accept_loop(server: &Arc<Server>, listener: &TcpListener) {
    for stream in listener.incoming() {
        if server.stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(s) = stream {
            let srv = Arc::clone(server);
            std::thread::spawn(move || handle_conn(&srv, s));
        }
    }
}

/// What one bounded line read produced.
enum LineRead {
    Line(Vec<u8>),
    Eof,
    Oversized,
}

/// Read one `\n`-terminated line of at most `max` bytes. EOF mid-line (a
/// client that died mid-request) reports `Eof` — the partial line is
/// dropped, never half-parsed. An over-limit line is discarded without
/// buffering: the rest of it is consumed (up to its newline or EOF) before
/// `Oversized` is reported, so the error reply reaches the client on a
/// clean close instead of racing a connection reset from unread bytes.
fn read_line_bounded(r: &mut impl BufRead, max: usize) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut oversized = false;
    loop {
        let (found, used) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(if oversized { LineRead::Oversized } else { LineRead::Eof });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    if !oversized {
                        buf.extend_from_slice(&chunk[..p]);
                    }
                    (true, p + 1)
                }
                None => {
                    if !oversized {
                        buf.extend_from_slice(chunk);
                    }
                    (false, chunk.len())
                }
            }
        };
        r.consume(used);
        if buf.len() > max {
            oversized = true;
            buf.clear();
        }
        if found {
            if oversized {
                return Ok(LineRead::Oversized);
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(LineRead::Line(buf));
        }
    }
}

fn handle_conn(server: &Server, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut out = stream;
    loop {
        match read_line_bounded(&mut reader, MAX_LINE_BYTES) {
            Ok(LineRead::Eof) | Err(_) => return,
            Ok(LineRead::Oversized) => {
                // The offending line was drained but its content is gone;
                // treat the peer as misbehaving: reply, then close.
                server.counters.errors.fetch_add(1, Ordering::SeqCst);
                let msg = format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes; \
                     requests are single-line JSON under that limit"
                );
                let _ = writeln!(out, "{}", render_error(&msg, None));
                return;
            }
            Ok(LineRead::Line(bytes)) => {
                let reply = match String::from_utf8(bytes) {
                    Ok(line) if line.trim().is_empty() => continue,
                    Ok(line) => server.handle_line(&line),
                    Err(_) => {
                        server.counters.errors.fetch_add(1, Ordering::SeqCst);
                        render_error("request line is not valid UTF-8", None)
                    }
                };
                if writeln!(out, "{reply}").is_err() {
                    return;
                }
                let _ = out.flush();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::engine::EngineConfig;
    use crate::graph::gen::rmat::{self, RmatConfig};
    use crate::graph::CsrGraph;
    use std::io::Cursor;

    fn server(scale: u32, opts: ServeOpts) -> Server {
        let g = CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, 33)));
        Server::new(Session::new(g, "rmat18", EngineConfig::default()), opts)
    }

    #[test]
    fn query_then_hit_is_byte_identical_modulo_cache_field() {
        let srv = server(8, ServeOpts::default());
        let line = r#"{"app":"bfs","source":0}"#;
        let cold = srv.handle_line(line);
        let hit = srv.handle_line(line);
        assert!(cold.contains("\"cache\":\"miss\""), "{cold}");
        assert!(hit.contains("\"cache\":\"hit\""), "{hit}");
        assert_eq!(
            cold.replace("\"cache\":\"miss\"", "\"cache\":\"hit\""),
            hit,
            "cached reply must be byte-identical apart from cache status"
        );
        assert_eq!(srv.counters.executed.load(Ordering::SeqCst), 1);
        assert_eq!(srv.counters.cache_hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn presentation_fields_share_the_cache_line() {
        let srv = server(8, ServeOpts::default());
        srv.handle_line(r#"{"app":"pr"}"#);
        let with_k = srv.handle_line(r#"{"app":"pr","k":3,"vertex":0,"id":7}"#);
        assert!(with_k.contains("\"cache\":\"hit\""), "{with_k}");
        assert!(with_k.contains("\"id\":7"), "{with_k}");
        assert!(with_k.contains("\"top\":["), "{with_k}");
        assert_eq!(srv.counters.executed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn errors_are_structured_and_do_not_poison_the_session() {
        let srv = server(8, ServeOpts::default());
        for bad in [
            "{not json",
            r#"{"app":"zzz"}"#,
            r#"{"app":"bfs","source":999999999}"#,
            r#"{"app":"bfs","vertex":999999999}"#,
            r#"{"app":"bfs","max_rounds":2000000}"#,
        ] {
            let reply = srv.handle_line(bad);
            assert!(reply.contains("\"status\":\"error\""), "{bad} -> {reply}");
            assert!(reply.contains("\"schema_version\""), "{reply}");
        }
        assert_eq!(srv.counters.errors.load(Ordering::SeqCst), 5);
        // The session still answers correctly afterwards.
        let ok = srv.handle_line(r#"{"app":"bfs"}"#);
        assert!(ok.contains("\"status\":\"ok\""), "{ok}");
    }

    #[test]
    fn stats_reports_the_counter_invariant() {
        let srv = server(8, ServeOpts::default());
        srv.handle_line(r#"{"app":"bfs"}"#);
        srv.handle_line(r#"{"app":"bfs"}"#);
        srv.handle_line(r#"{"app":"kcore"}"#);
        let stats = srv.handle_line(r#"{"op":"stats"}"#);
        assert!(stats.contains("\"queries\":3"), "{stats}");
        assert!(stats.contains("\"executed\":2"), "{stats}");
        assert!(stats.contains("\"cache_hits\":1"), "{stats}");
        assert!(stats.contains("\"coalesced\":0"), "{stats}");
        assert!(stats.contains("\"pending\":0"), "{stats}");
    }

    #[test]
    fn cache_disabled_reexecutes() {
        let srv = server(8, ServeOpts { cache_entries: 0, ..ServeOpts::default() });
        srv.handle_line(r#"{"app":"bfs"}"#);
        srv.handle_line(r#"{"app":"bfs"}"#);
        assert_eq!(srv.counters.executed.load(Ordering::SeqCst), 2);
        assert_eq!(srv.counters.cache_hits.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn bounded_line_reader() {
        let mut r = Cursor::new(b"short line\n".to_vec());
        match read_line_bounded(&mut r, 64).unwrap() {
            LineRead::Line(l) => assert_eq!(l, b"short line"),
            _ => panic!("expected a line"),
        }
        let mut r = Cursor::new(vec![b'x'; 100]);
        assert!(matches!(read_line_bounded(&mut r, 64).unwrap(), LineRead::Oversized));
        let mut r = Cursor::new(b"partial-then-eof".to_vec());
        assert!(matches!(read_line_bounded(&mut r, 64).unwrap(), LineRead::Eof));
        let mut r = Cursor::new(b"crlf\r\n".to_vec());
        match read_line_bounded(&mut r, 64).unwrap() {
            LineRead::Line(l) => assert_eq!(l, b"crlf"),
            _ => panic!("expected a line"),
        }
    }
}
