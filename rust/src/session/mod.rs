//! The unified execution API (DESIGN.md §16): one [`Session`] owns an
//! immutable prepared graph, a shared [`exec::Pool`], and a checkout pool of
//! recycled [`RoundScratch`] arenas; a typed [`RunRequest`] names everything
//! a query varies (app variant, source, balancer, budgets, cluster shape,
//! fault plan) and a [`RunReply`] carries the deterministic result summary.
//!
//! Before this layer existed, the CLI, the campaign runner, and any future
//! daemon each dispatched directly into three divergent entrypoints
//! ([`engine::run`], [`run_distributed`], [`run_distributed_faulty`]) and
//! re-derived sources, auto-balancer resolution, and result aggregation on
//! their own. [`Session::run`] is now the single seam: `alb run`,
//! `alb sweep` cells, and `alb serve` queries all execute through it, which
//! is what makes the serve layer's parity guarantee checkable — a daemon
//! reply's `labels_hash` is bit-identical to the batch CLI's for the same
//! `(app, input, source, config)` because it is literally the same code
//! path under a different transport.
//!
//! Concurrency: [`Session::run`] takes `&self`. The CSC view is built once
//! at construction (so pull-direction drivers never mutate the graph), the
//! pool accepts concurrent submitters (DESIGN.md §9), and scratch arenas
//! are checked out per query and recycled. Results are bit-identical to the
//! one-shot entrypoints for any number of concurrent callers.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::apps::engine::{self, EngineConfig, RoundScratch};
use crate::apps::App;
use crate::coordinator::{
    run_distributed, run_distributed_faulty, ClusterConfig, ExecMode, FaultConfig,
};
use crate::exec::Pool;
use crate::graph::{inputs, CsrGraph};
use crate::lb::{adaptive, Balancer};
use crate::metrics::labels_hash;
use crate::partition::Policy;
use crate::runtime::PjrtRuntime;

/// Version of every machine-readable result this crate emits at request
/// granularity: the `alb run --json` report and each `alb serve` reply
/// carry it as `schema_version`. The compatibility rule (DESIGN.md §16):
/// consumers parse unknown keys as ignorable and absent keys as their
/// documented defaults, so the version only bumps when an existing key
/// changes meaning or type. (`alb sweep` artifacts carry their own
/// [`crate::campaign::artifact::SCHEMA_VERSION`] under the same rule.)
pub const SCHEMA_VERSION: u64 = 1;

/// The multi-GPU shape of a request; `None` in [`RunRequest::cluster`]
/// means single-GPU execution through the engine.
#[derive(Debug, Clone)]
pub struct ClusterRequest {
    pub gpus: u32,
    pub policy: Policy,
    /// Host topology override; `None` = single host (every GPU intra).
    pub gpus_per_host: Option<u32>,
    pub exec: ExecMode,
}

/// One typed query against a [`Session`]. Every optional field defaults to
/// the session's base [`EngineConfig`]; the setters below are conveniences
/// over plain struct update syntax.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub app: App,
    /// Source vertex for bfs/sssp; `None` = the input's canonical source
    /// ([`inputs::source_vertex`]). Ignored (and canonicalized to 0 in the
    /// reply) for apps that take no source, so result-cache keys built from
    /// replies collapse equivalent requests.
    pub source: Option<u32>,
    /// Balancer override; [`Balancer::Auto`] resolves against the
    /// session's input name exactly as `alb run` and the campaign do.
    pub balancer: Option<Balancer>,
    pub direction_opt: Option<bool>,
    pub sssp_delta: Option<f32>,
    pub pr_tol: Option<f32>,
    pub kcore_k: Option<u32>,
    /// Per-query round budget (admission control for serve: a runaway
    /// query stops at the budget with `converged = false`).
    pub max_rounds: Option<u32>,
    pub record_blocks: bool,
    pub cluster: Option<ClusterRequest>,
    /// Fault plan + checkpoint cadence; multi-GPU only.
    pub fault: Option<FaultConfig>,
}

impl RunRequest {
    pub fn new(app: App) -> RunRequest {
        RunRequest {
            app,
            source: None,
            balancer: None,
            direction_opt: None,
            sssp_delta: None,
            pr_tol: None,
            kcore_k: None,
            max_rounds: None,
            record_blocks: false,
            cluster: None,
            fault: None,
        }
    }

    pub fn with_source(mut self, source: u32) -> Self {
        self.source = Some(source);
        self
    }

    pub fn with_balancer(mut self, balancer: Balancer) -> Self {
        self.balancer = Some(balancer);
        self
    }

    pub fn with_max_rounds(mut self, max_rounds: u32) -> Self {
        self.max_rounds = Some(max_rounds);
        self
    }
}

/// Multi-GPU result fields, present on distributed replies only.
#[derive(Debug, Clone, PartialEq)]
pub struct DistReply {
    pub comp_ms: f64,
    pub comm_ms: f64,
    pub comm_bytes: u64,
    pub comm_bytes_intra: u64,
    pub comm_bytes_inter: u64,
    /// Distinct OS threads that ran local compute.
    pub os_threads: usize,
    /// Per-GPU host wall-clock (ns) — the one machine-dependent field,
    /// reported for operator visibility and excluded from every
    /// deterministic comparison.
    pub per_gpu_wall_ns: Vec<u64>,
    pub recoveries: u32,
    pub replayed_rounds: u64,
    pub retry_count: u64,
    pub checkpoint_bytes: u64,
}

/// A completed query. Everything except [`DistReply::per_gpu_wall_ns`] is
/// deterministic and machine-independent; `labels_hash` (FNV-1a over the
/// final labels' f32 bit patterns, 16 hex digits) is the parity gate
/// between transports.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReply {
    pub app: App,
    /// The source the run actually used (resolved + canonicalized).
    pub source: u32,
    pub labels_hash: String,
    pub rounds: u64,
    pub total_cycles: u64,
    /// Total edges processed across all rounds (single-GPU runs; 0 for
    /// cluster runs, whose per-round records track cycles and bytes, not
    /// edge counts).
    pub total_edges: u64,
    pub simulated_ms: f64,
    pub lb_rounds: u64,
    pub converged: bool,
    /// Peak per-kernel thread-block imbalance when `record_blocks` was
    /// requested (single-GPU), max/mean per-GPU compute cycles
    /// (multi-GPU); 1.0 otherwise.
    pub imbalance_factor: f64,
    /// Inspector threshold after the last round (adaptive single-GPU runs;
    /// 0 otherwise).
    pub adaptive_threshold_final: u64,
    pub dist: Option<DistReply>,
    /// Final labels (distances / component ids / ranks / core membership).
    /// Owned by the reply so serve-layer caches can answer top-k and
    /// per-vertex lookups without re-running.
    pub labels: Vec<f32>,
}

/// A loaded graph plus the execution resources every query shares.
pub struct Session {
    graph: CsrGraph,
    input: String,
    base: EngineConfig,
    pool: Pool,
    /// Recycled arenas, checked out per single-GPU query.
    scratch: Mutex<Vec<RoundScratch>>,
}

impl Session {
    /// Prepare `graph` for serving: build the CSC view once (pull-direction
    /// drivers then never mutate the graph, which is what lets queries run
    /// concurrently over `&CsrGraph`) and spin up the shared pool sized
    /// from `base.sim_threads`. `input` is the preset name (or any tag for
    /// `.albg` files): it drives default-source selection and
    /// [`Balancer::Auto`] resolution.
    pub fn new(mut graph: CsrGraph, input: impl Into<String>, base: EngineConfig) -> Session {
        graph.build_csc();
        let pool = Pool::new(base.sim_threads.max(1));
        Session { graph, input: input.into(), base, pool, scratch: Mutex::new(Vec::new()) }
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    pub fn input(&self) -> &str {
        &self.input
    }

    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    /// The session's base configuration (what a request's `None` fields
    /// resolve to).
    pub fn base_config(&self) -> &EngineConfig {
        &self.base
    }

    /// Resolve `req` against the session defaults into the exact
    /// [`EngineConfig`] the run will use.
    pub fn effective_config(&self, req: &RunRequest) -> EngineConfig {
        let mut cfg = self.base.clone();
        if let Some(b) = &req.balancer {
            cfg = cfg.with_balancer(b.clone());
        }
        if matches!(cfg.balancer, Balancer::Auto) {
            cfg = cfg.with_balancer(adaptive::auto_balancer(req.app.name(), &self.input));
        }
        if let Some(d) = req.direction_opt {
            cfg = cfg.with_direction_opt(d);
        }
        if let Some(d) = req.sssp_delta {
            cfg = cfg.with_sssp_delta(Some(d));
        }
        if let Some(t) = req.pr_tol {
            cfg = cfg.with_pr_tol(t);
        }
        if let Some(k) = req.kcore_k {
            cfg = cfg.with_kcore_k(k);
        }
        if let Some(m) = req.max_rounds {
            cfg = cfg.with_max_rounds(m);
        }
        cfg.with_record_blocks(req.record_blocks)
    }

    /// Resolve and validate the request's source vertex. Apps that take no
    /// source canonicalize to 0 so equivalent requests share one identity;
    /// out-of-range explicit sources are a loud error naming the valid
    /// range (the serve layer forwards it verbatim as a structured error).
    pub fn resolve_source(&self, req: &RunRequest) -> Result<u32> {
        let n = self.graph.num_vertices() as u32;
        if !req.app.needs_source() {
            return Ok(0);
        }
        match req.source {
            Some(s) if s < n => Ok(s),
            Some(s) => Err(anyhow!(
                "source {s} is out of range for {} ({n} vertices); \
                 valid values: 0..={}",
                self.input,
                n.saturating_sub(1)
            )),
            None => Ok(inputs::source_vertex(&self.input, &self.graph)),
        }
    }

    /// Execute one query. Concurrent callers are safe and results are
    /// bit-identical to the equivalent one-shot [`engine::run`] /
    /// [`run_distributed`] / [`run_distributed_faulty`] call — asserted by
    /// `rust/tests/serve.rs`'s parity matrix.
    ///
    /// `pjrt` is per-call (the PJRT client is not `Sync`, so a daemon
    /// serving concurrent queries passes `None` and computes natively).
    pub fn run(&self, req: &RunRequest, pjrt: Option<&PjrtRuntime>) -> Result<RunReply> {
        let cfg = self.effective_config(req);
        let source = self.resolve_source(req)?;
        match &req.cluster {
            None => {
                if req.fault.is_some() {
                    return Err(anyhow!(
                        "fault injection requires a cluster request (gpus > 1); \
                         the fault model covers the distributed exchange"
                    ));
                }
                self.run_single(req.app, source, &cfg, pjrt)
            }
            Some(cluster) => self.run_cluster(req, cluster, source, &cfg, pjrt),
        }
    }

    fn run_single(
        &self,
        app: App,
        source: u32,
        cfg: &EngineConfig,
        pjrt: Option<&PjrtRuntime>,
    ) -> Result<RunReply> {
        let mut scratch =
            self.scratch.lock().unwrap_or_else(|e| e.into_inner()).pop().unwrap_or_default();
        let run = engine::run_prepared(
            app, &self.graph, source, cfg, pjrt, &self.pool, &mut scratch,
        )?;
        // Recycle the arena only on success; an errored run's scratch is
        // dropped so a poisoned buffer can never leak into the next query.
        self.scratch.lock().unwrap_or_else(|e| e.into_inner()).push(scratch);

        let imbalance_factor = run
            .rounds
            .iter()
            .flat_map(|rec| rec.kernels.iter().flatten())
            .map(|k| k.imbalance_factor())
            .fold(1.0f64, f64::max);
        let adaptive_threshold_final = run
            .rounds
            .last()
            .and_then(|rec| rec.adaptive.as_ref())
            .map(|a| a.threshold)
            .unwrap_or(0);
        Ok(RunReply {
            app,
            source,
            labels_hash: format!("{:016x}", labels_hash(&run.labels)),
            rounds: run.rounds.len() as u64,
            total_cycles: run.total_cycles,
            total_edges: run.total_edges(),
            simulated_ms: run.ms(&cfg.spec),
            lb_rounds: run.rounds_with_lb() as u64,
            converged: run.converged,
            imbalance_factor,
            adaptive_threshold_final,
            dist: None,
            labels: run.labels,
        })
    }

    fn run_cluster(
        &self,
        req: &RunRequest,
        cluster: &ClusterRequest,
        source: u32,
        cfg: &EngineConfig,
        pjrt: Option<&PjrtRuntime>,
    ) -> Result<RunReply> {
        let cc = ClusterConfig::new(
            cluster.gpus,
            cluster.policy,
            cluster.gpus_per_host,
            cluster.exec,
        );
        let run = match &req.fault {
            Some(fc) => run_distributed_faulty(
                req.app, &self.graph, source, cfg, &cc, pjrt, fc,
            )?,
            None => run_distributed(req.app, &self.graph, source, cfg, &cc, pjrt)?,
        };
        let max = run.per_gpu_comp.iter().copied().max().unwrap_or(0) as f64;
        let sum: u64 = run.per_gpu_comp.iter().sum();
        let mean = sum as f64 / run.per_gpu_comp.len().max(1) as f64;
        Ok(RunReply {
            app: req.app,
            source,
            labels_hash: format!("{:016x}", labels_hash(&run.labels)),
            rounds: run.rounds.len() as u64,
            total_cycles: run.total_cycles,
            total_edges: 0,
            simulated_ms: run.ms(&cfg.spec),
            lb_rounds: run.rounds.iter().filter(|rec| rec.lb_gpus > 0).count() as u64,
            converged: run.converged,
            imbalance_factor: if mean > 0.0 { max / mean } else { 1.0 },
            adaptive_threshold_final: 0,
            dist: Some(DistReply {
                comp_ms: run.comp_ms(&cfg.spec),
                comm_ms: run.comm_ms(&cfg.spec),
                comm_bytes: run.comm_bytes,
                comm_bytes_intra: run.comm_bytes_intra,
                comm_bytes_inter: run.comm_bytes_inter,
                os_threads: run.num_threads(),
                per_gpu_wall_ns: run.per_gpu_wall_ns.clone(),
                recoveries: run.recoveries,
                replayed_rounds: run.replayed_rounds,
                retry_count: run.retry_count,
                checkpoint_bytes: run.checkpoint_bytes,
            }),
            labels: run.labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat::{self, RmatConfig};

    fn rmat(scale: u32, seed: u64) -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, seed)))
    }

    #[test]
    fn session_matches_one_shot_engine() {
        let g = rmat(10, 21);
        let src = g.max_out_degree_vertex();
        let sess = Session::new(g.clone(), "rmat18", EngineConfig::default());
        for app in [App::Bfs, App::Sssp, App::Cc, App::Pr, App::Kcore] {
            let reply = sess.run(&RunRequest::new(app).with_source(src), None).unwrap();
            let direct =
                engine::run(app, &mut g.clone(), src, &EngineConfig::default(), None)
                    .unwrap();
            assert_eq!(reply.labels, direct.labels, "{}", app.name());
            assert_eq!(reply.rounds, direct.rounds.len() as u64);
            assert_eq!(reply.total_cycles, direct.total_cycles);
            assert_eq!(reply.converged, direct.converged);
            assert_eq!(
                reply.labels_hash,
                format!("{:016x}", labels_hash(&direct.labels))
            );
        }
    }

    #[test]
    fn scratch_recycles_across_queries() {
        let g = rmat(9, 22);
        let src = g.max_out_degree_vertex();
        let sess = Session::new(g, "rmat18", EngineConfig::default());
        let first = sess.run(&RunRequest::new(App::Bfs).with_source(src), None).unwrap();
        assert_eq!(sess.scratch.lock().unwrap().len(), 1, "arena returned to pool");
        let second = sess.run(&RunRequest::new(App::Bfs).with_source(src), None).unwrap();
        assert_eq!(first, second, "recycled arena must not perturb results");
        // A different app through the same arena.
        let k1 = sess.run(&RunRequest::new(App::Kcore), None).unwrap();
        let k2 = sess.run(&RunRequest::new(App::Kcore), None).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(sess.scratch.lock().unwrap().len(), 1);
    }

    #[test]
    fn out_of_range_source_is_a_loud_error() {
        let g = rmat(8, 23);
        let n = g.num_vertices() as u32;
        let sess = Session::new(g, "rmat18", EngineConfig::default());
        let err = sess
            .run(&RunRequest::new(App::Bfs).with_source(n + 7), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("valid values"), "error names the range: {err}");
        // Non-source apps canonicalize any in-range-or-absent source to 0.
        let r = sess.run(&RunRequest::new(App::Pr), None).unwrap();
        assert_eq!(r.source, 0);
    }

    #[test]
    fn cluster_request_matches_run_distributed() {
        let g = rmat(9, 24);
        let src = g.max_out_degree_vertex();
        let sess = Session::new(g.clone(), "rmat18", EngineConfig::default());
        let req = RunRequest {
            cluster: Some(ClusterRequest {
                gpus: 4,
                policy: Policy::Cvc,
                gpus_per_host: None,
                exec: ExecMode::Parallel,
            }),
            ..RunRequest::new(App::Bfs).with_source(src)
        };
        let reply = sess.run(&req, None).unwrap();
        let cc = ClusterConfig::new(4, Policy::Cvc, None, ExecMode::Parallel);
        let direct =
            run_distributed(App::Bfs, &g, src, &EngineConfig::default(), &cc, None)
                .unwrap();
        assert_eq!(reply.labels, direct.labels);
        assert_eq!(reply.total_cycles, direct.total_cycles);
        let d = reply.dist.expect("cluster replies carry dist stats");
        assert_eq!(d.comm_bytes, direct.comm_bytes);
        assert!(d.comm_bytes > 0);
    }

    #[test]
    fn concurrent_queries_are_bit_identical_to_serial() {
        let g = rmat(9, 25);
        let src = g.max_out_degree_vertex();
        let sess = Session::new(g, "rmat18", EngineConfig::default());
        let apps = [App::Bfs, App::Sssp, App::Pr, App::Kcore];
        let serial: Vec<RunReply> = apps
            .iter()
            .map(|&a| sess.run(&RunRequest::new(a).with_source(src), None).unwrap())
            .collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let sess = &sess;
                    s.spawn(move || {
                        let app = apps[i % apps.len()];
                        sess.run(&RunRequest::new(app).with_source(src), None).unwrap()
                    })
                })
                .collect();
            for (i, h) in handles.into_iter().enumerate() {
                assert_eq!(h.join().unwrap(), serial[i % apps.len()]);
            }
        });
    }
}
