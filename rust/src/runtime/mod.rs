//! PJRT runtime: loads the AOT-compiled JAX/Pallas kernels
//! (`artifacts/*.hlo.txt`) and executes them on the request path.
//!
//! This is the Layer-3 <-> Layer-1/2 bridge. The real client lives in
//! [`pjrt`] behind the `xla` cargo feature because the `xla` bindings crate
//! is not part of the offline vendored dependency set (DESIGN.md §7). When
//! the feature is off — the default — an API-identical [`PjrtRuntime`] stub
//! is compiled instead whose `load`/`load_default` report unavailability, so
//! every caller that handles a load error (the CLI's `--engine pjrt`, the
//! PJRT integration tests) degrades gracefully and the native engines are
//! unaffected.

pub mod artifact;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;

/// The f32 "infinity" sentinel shared with the kernels (`ref.INF`).
pub const INF: f32 = 1_073_741_824.0; // 2^30

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    /// Uninhabited stand-in for the PJRT runtime: carries the full public
    /// API so `Option<&PjrtRuntime>` plumbing type-checks everywhere, but
    /// can never be constructed — `load` always errors.
    pub enum PjrtRuntime {}

    impl PjrtRuntime {
        pub fn load(dir: &Path) -> Result<Self> {
            Err(anyhow!(
                "PJRT runtime unavailable (built without the `xla` feature): \
                 cannot load artifacts from {dir:?}; rebuild with \
                 `--features xla` after adding the xla bindings dependency"
            ))
        }

        pub fn load_default() -> Result<Self> {
            Self::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
        }

        pub fn platform(&self) -> String {
            match *self {}
        }

        pub fn num_kernels(&self) -> usize {
            match *self {}
        }

        pub fn max_relax_h(&self) -> usize {
            match *self {}
        }

        pub fn edge_relax(
            &self,
            _prefix: &[u32],
            _src_dist: &[f32],
            _edge_ids: &[u32],
            _weights: &[f32],
        ) -> Result<(Vec<i32>, Vec<f32>)> {
            match *self {}
        }

        pub fn prefix_sum(&self, _degrees: &[u32]) -> Result<Vec<u64>> {
            match *self {}
        }

        pub fn pr_pull(
            &self,
            _ranks: &[f32],
            _out_degree: &[u32],
            _damping: f32,
        ) -> Result<Vec<f32>> {
            match *self {}
        }

        pub fn twc_bin(&self, _degrees: &[u32], _cuts: [u32; 3]) -> Result<Vec<i32>> {
            match *self {}
        }

        pub fn kcore_alive(&self, _cur_degree: &[u32], _k: u32) -> Result<Vec<bool>> {
            match *self {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::PjrtRuntime;

#[cfg(test)]
mod tests {
    use super::PjrtRuntime;

    #[test]
    #[cfg(not(feature = "xla"))]
    fn stub_load_reports_missing_feature() {
        let err = PjrtRuntime::load(std::path::Path::new("/nonexistent"))
            .err()
            .expect("stub must not load");
        assert!(err.to_string().contains("xla"), "{err}");
        assert!(PjrtRuntime::load_default().is_err());
    }

    #[test]
    #[cfg(feature = "xla")]
    fn real_runtime_load_is_attempted() {
        // With the feature on, load_default either succeeds (artifacts built)
        // or fails with an artifact/scan error — both are exercised by
        // rust/tests/pjrt_integration.rs.
        let _ = PjrtRuntime::load_default();
    }
}
