//! The real PJRT-backed runtime (requires the `xla` feature; compiles
//! against the offline `vendor/xla` shim until the real bindings crate is
//! swapped in — see DESIGN.md §7).
//!
//! HLO *text* (not serialized protos — emitted by the retired AOT export
//! pipeline, DESIGN.md §7) is parsed by `HloModuleProto::from_text_file`,
//! compiled once per variant on the PJRT CPU client, and cached. The engine
//! calls [`PjrtRuntime::edge_relax`] with whatever batch it has; the
//! runtime pads to the smallest compiled variant.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::{discover, kernel_key, ArtifactKind};
use super::INF;

/// Compiled kernel cache keyed by variant.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// (H, B) variants available for `edge_relax`, ascending.
    relax_variants: Vec<(usize, usize)>,
    /// H variants for `prefix_sum`, ascending.
    prefix_variants: Vec<usize>,
    /// N variants for `pr_pull` / `kcore`, ascending.
    vertex_variants: Vec<usize>,
}

impl PjrtRuntime {
    /// Load and compile every artifact in `dir` on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut execs = HashMap::new();
        let mut relax_variants = Vec::new();
        let mut prefix_variants = Vec::new();
        let mut vertex_variants = Vec::new();
        for art in discover(dir).with_context(|| format!("scan {dir:?}"))? {
            let proto = xla::HloModuleProto::from_text_file(
                art.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", art.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {:?}: {e:?}", art.path))?;
            match art.kind {
                ArtifactKind::EdgeRelax { h, b } => relax_variants.push((h, b)),
                ArtifactKind::PrefixSum { h } => prefix_variants.push(h),
                ArtifactKind::PrPull { n } => vertex_variants.push(n),
                _ => {}
            }
            execs.insert(kernel_key(&art.kind), exe);
        }
        if execs.is_empty() {
            return Err(anyhow!("no artifacts in {dir:?}; run `make artifacts`"));
        }
        relax_variants.sort_unstable();
        prefix_variants.sort_unstable();
        vertex_variants.sort_unstable();
        Ok(PjrtRuntime { client, execs, relax_variants, prefix_variants, vertex_variants })
    }

    /// Default artifact location relative to the crate root.
    pub fn load_default() -> Result<Self> {
        Self::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn num_kernels(&self) -> usize {
        self.execs.len()
    }

    fn exec(&self, k: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.execs.get(k).ok_or_else(|| anyhow!("kernel {k} not loaded"))
    }

    /// Pick the smallest (H, B) relax variant fitting `h` huge vertices.
    fn pick_relax(&self, h: usize) -> Option<(usize, usize)> {
        self.relax_variants.iter().copied().find(|&(vh, _)| vh >= h)
    }

    /// Largest compiled huge-table size (callers split bigger tables).
    pub fn max_relax_h(&self) -> usize {
        self.relax_variants.iter().map(|&(h, _)| h).max().unwrap_or(0)
    }

    /// Run the LB-kernel relaxation over a batch of huge-vertex edges.
    ///
    /// * `prefix`: inclusive prefix sums of the huge vertices' degrees.
    /// * `src_dist`: current labels of the huge vertices.
    /// * `edge_ids`: edge ids in `[0, prefix.last())`, any schedule order.
    /// * `weights`: per-edge relax weight.
    ///
    /// Returns `(src_idx, candidate)` per edge, exactly the reference
    /// semantics the HLO artifacts were exported against (DESIGN.md §7).
    pub fn edge_relax(
        &self,
        prefix: &[u32],
        src_dist: &[f32],
        edge_ids: &[u32],
        weights: &[f32],
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        assert_eq!(prefix.len(), src_dist.len());
        assert_eq!(edge_ids.len(), weights.len());
        let (h, b) = self
            .pick_relax(prefix.len())
            .ok_or_else(|| anyhow!("huge table {} exceeds compiled variants", prefix.len()))?;
        let exe = self.exec(&kernel_key(&ArtifactKind::EdgeRelax { h, b }))?;

        // Pad the huge table: padded prefix entries repeat the total so the
        // searchsorted rank of any real edge id is unchanged.
        let total = prefix.last().copied().unwrap_or(0);
        let mut p = vec![0i32; h];
        let mut d = vec![0f32; h];
        for i in 0..h {
            p[i] = if i < prefix.len() { prefix[i] as i32 } else { total as i32 };
            d[i] = if i < src_dist.len() { src_dist[i] } else { INF };
        }
        let p_lit = xla::Literal::vec1(&p);
        let d_lit = xla::Literal::vec1(&d);

        let mut src_out = Vec::with_capacity(edge_ids.len());
        let mut cand_out = Vec::with_capacity(edge_ids.len());
        for chunk_start in (0..edge_ids.len()).step_by(b) {
            let chunk = &edge_ids[chunk_start..(chunk_start + b).min(edge_ids.len())];
            let wchunk = &weights[chunk_start..chunk_start + chunk.len()];
            let mut eids = vec![0i32; b];
            let mut ws = vec![0f32; b];
            let mut valid = vec![0i32; b];
            for (i, (&e, &w)) in chunk.iter().zip(wchunk).enumerate() {
                eids[i] = e as i32;
                ws[i] = w;
                valid[i] = 1;
            }
            let args = [
                p_lit.clone(),
                d_lit.clone(),
                xla::Literal::vec1(&eids),
                xla::Literal::vec1(&ws),
                xla::Literal::vec1(&valid),
            ];
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("execute edge_relax: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch: {e:?}"))?;
            let (src, cand) =
                result.to_tuple2().map_err(|e| anyhow!("untuple: {e:?}"))?;
            let src: Vec<i32> = src.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let cand: Vec<f32> = cand.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            src_out.extend_from_slice(&src[..chunk.len()]);
            cand_out.extend_from_slice(&cand[..chunk.len()]);
        }
        Ok((src_out, cand_out))
    }

    /// Inclusive prefix sum (the inspector's scan) via the Pallas kernel.
    pub fn prefix_sum(&self, degrees: &[u32]) -> Result<Vec<u64>> {
        let h = self
            .prefix_variants
            .iter()
            .copied()
            .find(|&vh| vh >= degrees.len())
            .ok_or_else(|| anyhow!("scan length {} exceeds variants", degrees.len()))?;
        let exe = self.exec(&kernel_key(&ArtifactKind::PrefixSum { h }))?;
        let mut x = vec![0i32; h];
        for (i, &d) in degrees.iter().enumerate() {
            x[i] = d as i32;
        }
        let result = exe
            .execute::<xla::Literal>(&[xla::Literal::vec1(&x)])
            .map_err(|e| anyhow!("execute prefix_sum: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let out: Vec<i32> = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok(out[..degrees.len()].iter().map(|&v| v as u64).collect())
    }

    /// Pull-pagerank per-vertex contributions via the Pallas kernel.
    pub fn pr_pull(&self, ranks: &[f32], out_degree: &[u32], damping: f32) -> Result<Vec<f32>> {
        assert_eq!(ranks.len(), out_degree.len());
        let n = self
            .vertex_variants
            .iter()
            .copied()
            .find(|&vn| vn >= ranks.len())
            .ok_or_else(|| anyhow!("tile {} exceeds variants", ranks.len()))?;
        let exe = self.exec(&kernel_key(&ArtifactKind::PrPull { n }))?;
        let mut r = vec![0f32; n];
        let mut d = vec![0i32; n];
        r[..ranks.len()].copy_from_slice(ranks);
        for (i, &x) in out_degree.iter().enumerate() {
            d[i] = x as i32;
        }
        let result = exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&r),
                xla::Literal::vec1(&d),
                xla::Literal::vec1(&[damping]),
            ])
            .map_err(|e| anyhow!("execute pr_pull: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let out: Vec<f32> = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok(out[..ranks.len()].to_vec())
    }

    /// Inspector bin assignment via the Pallas kernel: degrees ->
    /// 0 (thread) / 1 (warp) / 2 (CTA) / 3 (huge), given the
    /// (warp, block, huge) cutoffs.
    pub fn twc_bin(&self, degrees: &[u32], cuts: [u32; 3]) -> Result<Vec<i32>> {
        let n = self
            .vertex_variants
            .iter()
            .copied()
            .find(|&vn| vn >= degrees.len())
            .ok_or_else(|| anyhow!("tile {} exceeds variants", degrees.len()))?;
        let exe = self.exec(&kernel_key(&ArtifactKind::Binning { n }))?;
        let mut d = vec![0i32; n];
        for (i, &x) in degrees.iter().enumerate() {
            d[i] = x as i32;
        }
        let c = [cuts[0] as i32, cuts[1] as i32, cuts[2] as i32];
        let result = exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&d),
                xla::Literal::vec1(&c),
            ])
            .map_err(|e| anyhow!("execute binning: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let out: Vec<i32> = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok(out[..degrees.len()].to_vec())
    }

    /// One k-core filter step via the Pallas kernel.
    pub fn kcore_alive(&self, cur_degree: &[u32], k: u32) -> Result<Vec<bool>> {
        let n = self
            .vertex_variants
            .iter()
            .copied()
            .find(|&vn| vn >= cur_degree.len())
            .ok_or_else(|| anyhow!("tile {} exceeds variants", cur_degree.len()))?;
        let exe = self.exec(&kernel_key(&ArtifactKind::Kcore { n }))?;
        let mut d = vec![0i32; n];
        for (i, &x) in cur_degree.iter().enumerate() {
            d[i] = x as i32;
        }
        let result = exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(&d),
                xla::Literal::vec1(&[k as i32]),
            ])
            .map_err(|e| anyhow!("execute kcore: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let out: Vec<i32> = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?
            .to_vec()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok(out[..cur_degree.len()].iter().map(|&v| v != 0).collect())
    }
}
