//! Artifact discovery: map the AOT outputs in `artifacts/` to typed kernel
//! variants the runtime can select by shape.
//!
//! Shape metadata is encoded in the artifact file names by the exporter
//! (the retired AOT pipeline, DESIGN.md §7 — any tool emitting these names
//! works: `edge_relax_h{H}_b{B}.hlo.txt`, `prefix_sum_h{H}.hlo.txt`,
//! `pr_pull_n{N}.hlo.txt`, `kcore_n{N}.hlo.txt`,
//! `relax_merge_h{H}_b{B}_s{S}.hlo.txt`), which keeps the Rust side free of
//! a JSON dependency; `manifest.json` stays the human-readable description.

use std::path::{Path, PathBuf};

/// One compiled-ahead-of-time kernel variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactKind {
    /// (H, B): huge-table size, edge-batch size.
    EdgeRelax { h: usize, b: usize },
    /// (H, B, S): adds destination-slot table size.
    RelaxMerge { h: usize, b: usize, s: usize },
    /// H: scan length.
    PrefixSum { h: usize },
    /// N: vertex tile.
    PrPull { n: usize },
    /// N: vertex tile.
    Kcore { n: usize },
    /// N: vertex tile (inspector bin assignment).
    Binning { n: usize },
}

#[derive(Debug, Clone)]
pub struct Artifact {
    pub kind: ArtifactKind,
    pub path: PathBuf,
}

/// Stable cache key for a compiled kernel variant (the runtime's executable
/// map is keyed by this).
pub fn kernel_key(kind: &ArtifactKind) -> String {
    match kind {
        ArtifactKind::EdgeRelax { h, b } => format!("edge_relax_{h}_{b}"),
        ArtifactKind::RelaxMerge { h, b, s } => format!("relax_merge_{h}_{b}_{s}"),
        ArtifactKind::PrefixSum { h } => format!("prefix_sum_{h}"),
        ArtifactKind::PrPull { n } => format!("pr_pull_{n}"),
        ArtifactKind::Kcore { n } => format!("kcore_{n}"),
        ArtifactKind::Binning { n } => format!("binning_{n}"),
    }
}

/// Parse one artifact file name; `None` for unrelated files.
pub fn parse_name(name: &str) -> Option<ArtifactKind> {
    let stem = name.strip_suffix(".hlo.txt")?;
    let nums = |s: &str, prefix: &str| -> Option<Vec<usize>> {
        let rest = s.strip_prefix(prefix)?;
        rest.split('_')
            .map(|tok| {
                tok.trim_start_matches(|c: char| c.is_ascii_alphabetic())
                    .parse::<usize>()
                    .ok()
            })
            .collect()
    };
    if let Some(v) = nums(stem, "edge_relax_") {
        if let [h, b] = v[..] {
            return Some(ArtifactKind::EdgeRelax { h, b });
        }
    }
    if let Some(v) = nums(stem, "relax_merge_") {
        if let [h, b, s] = v[..] {
            return Some(ArtifactKind::RelaxMerge { h, b, s });
        }
    }
    if let Some(v) = nums(stem, "prefix_sum_") {
        if let [h] = v[..] {
            return Some(ArtifactKind::PrefixSum { h });
        }
    }
    if let Some(v) = nums(stem, "pr_pull_") {
        if let [n] = v[..] {
            return Some(ArtifactKind::PrPull { n });
        }
    }
    if let Some(v) = nums(stem, "kcore_") {
        if let [n] = v[..] {
            return Some(ArtifactKind::Kcore { n });
        }
    }
    if let Some(v) = nums(stem, "binning_") {
        if let [n] = v[..] {
            return Some(ArtifactKind::Binning { n });
        }
    }
    None
}

/// Scan a directory for artifacts.
pub fn discover(dir: &Path) -> std::io::Result<Vec<Artifact>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(kind) = parse_name(&name) {
            out.push(Artifact { kind, path: entry.path() });
        }
    }
    out.sort_by_key(|a| a.path.clone());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        assert_eq!(
            parse_name("edge_relax_h256_b2048.hlo.txt"),
            Some(ArtifactKind::EdgeRelax { h: 256, b: 2048 })
        );
        assert_eq!(
            parse_name("relax_merge_h256_b2048_s2048.hlo.txt"),
            Some(ArtifactKind::RelaxMerge { h: 256, b: 2048, s: 2048 })
        );
        assert_eq!(
            parse_name("prefix_sum_h1024.hlo.txt"),
            Some(ArtifactKind::PrefixSum { h: 1024 })
        );
        assert_eq!(parse_name("pr_pull_n4096.hlo.txt"), Some(ArtifactKind::PrPull { n: 4096 }));
        assert_eq!(parse_name("kcore_n16384.hlo.txt"), Some(ArtifactKind::Kcore { n: 16384 }));
        assert_eq!(parse_name("binning_n4096.hlo.txt"), Some(ArtifactKind::Binning { n: 4096 }));
    }

    #[test]
    fn kernel_key_is_stable() {
        assert_eq!(
            kernel_key(&ArtifactKind::EdgeRelax { h: 256, b: 2048 }),
            "edge_relax_256_2048"
        );
        assert_eq!(kernel_key(&ArtifactKind::PrefixSum { h: 1024 }), "prefix_sum_1024");
    }

    #[test]
    fn ignores_unrelated_files() {
        assert_eq!(parse_name("manifest.json"), None);
        assert_eq!(parse_name("notes.txt"), None);
        assert_eq!(parse_name("edge_relax_weird.hlo.txt"), None);
    }

    #[test]
    fn discover_finds_generated_artifacts() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let arts = discover(&dir).unwrap();
        assert!(arts.iter().any(|a| matches!(a.kind, ArtifactKind::EdgeRelax { .. })));
        assert!(arts.iter().any(|a| matches!(a.kind, ArtifactKind::PrefixSum { .. })));
        assert!(arts.iter().any(|a| matches!(a.kind, ArtifactKind::PrPull { .. })));
    }
}
