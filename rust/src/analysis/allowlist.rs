//! The committed lint allowlist, `LINT_ALLOW.txt` (DESIGN.md §15).
//!
//! A rule that is right 99% of the time still needs an escape hatch for
//! the intentional 1% — but an escape hatch that rots silently is worse
//! than none. Three properties keep this one honest:
//!
//! 1. **Every suppression carries a justification.** An entry without a
//!    non-empty `why:` field is a parse error, and parse errors fail the
//!    lint run exactly like diagnostics do.
//! 2. **Entries go stale-and-fail.** An entry is matched against the
//!    diagnostics of the current run; if it suppresses nothing (the
//!    offending line was fixed, moved, or rewritten) the entry itself
//!    becomes an error until it is deleted. The allowlist can only ever
//!    shrink ahead of the tree, never lag behind it.
//! 3. **Matching is by content, not by line number.** An entry names the
//!    rule, the file, and a substring of the offending *line text*, so
//!    unrelated edits shifting line numbers do not detach it — but any
//!    rewrite of the line itself does.
//!
//! Format, one entry per line (`#` comments and blank lines ignored):
//!
//! ```text
//! RULE | repo/relative/path.rs | line-text substring | why: justification
//! ```
//!
//! The substring field cannot contain `|` (it delimits fields) and must be
//! non-empty (an empty substring would match every diagnostic in the
//! file).

use super::rules::Diagnostic;

/// One parsed suppression.
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    pub substring: String,
    pub why: String,
    /// 1-based line in LINT_ALLOW.txt, for stale-entry reporting.
    pub line_no: usize,
}

/// The parsed allowlist: valid entries plus parse errors (which fail the
/// run — see [`Allowlist::apply`] callers).
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
    pub errors: Vec<String>,
}

/// The result of filtering diagnostics through the allowlist.
pub struct Applied {
    /// Diagnostics no entry matched — these fail the run.
    pub kept: Vec<Diagnostic>,
    /// How many diagnostics were suppressed by a justified entry.
    pub suppressed: usize,
    /// Entries that matched nothing this run — stale, and fail the run.
    pub stale: Vec<String>,
}

pub fn parse(text: &str) -> Allowlist {
    let mut entries = Vec::new();
    let mut errors = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = t.splitn(4, '|').map(str::trim).collect();
        if parts.len() != 4 {
            errors.push(format!(
                "LINT_ALLOW.txt:{}: want `RULE | file | substring | why: ...`, got `{t}`",
                i + 1
            ));
            continue;
        }
        let (rule, file, substring, why_field) = (parts[0], parts[1], parts[2], parts[3]);
        if rule.is_empty() || file.is_empty() {
            errors.push(format!("LINT_ALLOW.txt:{}: empty rule or file field", i + 1));
            continue;
        }
        if substring.is_empty() {
            errors.push(format!(
                "LINT_ALLOW.txt:{}: empty substring would match every {rule} \
                 diagnostic in {file}",
                i + 1
            ));
            continue;
        }
        let why = why_field.strip_prefix("why:").map(str::trim);
        match why {
            Some(w) if !w.is_empty() => entries.push(AllowEntry {
                rule: rule.to_string(),
                file: file.to_string(),
                substring: substring.to_string(),
                why: w.to_string(),
                line_no: i + 1,
            }),
            _ => errors.push(format!(
                "LINT_ALLOW.txt:{}: suppression of {rule} in {file} has no \
                 `why:` justification",
                i + 1
            )),
        }
    }
    Allowlist { entries, errors }
}

impl Allowlist {
    /// Partition diagnostics into kept (unmatched) and suppressed, and
    /// report entries that matched nothing as stale.
    pub fn apply(&self, diags: Vec<Diagnostic>) -> Applied {
        let mut matched = vec![0usize; self.entries.len()];
        let mut kept = Vec::new();
        let mut suppressed = 0usize;
        for d in diags {
            let mut hit = false;
            for (idx, e) in self.entries.iter().enumerate() {
                if e.rule == d.rule && e.file == d.file && d.text.contains(&e.substring) {
                    matched[idx] += 1;
                    hit = true;
                }
            }
            if hit {
                suppressed += 1;
            } else {
                kept.push(d);
            }
        }
        let stale = self
            .entries
            .iter()
            .zip(&matched)
            .filter(|(_, &m)| m == 0)
            .map(|(e, _)| {
                format!(
                    "LINT_ALLOW.txt:{}: stale entry `{} | {} | {}` — it suppresses \
                     nothing; the violation it covered is gone, delete the entry",
                    e.line_no, e.rule, e.file, e.substring
                )
            })
            .collect();
        Applied { kept, suppressed, stale }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(rule: &'static str, file: &str, text: &str) -> Diagnostic {
        Diagnostic {
            rule,
            file: file.to_string(),
            line: 7,
            message: "m".into(),
            text: text.to_string(),
        }
    }

    #[test]
    fn entry_without_why_is_an_error() {
        let a = parse("D002 | rust/src/x.rs | .values() | because\n");
        assert!(a.entries.is_empty());
        assert_eq!(a.errors.len(), 1);
        assert!(a.errors[0].contains("why:"), "{}", a.errors[0]);
    }

    #[test]
    fn empty_substring_is_an_error() {
        let a = parse("D002 | rust/src/x.rs |  | why: too broad\n");
        assert!(a.entries.is_empty());
        assert_eq!(a.errors.len(), 1);
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        let a = parse("# header\n\nD002 | rust/src/x.rs | .values() | why: sorted after\n");
        assert_eq!(a.entries.len(), 1);
        assert!(a.errors.is_empty());
        assert_eq!(a.entries[0].why, "sorted after");
    }

    #[test]
    fn matching_suppresses_and_nonmatching_goes_stale() {
        let a = parse(
            "D002 | rust/src/x.rs | .values() | why: sorted after\n\
             U001 | rust/src/y.rs | transmute | why: covered elsewhere\n",
        );
        let out = a.apply(vec![
            d("D002", "rust/src/x.rs", "let v = prior.values()"),
            d("D002", "rust/src/z.rs", "let v = other.values()"),
        ]);
        assert_eq!(out.suppressed, 1);
        assert_eq!(out.kept.len(), 1);
        assert_eq!(out.kept[0].file, "rust/src/z.rs");
        assert_eq!(out.stale.len(), 1);
        assert!(out.stale[0].contains("U001"), "{}", out.stale[0]);
    }

    #[test]
    fn rule_and_file_must_both_match() {
        let a = parse("D002 | rust/src/x.rs | .values() | why: sorted\n");
        let out = a.apply(vec![d("D003", "rust/src/x.rs", "prior.values()")]);
        assert_eq!(out.suppressed, 0);
        assert_eq!(out.kept.len(), 1);
        assert_eq!(out.stale.len(), 1);
    }
}
