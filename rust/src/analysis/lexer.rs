//! A lightweight line-model lexer for Rust sources (DESIGN.md §15).
//!
//! `alb lint` does not need a parse tree — every rule it enforces is a
//! property of *lines*: "this line reads the wall clock", "the line above
//! this `unsafe` is a `// SAFETY:` comment", "this string literal names a
//! flag but no valid set". What the rules *do* need, and what a plain
//! substring grep cannot give them, is to know which bytes of a line are
//! code, which are comment, and which sit inside a string/char literal.
//!
//! [`FileModel::parse`] walks the source once with a six-state machine
//! (code, line comment, nested block comment, string, raw string, char
//! literal) and emits, per line:
//!
//! - `code`: the line with comments removed and literal *contents* blanked
//!   to spaces (the delimiting quotes survive, so column positions are
//!   stable). Rules that match identifiers (`unsafe`, `Instant::now`,
//!   `HashMap`) run against this view and cannot be fooled by occurrences
//!   inside strings or comments — which matters, because the linter lints
//!   its own sources and its own test fixtures.
//! - `comment`: the comment text of the line (`// SAFETY:` lives here).
//! - `raw`: the verbatim line, for diagnostics and for rules that scan
//!   prose (`DESIGN.md §N` references appear in comments).
//!
//! Literal contents are not discarded: they are recorded per start line in
//! [`FileModel::literals`] so the C-rules can inspect error-message text.
//!
//! The model also records where `#[cfg(test)]` first appears. This
//! repository keeps each file's test module at the end of the file, so
//! "everything from that line on" is a faithful test region — rules that
//! only govern product code (the D-rules, C001) stop there.
//!
//! Known, accepted approximations: a lifetime is distinguished from a char
//! literal by lookahead (`'a` vs `'x'`), raw strings support any `#` depth,
//! block comments nest, and a backslash-newline continues a string across
//! lines. Exotic shapes the tree does not contain (e.g. `'\u{…}'` spanning
//! a newline) are out of scope; the fixture corpus in `rust/tests/lint.rs`
//! pins everything the rules rely on.

/// One source line, split into its code, comment, and verbatim views.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code view: comments stripped, literal contents blanked to spaces.
    pub code: String,
    /// Comment text appearing on this line (both `//` and `/* */`).
    pub comment: String,
    /// The verbatim line, for diagnostics and prose scans.
    pub raw: String,
}

/// The per-line model of one source file.
#[derive(Debug, Default)]
pub struct FileModel {
    /// 0-indexed storage; use [`FileModel::line`] for 1-based access.
    pub lines: Vec<Line>,
    /// String-literal contents, recorded at the literal's *start* line.
    pub literals: Vec<(usize, String)>,
    /// 1-based line of the first `#[cfg(test)]`; the test region runs from
    /// there to end of file (repo convention: tests module last).
    pub test_start: Option<usize>,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    Block,
    Str,
    RawStr,
    Char,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

impl FileModel {
    pub fn parse(src: &str) -> FileModel {
        let chars: Vec<char> = src.chars().collect();
        let n = chars.len();
        let mut i = 0usize;
        let mut state = State::Code;
        let mut depth = 0usize; // block-comment nesting
        let mut hashes = 0usize; // raw-string `#` count
        let mut code = String::new();
        let mut cmt = String::new();
        let mut raw = String::new();
        let mut lit = String::new();
        let mut lit_start = 0usize;
        let mut line_no = 1usize;
        let mut lines: Vec<Line> = Vec::new();
        let mut literals: Vec<(usize, String)> = Vec::new();

        macro_rules! endline {
            () => {{
                lines.push(Line {
                    code: std::mem::take(&mut code),
                    comment: std::mem::take(&mut cmt),
                    raw: std::mem::take(&mut raw),
                });
            }};
        }

        while i < n {
            let c = chars[i];
            if c == '\n' {
                if state == State::LineComment {
                    state = State::Code;
                }
                endline!();
                line_no += 1;
                i += 1;
                continue;
            }
            raw.push(c);
            match state {
                State::Code => {
                    let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                    if c == '/' && nxt == '/' {
                        state = State::LineComment;
                        cmt.push_str("//");
                        raw.push(nxt);
                        i += 2;
                        continue;
                    }
                    if c == '/' && nxt == '*' {
                        state = State::Block;
                        depth = 1;
                        cmt.push_str("/*");
                        raw.push(nxt);
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        state = State::Str;
                        code.push('"');
                        lit.clear();
                        lit_start = line_no;
                        i += 1;
                        continue;
                    }
                    let prev = if i > 0 { chars[i - 1] } else { '\0' };
                    if c == 'r' && (nxt == '"' || nxt == '#') && !is_ident(prev) {
                        let mut j = i + 1;
                        let mut h = 0usize;
                        while j < n && chars[j] == '#' {
                            h += 1;
                            j += 1;
                        }
                        if j < n && chars[j] == '"' {
                            state = State::RawStr;
                            hashes = h;
                            code.push('r');
                            for _ in 0..h {
                                code.push('#');
                            }
                            code.push('"');
                            for k in chars.iter().take(j + 1).skip(i + 1) {
                                raw.push(*k);
                            }
                            lit.clear();
                            lit_start = line_no;
                            i = j + 1;
                            continue;
                        }
                    }
                    if c == 'b' && nxt == '"' && !is_ident(prev) {
                        state = State::Str;
                        code.push_str("b\"");
                        raw.push(nxt);
                        lit.clear();
                        lit_start = line_no;
                        i += 2;
                        continue;
                    }
                    if c == '\'' {
                        if nxt == '\\' {
                            // escaped char literal: '\n', '\'', '\u{..}'
                            state = State::Char;
                            code.push('\'');
                            i += 1;
                            continue;
                        }
                        let nxt2 = if i + 2 < n { chars[i + 2] } else { '\0' };
                        if nxt != '\0' && nxt2 == '\'' {
                            // plain char literal 'x' (including '"')
                            code.push_str("' '");
                            raw.push(nxt);
                            raw.push(nxt2);
                            i += 3;
                            continue;
                        }
                        // lifetime: leave the tick in the code view
                        code.push('\'');
                        i += 1;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                }
                State::LineComment => {
                    cmt.push(c);
                    i += 1;
                }
                State::Block => {
                    let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                    if c == '/' && nxt == '*' {
                        depth += 1;
                        cmt.push_str("/*");
                        raw.push(nxt);
                        i += 2;
                        continue;
                    }
                    if c == '*' && nxt == '/' {
                        depth -= 1;
                        cmt.push_str("*/");
                        raw.push(nxt);
                        i += 2;
                        if depth == 0 {
                            state = State::Code;
                        }
                        continue;
                    }
                    cmt.push(c);
                    i += 1;
                }
                State::Str => {
                    if c == '\\' {
                        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                        lit.push(c);
                        lit.push(nxt);
                        if nxt == '\n' {
                            endline!();
                            line_no += 1;
                        } else {
                            raw.push(nxt);
                            code.push_str("  ");
                        }
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        state = State::Code;
                        code.push('"');
                        literals.push((lit_start, std::mem::take(&mut lit)));
                        i += 1;
                        continue;
                    }
                    lit.push(c);
                    code.push(' ');
                    i += 1;
                }
                State::RawStr => {
                    if c == '"' {
                        let mut j = i + 1;
                        let mut h = 0usize;
                        while j < n && chars[j] == '#' && h < hashes {
                            h += 1;
                            j += 1;
                        }
                        if h == hashes {
                            state = State::Code;
                            code.push('"');
                            for _ in 0..h {
                                code.push('#');
                            }
                            for k in chars.iter().take(j).skip(i + 1) {
                                raw.push(*k);
                            }
                            literals.push((lit_start, std::mem::take(&mut lit)));
                            i = j;
                            continue;
                        }
                    }
                    lit.push(c);
                    code.push(' ');
                    i += 1;
                }
                State::Char => {
                    if c == '\\' {
                        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                        raw.push(nxt);
                        code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if c == '\'' {
                        state = State::Code;
                        code.push('\'');
                        i += 1;
                        continue;
                    }
                    code.push(' ');
                    i += 1;
                }
            }
        }
        if !code.is_empty() || !cmt.is_empty() || !raw.is_empty() || lines.is_empty() {
            lines.push(Line { code, comment: cmt, raw });
        }

        let test_start = lines
            .iter()
            .position(|l| l.code.contains("#[cfg(test)]"))
            .map(|idx| idx + 1);
        FileModel { lines, literals, test_start }
    }

    /// 1-based line access.
    pub fn line(&self, no: usize) -> &Line {
        &self.lines[no - 1]
    }

    /// Is this 1-based line inside the trailing test region?
    pub fn is_test_line(&self, no: usize) -> bool {
        matches!(self.test_start, Some(t) if no >= t)
    }

    /// Does this 1-based line hold only comment text (no code)?
    pub fn is_comment_only(&self, no: usize) -> bool {
        let l = self.line(no);
        l.code.trim().is_empty() && !l.comment.trim().is_empty()
    }
}

/// All start offsets where `word` occurs in `hay` with non-identifier
/// characters (or the string boundary) on both sides.
pub fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    if word.is_empty() {
        return out;
    }
    let hb = hay.as_bytes();
    let wlen = word.len();
    let mut start = 0usize;
    while let Some(k) = hay[start..].find(word) {
        let at = start + k;
        let before_ok = at == 0 || !is_ident(hb[at - 1] as char);
        let after_ok = at + wlen >= hb.len() || !is_ident(hb[at + wlen] as char);
        if before_ok && after_ok {
            out.push(at);
        }
        start = at + 1;
    }
    out
}

/// Whole-word containment (see [`find_word`]).
pub fn contains_word(hay: &str, word: &str) -> bool {
    !find_word(hay, word).is_empty()
}

/// Is `c` an identifier character (`XID`-ish: alphanumeric or `_`)?
pub fn ident_char(c: char) -> bool {
    is_ident(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_leave_the_code_view() {
        let fm = FileModel::parse(
            "let a = \"text in string\"; // trailing words\nlet b = 2;\n",
        );
        assert_eq!(fm.lines.len(), 2);
        assert!(!fm.lines[0].code.contains("text"));
        assert!(!fm.lines[0].code.contains("trailing"));
        assert!(fm.lines[0].comment.contains("trailing words"));
        assert_eq!(fm.literals.len(), 1);
        assert_eq!(fm.literals[0], (1, "text in string".to_string()));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let fm = FileModel::parse("/* a /* b */ c */ let x = 1;\n");
        assert_eq!(fm.lines[0].code.trim(), "let x = 1;");
        assert!(fm.lines[0].comment.contains('b'));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let fm = FileModel::parse("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        // the body brace survives: the tick did not swallow code
        assert!(fm.lines[0].code.contains("{ x }"));
    }

    #[test]
    fn char_literals_are_blanked() {
        let fm = FileModel::parse("let q = '\\''; let d = '\"'; let z = 'u';\n");
        let code = &fm.lines[0].code;
        assert!(!code.contains('u') || code.contains("let"), "{code}");
        assert!(!code.contains('"'), "double quote must be blanked: {code}");
    }

    #[test]
    fn raw_strings_record_contents_and_blank_code() {
        let fm = FileModel::parse("let s = r#\"has \"quotes\" inside\"#;\nlet t = 1;\n");
        assert!(!fm.lines[0].code.contains("quotes"));
        assert_eq!(fm.literals.len(), 1);
        assert!(fm.literals[0].1.contains("has \"quotes\" inside"));
        assert_eq!(fm.lines[1].code.trim(), "let t = 1;");
    }

    #[test]
    fn test_region_starts_at_cfg_test() {
        let fm = FileModel::parse("fn a() {}\n#[cfg(test)]\nmod tests {}\n");
        assert_eq!(fm.test_start, Some(2));
        assert!(!fm.is_test_line(1));
        assert!(fm.is_test_line(2));
        assert!(fm.is_test_line(3));
    }

    #[test]
    fn comment_only_detection() {
        let fm = FileModel::parse("// just words\nlet x = 1; // tail\n\n");
        assert!(fm.is_comment_only(1));
        assert!(!fm.is_comment_only(2));
        assert!(!fm.is_comment_only(3));
    }

    #[test]
    fn find_word_respects_boundaries() {
        assert_eq!(find_word("foo unsafely", "unsafe"), Vec::<usize>::new());
        assert_eq!(find_word("an unsafe block", "unsafe"), vec![3]);
        assert_eq!(find_word("unsafe", "unsafe"), vec![0]);
    }
}
