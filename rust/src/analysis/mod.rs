//! `alb lint` — repo-invariant static analysis (DESIGN.md §15).
//!
//! The determinism story of this reproduction (bit-identical results
//! across thread counts, GPU counts, and fault plans) rests on coding
//! conventions that no compiler checks: wall-clock reads stay out of
//! result paths, hash-ordered iteration never feeds ordered output,
//! `unsafe` lives in two audited modules with written safety arguments,
//! and every SWAR hot path keeps a scalar twin wired into a parity test.
//! This module turns those conventions into machine-checked rules, in the
//! spirit of the IrGL compiler the source paper builds on: *check* the
//! program, don't trust it.
//!
//! Layout:
//!
//! - [`lexer`]: a per-line code/comment/literal model of Rust source — no
//!   parse tree, just enough structure that rules cannot be fooled by
//!   strings or comments.
//! - [`rules`]: the rule engine — stable IDs (D/U/T/C families),
//!   `file:line` diagnostics. See its module docs for the full table.
//! - [`allowlist`]: the committed suppression file `LINT_ALLOW.txt`;
//!   every entry carries a justification and goes stale-and-fails when
//!   the line it covered disappears.
//!
//! Entry points: [`run_lint`] (walk a repo root, apply the allowlist,
//! produce a [`LintReport`]) drives the `alb lint` CLI verb and the tier-1
//! gate in `rust/tests/lint.rs`; [`rules::lint_source`] runs the
//! file-scoped rules on one in-memory source (the fixture corpus);
//! [`load_tree`]/[`rules::lint_tree`] expose the tree level for tests that
//! mutate a loaded tree and assert the gate trips.

pub mod allowlist;
pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metrics::Json;
pub use rules::{lint_source, lint_tree, Diagnostic, SourceFile, Tree};

/// The committed twin manifest (see `twins.list` and the T-rules).
pub const TWINS_MANIFEST: &str = include_str!("twins.list");

/// Allowlist filename, resolved relative to the lint root.
pub const ALLOWLIST_FILE: &str = "LINT_ALLOW.txt";

/// Directories scanned for `.rs` sources, relative to the lint root.
pub const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/tests", "benches", "examples"];

/// The outcome of one lint run.
pub struct LintReport {
    /// Unsuppressed diagnostics, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    /// Diagnostics suppressed by a justified allowlist entry.
    pub suppressed: usize,
    /// Stale or malformed allowlist entries — these fail the run too.
    pub stale: Vec<String>,
    pub files_scanned: usize,
}

impl LintReport {
    /// A run is clean only if nothing fired *and* the allowlist is tight.
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty() && self.stale.is_empty()
    }

    /// Machine-readable form (the CI `lint-invariants` artifact).
    pub fn to_json(&self) -> Json {
        let diags: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|d| {
                Json::obj()
                    .set("rule", d.rule)
                    .set("file", d.file.as_str())
                    .set("line", d.line as u64)
                    .set("message", d.message.as_str())
                    .set("text", d.text.as_str())
            })
            .collect();
        let stale: Vec<Json> = self.stale.iter().map(|s| Json::from(s.as_str())).collect();
        Json::obj()
            .set("clean", self.clean())
            .set("diagnostics", diags)
            .set("files_scanned", self.files_scanned as u64)
            .set("stale_allowlist", stale)
            .set("suppressed", self.suppressed as u64)
    }

    /// Human-readable form (the default CLI output).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&d.render());
            s.push('\n');
        }
        for e in &self.stale {
            s.push_str(e);
            s.push('\n');
        }
        s.push_str(&format!(
            "lint: {} file(s) scanned, {} diagnostic(s), {} suppressed, {} stale \
             allowlist entr{}\n",
            self.files_scanned,
            self.diagnostics.len(),
            self.suppressed,
            self.stale.len(),
            if self.stale.len() == 1 { "y" } else { "ies" },
        ));
        s
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn walk_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("read_dir {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Parse every source under the [`SCAN_DIRS`] of `root` plus DESIGN.md's
/// section list into a [`Tree`] ready for [`rules::lint_tree`].
pub fn load_tree(root: &Path) -> Result<Tree> {
    let mut paths = Vec::new();
    for sub in SCAN_DIRS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk_rs(&dir, &mut paths)?;
        }
    }
    if paths.is_empty() {
        bail!(
            "no .rs sources under {} — pass the repository root via --root",
            root.display()
        );
    }
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let src =
            fs::read_to_string(p).with_context(|| format!("read {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile::new(rel, &src));
    }
    let design_path = root.join("DESIGN.md");
    let design = fs::read_to_string(&design_path)
        .with_context(|| format!("read {} (needed for the C002 rule)", design_path.display()))?;
    let design_sections: BTreeSet<u32> = rules::design_sections(&design);
    Ok(Tree { files, design_sections, manifest: TWINS_MANIFEST.to_string() })
}

/// Lint the repo at `root`: load the tree, run every rule, filter through
/// `LINT_ALLOW.txt`. Errors are environmental (unreadable files); rule
/// findings land in the report, whose [`LintReport::clean`] decides the
/// process exit.
pub fn run_lint(root: &Path) -> Result<LintReport> {
    let tree = load_tree(root)?;
    let diags = rules::lint_tree(&tree);
    let allow_text = fs::read_to_string(root.join(ALLOWLIST_FILE)).unwrap_or_default();
    let list = allowlist::parse(&allow_text);
    let applied = list.apply(diags);
    let mut stale = list.errors;
    stale.extend(applied.stale);
    Ok(LintReport {
        diagnostics: applied.kept,
        suppressed: applied.suppressed,
        stale,
        files_scanned: tree.files.len(),
    })
}
