//! The `alb lint` rule engine (DESIGN.md §15).
//!
//! Every rule has a stable ID and a one-line contract:
//!
//! | ID   | family      | contract                                                        |
//! |------|-------------|-----------------------------------------------------------------|
//! | D001 | determinism | no wall-clock reads outside the allowlisted host-timing sites   |
//! | D002 | determinism | no iteration over hash-ordered collections in product code      |
//! | D003 | determinism | no ambient randomness (`RandomState`, `thread_rng`, `rand::`)   |
//! | U001 | unsafe      | every `unsafe` is immediately preceded by a `// SAFETY:` comment |
//! | U002 | unsafe      | `unsafe` is confined to `exec/mod.rs` and `comm/bsp.rs`         |
//! | T001 | twins       | every manifest hot path and its `*_ref` twin still exist        |
//! | T002 | twins       | every `*_ref` twin is referenced from a parity/oracle test      |
//! | C001 | consistency | flag-parse error messages name the valid set                    |
//! | C002 | consistency | `DESIGN.md §N` references resolve to an existing section        |
//!
//! D-rules and C001 govern product code only: they stop at the file's
//! trailing `#[cfg(test)]` region and skip `rust/tests/`, `benches/`, and
//! `examples/`. U-rules scan everything — an unsound test is still
//! unsound. The rules are deliberately syntactic (no type information), so
//! each one is tuned to the shapes this tree actually contains and is
//! pinned by the fixture corpus in `rust/tests/lint.rs`; intentional
//! violations are suppressed via `LINT_ALLOW.txt` (see
//! [`super::allowlist`]), never by weakening a rule.

use std::collections::BTreeSet;

use super::lexer::{contains_word, find_word, ident_char, FileModel};

/// Wall-clock reads are allowed only at these host-timing sites: the bench
/// harness, the campaign runner's per-cell `host_ms`, the coordinator's
/// advisory timings, and the CLI's end-to-end report. All are measurement
/// channels; none feed results, hashes, or artifacts bytes.
const D001_ALLOWED_FILES: [&str; 3] =
    ["rust/src/metrics/bench.rs", "rust/src/campaign/runner.rs", "rust/src/main.rs"];
const D001_ALLOWED_PREFIXES: [&str; 1] = ["rust/src/coordinator/"];

/// The only modules allowed to contain `unsafe` (DESIGN.md §9): the
/// caller-participating job pool and the per-index exclusive exchange view.
const U002_ALLOWED_FILES: [&str; 2] = ["rust/src/exec/mod.rs", "rust/src/comm/bsp.rs"];

/// Iterator methods whose order is the hash order of the receiver.
const D002_METHODS: [&str; 9] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "into_keys",
    "into_values", "drain",
];

/// One `file:line` finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Stable rule ID (`D001`, `U002`, ...).
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based line (0 for file-level findings like a missing twin).
    pub line: usize,
    pub message: String,
    /// The offending line, trimmed — also the allowlist match target.
    pub text: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!("{} {}:{} {} | {}", self.rule, self.file, self.line, self.message, self.text)
    }
}

/// A parsed source file plus its repo-relative path.
pub struct SourceFile {
    pub path: String,
    pub model: FileModel,
}

impl SourceFile {
    pub fn new(path: impl Into<String>, src: &str) -> SourceFile {
        SourceFile { path: path.into(), model: FileModel::parse(src) }
    }
}

/// Everything tree-scoped rules need: the parsed sources, the set of
/// `## §N` sections in DESIGN.md, and the twin manifest text.
pub struct Tree {
    pub files: Vec<SourceFile>,
    pub design_sections: BTreeSet<u32>,
    pub manifest: String,
}

/// Section numbers declared as `## §N ...` headings in DESIGN.md.
pub fn design_sections(md: &str) -> BTreeSet<u32> {
    md.lines()
        .filter_map(|l| {
            let rest = l.strip_prefix("##")?.trim_start();
            let rest = rest.strip_prefix('§')?;
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits.parse().ok()
        })
        .collect()
}

/// Run the file-scoped rules (D001–D003, U001, U002, C001) on one source.
/// This is the fixture-corpus entry point; [`lint_tree`] adds the
/// tree-scoped rules (T001, T002, C002) on top.
pub fn lint_source(path: &str, src: &str) -> Vec<Diagnostic> {
    let f = SourceFile::new(path, src);
    let mut out = Vec::new();
    lint_file(&f, &mut out);
    sort(&mut out);
    out
}

/// Run every rule over a loaded tree.
pub fn lint_tree(tree: &Tree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in &tree.files {
        lint_file(f, &mut out);
        rule_c002(f, &tree.design_sections, &mut out);
    }
    check_twins(&tree.manifest, &tree.files, &mut out);
    sort(&mut out);
    out
}

fn sort(out: &mut [Diagnostic]) {
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

fn lint_file(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    rule_d001(f, out);
    rule_d002(f, out);
    rule_d003(f, out);
    rule_u001(f, out);
    rule_u002(f, out);
    rule_c001(f, out);
}

fn diag(
    out: &mut Vec<Diagnostic>,
    rule: &'static str,
    f: &SourceFile,
    line: usize,
    message: impl Into<String>,
) {
    let text = if line >= 1 && line <= f.model.lines.len() {
        f.model.line(line).raw.trim().to_string()
    } else {
        String::new()
    };
    out.push(Diagnostic { rule, file: f.path.clone(), line, message: message.into(), text });
}

/// Last 1-based product-code line + 1 (i.e. iterate `1..limit`).
fn product_limit(fm: &FileModel) -> usize {
    fm.test_start.unwrap_or(fm.lines.len() + 1)
}

fn in_src(path: &str) -> bool {
    path.starts_with("rust/src/")
}

// ---------------------------------------------------------------- D-rules

fn rule_d001(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_src(&f.path)
        || D001_ALLOWED_FILES.contains(&f.path.as_str())
        || D001_ALLOWED_PREFIXES.iter().any(|p| f.path.starts_with(p))
    {
        return;
    }
    for no in 1..product_limit(&f.model) {
        let code = &f.model.line(no).code;
        if code.contains("Instant::now") || contains_word(code, "SystemTime") {
            diag(
                out,
                "D001",
                f,
                no,
                "wall-clock read outside the allowlisted host-timing sites \
                 (bench.rs, campaign/runner.rs, coordinator/, main.rs)",
            );
        }
    }
}

fn rule_d002(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_src(&f.path) {
        return;
    }
    let fm = &f.model;
    let limit = product_limit(fm);
    let mut idents: BTreeSet<String> = BTreeSet::new();
    for no in 1..limit {
        collect_hash_idents(&fm.line(no).code, &mut idents);
    }
    if idents.is_empty() {
        return;
    }

    // One flat code-view text so receiver and method may sit on different
    // lines (`prior\n    .values()`).
    let mut text = String::new();
    let mut starts: Vec<usize> = Vec::with_capacity(fm.lines.len());
    for l in &fm.lines {
        starts.push(text.len());
        text.push_str(&l.code);
        text.push('\n');
    }
    let line_of = |pos: usize| -> usize {
        match starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i, // i >= 1: starts[0] == 0
        }
    };

    for meth in D002_METHODS {
        for p in find_word(&text, meth) {
            let after = text[p + meth.len()..].trim_start();
            if !after.starts_with('(') {
                continue;
            }
            let Some(name) = receiver_before(&text, p) else { continue };
            if !idents.contains(&name) {
                continue;
            }
            let no = line_of(p);
            if no >= limit {
                continue;
            }
            diag(
                out,
                "D002",
                f,
                no,
                format!(
                    "iteration over hash-ordered collection `{name}` — sort \
                     before iterating or use a BTree collection"
                ),
            );
        }
    }

    for no in 1..limit {
        let code = &fm.line(no).code;
        for name in for_loop_receivers(code) {
            if idents.contains(&name) {
                diag(
                    out,
                    "D002",
                    f,
                    no,
                    format!(
                        "for-loop over hash-ordered collection `{name}` — sort \
                         before iterating or use a BTree collection"
                    ),
                );
            }
        }
    }
}

fn rule_d003(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_src(&f.path) {
        return;
    }
    for no in 1..product_limit(&f.model) {
        let code = &f.model.line(no).code;
        if contains_word(code, "RandomState")
            || contains_word(code, "thread_rng")
            || code.contains("rand::")
        {
            diag(
                out,
                "D003",
                f,
                no,
                "ambient randomness in src/ — all randomness must flow from \
                 an explicit seed",
            );
        }
    }
}

// ---------------------------------------------------------------- U-rules

fn rule_u001(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    let fm = &f.model;
    for no in 1..=fm.lines.len() {
        let l = fm.line(no);
        if !contains_word(&l.code, "unsafe") {
            continue;
        }
        if l.comment.contains("SAFETY:") {
            continue;
        }
        let mut ok = false;
        let mut j = no;
        while j > 1 && fm.is_comment_only(j - 1) {
            j -= 1;
            if fm.line(j).comment.contains("SAFETY:") {
                ok = true;
                break;
            }
        }
        if !ok {
            diag(
                out,
                "U001",
                f,
                no,
                "`unsafe` without an immediately preceding `// SAFETY:` comment",
            );
        }
    }
}

fn rule_u002(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    if U002_ALLOWED_FILES.contains(&f.path.as_str()) {
        return;
    }
    for no in 1..=f.model.lines.len() {
        if contains_word(&f.model.line(no).code, "unsafe") {
            diag(
                out,
                "U002",
                f,
                no,
                "`unsafe` outside rust/src/exec/mod.rs and rust/src/comm/bsp.rs",
            );
        }
    }
}

// ---------------------------------------------------------------- C-rules

fn rule_c001(f: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_src(&f.path) {
        return;
    }
    for (no, lit) in &f.model.literals {
        if f.model.is_test_line(*no) || !lit.contains("--") {
            continue;
        }
        let low = lit.to_ascii_lowercase();
        if !(low.contains("unknown") || low.contains("invalid") || low.contains("bad ")) {
            continue;
        }
        // "invalid" alone must not satisfy the "names the valid set" check.
        let stripped = low.replace("invalid", "");
        if stripped.contains("valid") || lit.contains('|') || lit.contains("..=") {
            continue;
        }
        diag(
            out,
            "C001",
            f,
            *no,
            "flag-parse error message does not name the valid set \
             (list the accepted values, a `a|b` alternation, or a `..=` range)",
        );
    }
}

fn rule_c002(f: &SourceFile, sections: &BTreeSet<u32>, out: &mut Vec<Diagnostic>) {
    for no in 1..=f.model.lines.len() {
        let l = f.model.line(no);
        // Scan the code and comment views, not the raw line: references
        // live in comments (and occasionally code paths), while string
        // literals may quote section numbers as data — e.g. the lint
        // fixture corpus itself.
        let hay = format!("{} {}", l.code, l.comment);
        let mut start = 0usize;
        while let Some(k) = hay[start..].find("DESIGN.md") {
            let at = start + k + "DESIGN.md".len();
            start = at;
            let rest = hay[at..].trim_start();
            let Some(rest) = rest.strip_prefix('§') else { continue };
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            let Ok(nref) = digits.parse::<u32>() else { continue };
            if !sections.contains(&nref) {
                diag(
                    out,
                    "C002",
                    f,
                    no,
                    format!("reference to DESIGN.md §{nref}, which has no `## §{nref}` section"),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- T-rules

/// One line of the committed twin manifest:
/// `name | optimized_fn | file | twin_fn`.
pub struct TwinEntry {
    pub name: String,
    pub optimized: String,
    pub file: String,
    pub twin: String,
}

/// Parse the manifest; malformed lines become T001 diagnostics against the
/// manifest itself.
pub fn parse_manifest(text: &str) -> (Vec<TwinEntry>, Vec<Diagnostic>) {
    let mut entries = Vec::new();
    let mut diags = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = t.splitn(4, '|').map(str::trim).collect();
        if parts.len() != 4 || parts.iter().any(|p| p.is_empty()) {
            diags.push(Diagnostic {
                rule: "T001",
                file: "rust/src/analysis/twins.list".into(),
                line: i + 1,
                message: "malformed manifest line: want `name | optimized_fn | file | twin_fn`"
                    .into(),
                text: t.to_string(),
            });
            continue;
        }
        entries.push(TwinEntry {
            name: parts[0].into(),
            optimized: parts[1].into(),
            file: parts[2].into(),
            twin: parts[3].into(),
        });
    }
    (entries, diags)
}

/// T001/T002 over a parsed tree: each manifest entry's optimized path and
/// `*_ref` twin must exist, and the twin must be exercised from a test —
/// either the defining file's `#[cfg(test)]` region, or any file under
/// `rust/tests/` or `benches/`.
pub fn check_twins(manifest: &str, files: &[SourceFile], out: &mut Vec<Diagnostic>) {
    let (entries, mut parse_diags) = parse_manifest(manifest);
    out.append(&mut parse_diags);
    for e in &entries {
        let Some(f) = files.iter().find(|f| f.path == e.file) else {
            out.push(Diagnostic {
                rule: "T001",
                file: e.file.clone(),
                line: 0,
                message: format!("manifest entry `{}`: file not found in tree", e.name),
                text: String::new(),
            });
            continue;
        };
        let def_line = fn_def_line(&f.model, &e.twin);
        let Some(def_line) = def_line else {
            out.push(Diagnostic {
                rule: "T001",
                file: e.file.clone(),
                line: 0,
                message: format!(
                    "twin `{}` for hot path `{}` is not defined in this file",
                    e.twin, e.name
                ),
                text: String::new(),
            });
            continue;
        };
        if fn_def_line(&f.model, &e.optimized).is_none() {
            out.push(Diagnostic {
                rule: "T001",
                file: e.file.clone(),
                line: 0,
                message: format!(
                    "optimized path `{}` for `{}` is not defined in this file — \
                     update twins.list",
                    e.optimized, e.name
                ),
                text: String::new(),
            });
        }
        let mut referenced = (1..=f.model.lines.len()).any(|no| {
            no != def_line
                && f.model.is_test_line(no)
                && contains_word(&f.model.line(no).code, &e.twin)
        });
        if !referenced {
            referenced = files.iter().any(|g| {
                (g.path.starts_with("rust/tests/") || g.path.starts_with("benches/"))
                    && g.model.lines.iter().any(|l| contains_word(&l.code, &e.twin))
            });
        }
        if !referenced {
            out.push(Diagnostic {
                rule: "T002",
                file: e.file.clone(),
                line: def_line,
                message: format!(
                    "twin `{}` is not referenced from any parity/oracle test \
                     (same-file test region, rust/tests/, or benches/)",
                    e.twin
                ),
                text: f.model.line(def_line).raw.trim().to_string(),
            });
        }
    }
}

/// 1-based line where `fn <name>` is defined (whole-word, `fn` immediately
/// before), or None.
fn fn_def_line(fm: &FileModel, name: &str) -> Option<usize> {
    for no in 1..=fm.lines.len() {
        let code = &fm.line(no).code;
        for p in find_word(code, name) {
            let pre = code[..p].trim_end();
            if pre.ends_with("fn")
                && (pre.len() == 2 || !ident_char(pre.as_bytes()[pre.len() - 3] as char))
            {
                return Some(no);
            }
        }
    }
    None
}

// ------------------------------------------------- D002 textual helpers

fn ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

fn skip_spaces_back(b: &[u8], mut j: isize) -> isize {
    while j >= 0 && (b[j as usize] == b' ' || b[j as usize] == b'\t') {
        j -= 1;
    }
    j
}

/// The identifier ending at byte `j` (inclusive); returns (index before the
/// identifier, identifier).
fn word_ending_at(b: &[u8], j: isize) -> (isize, String) {
    let end = j;
    let mut k = j;
    while k >= 0 && ident_byte(b[k as usize]) {
        k -= 1;
    }
    if end < 0 || k == end {
        return (k, String::new());
    }
    let w = String::from_utf8_lossy(&b[(k + 1) as usize..=(end as usize)]).into_owned();
    (k, w)
}

/// Collect names bound to `HashMap`/`HashSet` on this code line, from both
/// shapes the tree contains: a typed binding/field/param
/// (`name: [&][mut] [path::]HashMap<...>`) and a `let` initialisation
/// (`let [mut] name = HashMap::new/with_capacity/default/from(...)`).
fn collect_hash_idents(code: &str, idents: &mut BTreeSet<String>) {
    let b = code.as_bytes();
    for word in ["HashMap", "HashSet"] {
        for p in find_word(code, word) {
            let after = code[p + word.len()..].trim_start();
            if after.starts_with('<') {
                if let Some(name) = typed_decl_name(b, p) {
                    idents.insert(name);
                }
            } else if let Some(rest) = after.strip_prefix("::") {
                let rest = rest.trim_start();
                let is_ctor = ["new", "with_capacity", "default", "from"]
                    .iter()
                    .any(|c| {
                        rest.strip_prefix(c).is_some_and(|r| {
                            !r.starts_with(|ch: char| ident_char(ch))
                        })
                    });
                if is_ctor {
                    if let Some(name) = let_binding_name(code, p) {
                        idents.insert(name);
                    }
                }
            }
        }
    }
}

/// For `name: [&][mut] [path::]Hash...` with the type word starting at
/// byte `p`, walk backwards to the declared name.
fn typed_decl_name(b: &[u8], p: usize) -> Option<String> {
    let mut j = p as isize - 1;
    // strip a `path::segment::` chain
    loop {
        if j >= 1 && b[j as usize] == b':' && b[(j - 1) as usize] == b':' {
            j -= 2;
            while j >= 0 && ident_byte(b[j as usize]) {
                j -= 1;
            }
        } else {
            break;
        }
    }
    j = skip_spaces_back(b, j);
    let (k, w) = word_ending_at(b, j);
    if w == "mut" {
        j = skip_spaces_back(b, k);
    }
    if j >= 0 && b[j as usize] == b'&' {
        j = skip_spaces_back(b, j - 1);
    }
    if j < 0 || b[j as usize] != b':' {
        return None;
    }
    if j >= 1 && b[(j - 1) as usize] == b':' {
        return None; // `::` — a path, not a declaration colon
    }
    j = skip_spaces_back(b, j - 1);
    let (_, name) = word_ending_at(b, j);
    let first = name.chars().next()?;
    if first.is_lowercase() || first == '_' {
        Some(name)
    } else {
        None
    }
}

/// For `let [mut] name = Hash...::ctor(...)` with the type word at byte
/// `p`, read the binding name after the `let`.
fn let_binding_name(code: &str, p: usize) -> Option<String> {
    let let_pos = find_word(code, "let").into_iter().find(|&l| l < p)?;
    let rest = code[let_pos + 3..].trim_start();
    let rest = rest.strip_prefix("mut").map_or(rest, |r| r.trim_start());
    let name: String = rest.chars().take_while(|&c| ident_char(c)).collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// The receiver identifier of `.method(` at byte `p` (start of the method
/// name), skipping whitespace — so a receiver on the previous line is
/// still found.
fn receiver_before(text: &str, p: usize) -> Option<String> {
    let b = text.as_bytes();
    let mut j = p as isize - 1;
    while j >= 0 && (b[j as usize] as char).is_whitespace() {
        j -= 1;
    }
    if j < 0 || b[j as usize] != b'.' {
        return None;
    }
    j -= 1;
    while j >= 0 && (b[j as usize] as char).is_whitespace() {
        j -= 1;
    }
    let (_, name) = word_ending_at(b, j);
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Receivers of `for ... in [&][mut ]name {` loops on this code line.
fn for_loop_receivers(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    for fp in find_word(code, "for") {
        for ip in find_word(code, "in") {
            if ip <= fp {
                continue;
            }
            let mut rest = code[ip + 2..].trim_start();
            rest = rest.strip_prefix('&').unwrap_or(rest).trim_start();
            rest = rest
                .strip_prefix("mut ")
                .map_or(rest, |r| r.trim_start());
            let name: String = rest.chars().take_while(|&c| ident_char(c)).collect();
            if name.is_empty() {
                continue;
            }
            let tail = rest[name.len()..].trim_start();
            if tail.starts_with('{') {
                out.push(name);
            }
            break; // only the first `in` after this `for`
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(path: &str, src: &str) -> Vec<String> {
        lint_source(path, src)
            .into_iter()
            .map(|d| format!("{}:{}", d.rule, d.line))
            .collect()
    }

    #[test]
    fn d002_sees_receiver_on_previous_line() {
        let src = "use std::collections::HashMap;\n\
                   fn f(prior: &HashMap<String, u32>) -> Vec<u32> {\n\
                       let keep: Vec<u32> = prior\n\
                           .values()\n\
                           .cloned()\n\
                           .collect();\n\
                       keep\n\
                   }\n";
        assert_eq!(rules_of("rust/src/x.rs", src), vec!["D002:4"]);
    }

    #[test]
    fn d002_ignores_lookups_and_btree() {
        let src = "use std::collections::{BTreeMap, HashMap};\n\
                   fn f(m: &HashMap<String, u32>, b: &BTreeMap<String, u32>) -> u32 {\n\
                       let mut s = 0;\n\
                       for (_k, v) in b.iter() {\n\
                           s += v;\n\
                       }\n\
                       s + m.get(\"x\").copied().unwrap_or(0)\n\
                   }\n";
        assert!(rules_of("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn design_section_parse() {
        let md = "# title\n## §1 First\ntext\n## §12 Twelfth\n";
        let s = design_sections(md);
        assert!(s.contains(&1) && s.contains(&12) && !s.contains(&2));
    }

    #[test]
    fn fn_def_line_requires_fn_keyword() {
        let fm =
            FileModel::parse("pub fn access_ref(x: u64) -> u64 { x }\nlet y = access_ref(1);\n");
        assert_eq!(fn_def_line(&fm, "access_ref"), Some(1));
        assert_eq!(fn_def_line(&fm, "access"), None);
    }
}
