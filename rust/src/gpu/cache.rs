//! Set-associative LRU cache model.
//!
//! Exists to make the paper's §4.1 locality argument *emerge* rather than be
//! hard-coded: in the cyclic distribution, the 32 lanes of a warp binary-
//! search for consecutive edge ids, so their probe trajectories touch the
//! same prefix-array cache lines (hits); in the blocked distribution the
//! lanes search ids separated by `edges_per_thread`, touching scattered
//! lines (misses). The LB-kernel simulator pushes every (deduplicated) probe
//! through this model and charges hit/miss cycles accordingly.

/// A set-associative cache with LRU replacement, tracking line tags only.
///
/// Storage is one flat `Vec<u64>` with `assoc` consecutive slots per set
/// (MRU last, `u64::MAX` = empty); `access` is a short in-place scan +
/// rotate — this sits on the LB-kernel simulator's innermost loop (§Perf),
/// so no per-set allocation or element shifting through `Vec::remove`.
#[derive(Debug, Clone)]
pub struct CacheSim {
    /// `slots[set * assoc .. (set+1) * assoc]`, most-recently-used last.
    slots: Vec<u64>,
    num_sets: u64,
    assoc: usize,
    line_bytes: u64,
    /// Line tag of the most recent [`access`](Self::access) (§Perf,
    /// DESIGN.md §13): probe trajectories touch long runs of same-line
    /// addresses, and a repeat of the last line is always a hit that leaves
    /// the LRU state unchanged — the line is already MRU in its set, so the
    /// hit-rotate the slow path would perform is a no-op.
    last_line: u64,
    hits: u64,
    misses: u64,
}

const EMPTY: u64 = u64::MAX;

/// Geometry derivation shared by [`CacheSim::new`] and
/// [`CacheSim::matches`]: `(num_sets, assoc, line_bytes)`.
fn geometry(capacity_kb: u32, line_bytes: u32, assoc: u32) -> (u64, usize, u64) {
    let lines = (capacity_kb as u64 * 1024) / line_bytes as u64;
    let num_sets = (lines / assoc as u64).max(1);
    (num_sets, assoc as usize, line_bytes as u64)
}

impl CacheSim {
    /// `capacity_kb` total, `line_bytes` per line, `assoc` ways.
    pub fn new(capacity_kb: u32, line_bytes: u32, assoc: u32) -> Self {
        let (num_sets, assoc, line_bytes) = geometry(capacity_kb, line_bytes, assoc);
        CacheSim {
            slots: vec![EMPTY; num_sets as usize * assoc],
            num_sets,
            assoc,
            line_bytes,
            last_line: EMPTY,
            hits: 0,
            misses: 0,
        }
    }

    /// Access a byte address; returns `true` on hit. Updates LRU state.
    ///
    /// Same-line runs short-circuit through the `last_line` tag: the
    /// previous access left that line MRU in its set, so counting the hit
    /// without touching the ways is bit-identical to the full walk
    /// ([`access_ref`](Self::access_ref) is the pre-fast-path twin).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr / self.line_bytes;
        if line == self.last_line {
            self.hits += 1;
            return true;
        }
        self.last_line = line;
        self.access_line(line)
    }

    /// The set scan + LRU rotate shared by both access paths.
    #[inline]
    fn access_line(&mut self, line: u64) -> bool {
        let set = (line % self.num_sets) as usize * self.assoc;
        let ways = &mut self.slots[set..set + self.assoc];
        // MRU is the last slot; scan backwards so the hot line hits first.
        for pos in (0..ways.len()).rev() {
            if ways[pos] == line {
                ways[pos..].rotate_left(1);
                self.hits += 1;
                return true;
            }
        }
        // Miss: evict LRU (slot 0) by shifting everything down one.
        ways.rotate_left(1);
        ways[self.assoc - 1] = line;
        self.misses += 1;
        false
    }

    /// The pre-fast-path access — the full set scan on every call, no
    /// `last_line` involvement — kept in-binary as the `-ref` twin for the
    /// oracle tests and the reference LB simulation. Do not interleave with
    /// [`access`](Self::access) on one instance: this path does not
    /// maintain the tag.
    #[doc(hidden)]
    pub fn access_ref(&mut self, addr: u64) -> bool {
        self.access_line(addr / self.line_bytes)
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Invalidate every line and zero the counters — equivalent to a fresh
    /// `CacheSim` of the same geometry. Lets the LB-kernel simulator keep
    /// one pooled instance per scratch instead of allocating per sampled
    /// warp (§Perf).
    pub fn reset_all(&mut self) {
        self.slots.fill(EMPTY);
        self.last_line = EMPTY;
        self.hits = 0;
        self.misses = 0;
    }

    /// Whether this cache has the geometry `new(capacity_kb, line_bytes,
    /// assoc)` would produce (pooled instances are rebuilt on mismatch).
    pub fn matches(&self, capacity_kb: u32, line_bytes: u32, assoc: u32) -> bool {
        (self.num_sets, self.assoc, self.line_bytes)
            == geometry(capacity_kb, line_bytes, assoc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_second_hits() {
        let mut c = CacheSim::new(16, 64, 4);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        // 1 set x 2 ways: capacity 2 lines.
        let mut c = CacheSim::new(0, 64, 2);
        assert_eq!(c.num_sets, 1);
        c.access(0); // line 0
        c.access(64); // line 1
        c.access(0); // refresh line 0
        c.access(128); // evicts line 1 (LRU)
        assert!(c.access(0), "line 0 must survive");
        assert!(!c.access(64), "line 1 must have been evicted");
    }

    #[test]
    fn distinct_sets_do_not_conflict() {
        let mut c = CacheSim::new(16, 64, 1);
        // Lines mapping to different sets coexist even at assoc 1.
        assert!(!c.access(0));
        assert!(!c.access(64));
        assert!(c.access(0));
        assert!(c.access(64));
    }

    #[test]
    fn sequential_trajectories_hit_like_cyclic_warps() {
        // Two consecutive binary searches over the same array share their
        // root-side probes -> high hit rate. This is the cyclic-distribution
        // effect the paper relies on.
        let mut c = CacheSim::new(16, 128, 4);
        let probes = |target: u64| {
            // binary search probe addresses over a 1024-entry u64 array
            let (mut lo, mut hi) = (0u64, 1024u64);
            let mut v = Vec::new();
            while lo < hi {
                let mid = (lo + hi) / 2;
                v.push(mid * 8);
                if mid < target {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            v
        };
        for a in probes(500) {
            c.access(a);
        }
        c.reset_stats();
        for a in probes(501) {
            c.access(a);
        }
        assert!(
            c.hits() >= 8,
            "neighboring searches must mostly hit: {} hits {} misses",
            c.hits(),
            c.misses()
        );
    }

    #[test]
    fn reset_stats_clears_counts_not_state() {
        let mut c = CacheSim::new(16, 64, 4);
        c.access(0);
        c.reset_stats();
        assert_eq!(c.misses(), 0);
        assert!(c.access(0), "cached line survives stats reset");
    }

    #[test]
    fn fast_path_oracle_matches_full_walk() {
        // Random address stream with heavy same-line runs (the access
        // pattern the tag targets) through two same-geometry instances:
        // the fast path must agree with the full walk on every return
        // value and on the final counters.
        let mut opt = CacheSim::new(4, 64, 2);
        let mut rf = CacheSim::new(4, 64, 2);
        let mut x = 0x243f6a8885a308d3u64;
        let mut addr = 0u64;
        for _ in 0..20_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            match (x >> 60) & 3 {
                0 => addr = (x >> 33) % (1 << 20), // far jump
                1 => addr += 64,                   // next line
                _ => addr += (x >> 50) & 63,       // same-line run
            }
            assert_eq!(opt.access(addr), rf.access_ref(addr), "addr {addr}");
        }
        assert_eq!(opt.hits(), rf.hits());
        assert_eq!(opt.misses(), rf.misses());
        // Invalidation clears the tag: the next same-line access must miss.
        opt.access(0);
        opt.reset_all();
        assert!(!opt.access(0));
    }

    #[test]
    fn reset_all_equals_fresh_cache() {
        let mut c = CacheSim::new(16, 64, 4);
        c.access(0);
        c.access(64);
        c.reset_all();
        assert_eq!(c.hits() + c.misses(), 0);
        assert!(!c.access(0), "lines must be invalidated");
        assert!(c.matches(16, 64, 4));
        assert!(!c.matches(16, 128, 4));
    }
}
