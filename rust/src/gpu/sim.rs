//! The kernel simulator: executes a [`Schedule`] on a [`GpuSpec`] and
//! returns per-thread-block cycle and edge counts.
//!
//! Modeling decisions (DESIGN.md §5):
//!
//! * All launched blocks are resident (one wave); a kernel finishes when its
//!   slowest block does: `kernel_cycles = launch + max_b block_cycles[b]`.
//! * Within a block, warps execute concurrently; within a warp, a lane's
//!   work is serial. So `block_cycles = max over its threads` of the cycles
//!   charged to that thread (thread-bin work) + its warp's shared work
//!   (warp-bin items) + the block's shared work (CTA-bin items).
//! * TWC work items are assigned round-robin over the matching unit class in
//!   worklist order — exactly the strided `for (src = tid; ...)` loop of the
//!   paper's generated code.
//! * The LB kernel charges every thread `ceil(total_edges/p)` relaxations
//!   plus the binary-search probes, which go through the set-associative
//!   cache model so cyclic/blocked genuinely diverge via locality.
//! * ALB launches the LB kernel *alongside* the TWC kernel (paper §4,
//!   separate streams), so by default a round costs
//!   `scan + max(twc, prefix + lb)`: the inspector's prefix sum gates only
//!   the LB launch and overlaps TWC. [`CostModel::serial_kernels`] restores
//!   the historical back-to-back accounting (`scan + twc + prefix + lb`).
//!
//! Hot-path memory discipline (DESIGN.md §8): the engine calls
//! [`Simulator::simulate_into`] with a per-run [`SimScratch`] that keeps the
//! per-thread/warp/CTA accounting arrays, the probe-line buffer, the pooled
//! cache model, and the recycled [`KernelStats`] across rounds — the steady
//! state allocates nothing. [`Simulator::simulate`] wraps it for one-shot
//! callers, and [`Simulator::simulate_reference`] preserves the
//! fresh-allocation, lane-by-lane implementation as the golden reference
//! (`rust/tests/parity.rs`) and the pre-optimization baseline
//! (`benches/hotpath.rs`).
//!
//! Intra-GPU parallel simulation (DESIGN.md §9):
//! [`Simulator::simulate_into_pooled`] splits the block and warp walks into
//! fixed contiguous chunks and runs them as [`crate::exec::Pool`] tasks.
//! Each chunk simulates into its own [`SimScratch`] arena slot (per-chunk
//! cache model, line buffer, and partial-result fields — §8's zero-
//! allocation discipline survives) and the caller folds per-block results
//! **in block order**, so the output is bit-identical to the sequential
//! walk for any worker count. The per-warp and per-block bodies
//! ([`Simulator`]'s `lb_warp` / `twc_block_chunk` / `lb_block_edges_chunk`)
//! are shared verbatim between the two paths so they cannot drift.

use std::sync::Mutex;

use crate::exec::Pool;
use crate::gpu::cache::CacheSim;
use crate::gpu::cost::CostModel;
use crate::gpu::model::GpuSpec;
use crate::lb::schedule::{Distribution, LbLaunch, Schedule, Unit, VertexItem};


/// Per-kernel simulation result.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelStats {
    pub label: &'static str,
    /// Edges processed by each thread block (the paper's Figures 1 and 5).
    pub block_edges: Vec<u64>,
    /// Modeled cycles per block.
    pub block_cycles: Vec<u64>,
    /// Launch overhead + slowest block.
    pub kernel_cycles: u64,
    pub total_edges: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl KernelStats {
    /// Load-imbalance factor: max block edges / mean block edges.
    /// An empty kernel (no launched blocks recorded) is perfectly balanced
    /// by definition: `1.0`, never `0/0`.
    pub fn imbalance_factor(&self) -> f64 {
        if self.block_edges.is_empty() {
            return 1.0;
        }
        let max = *self.block_edges.iter().max().unwrap_or(&0) as f64;
        let sum: u64 = self.block_edges.iter().sum();
        let mean = sum as f64 / self.block_edges.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// One round's simulation: the launched kernels plus worklist management.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundSim {
    pub kernels: Vec<KernelStats>,
    /// Worklist scan + inspector prefix-sum cycles.
    pub overhead_cycles: u64,
    /// Total modeled cycles for the round. Under the default concurrent
    /// accounting this is `scan + max(twc, prefix + lb)`, NOT the sum of
    /// `kernels[*].kernel_cycles` plus `overhead_cycles`.
    pub total_cycles: u64,
}

/// Reusable per-round simulation buffers (DESIGN.md §8) — one per engine
/// run; the multi-GPU coordinator owns one per simulated GPU, used only by
/// that GPU's BSP thread. All vectors retain their capacity between rounds
/// and the finished [`KernelStats`] are recycled through a pool, so
/// steady-state rounds perform zero heap allocations (asserted by
/// `rust/tests/alloc.rs`).
#[derive(Debug, Default)]
pub struct SimScratch {
    thread_c: Vec<u64>,
    warp_c: Vec<u64>,
    cta_c: Vec<u64>,
    line_buf: Vec<u64>,
    cache: Option<CacheSim>,
    /// Output of the latest [`Simulator::simulate_into`] call.
    pub round: RoundSim,
    /// Recycled kernel stats (keeps the block arrays' capacity).
    pool: Vec<KernelStats>,
    /// Per-chunk worker arenas + partial results for
    /// [`Simulator::simulate_into_pooled`] (DESIGN.md §9). A chunk index is
    /// touched by exactly one pool task per phase; the mutex exists to
    /// satisfy the shared-closure aliasing rules, not for contention.
    chunks: Vec<Mutex<ChunkSim>>,
}

/// One chunk's arena and partial results for the pooled simulation: its own
/// cache model + probe-line buffer (so sampled warps never share mutable
/// state across chunks) and the chunk's per-block / per-warp outputs, folded
/// by the caller in chunk order. All buffers retain capacity across rounds
/// (§8).
#[derive(Debug, Default)]
struct ChunkSim {
    block_cycles: Vec<u64>,
    block_edges: Vec<u64>,
    line_buf: Vec<u64>,
    cache: Option<CacheSim>,
    search_cycles: u64,
    hits: u64,
    misses: u64,
    simulated: u64,
}

impl SimScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the chunk-arena list to at least `n` slots (capacities and the
    /// per-chunk cache models persist across rounds).
    fn ensure_chunks(&mut self, n: usize) {
        while self.chunks.len() < n {
            self.chunks.push(Mutex::new(ChunkSim::default()));
        }
    }

    /// Move last round's kernels back into the pool and zero the summary.
    fn recycle(&mut self) {
        while let Some(k) = self.round.kernels.pop() {
            self.pool.push(k);
        }
        self.round.overhead_cycles = 0;
        self.round.total_cycles = 0;
    }

    /// A cleared [`KernelStats`], from the pool when possible.
    fn fresh_kernel(&mut self, label: &'static str) -> KernelStats {
        let mut k = self.pool.pop().unwrap_or_default();
        k.label = label;
        k.block_edges.clear();
        k.block_cycles.clear();
        k.kernel_cycles = 0;
        k.total_edges = 0;
        k.cache_hits = 0;
        k.cache_misses = 0;
        k
    }

    /// Make sure the pooled cache model exists with `spec`'s geometry
    /// (rebuilt only when the geometry changes).
    fn ensure_cache(&mut self, spec: &GpuSpec) {
        ensure_cache_slot(&mut self.cache, spec);
    }
}

/// Ensure `slot` holds a cache model with `spec`'s geometry (rebuilt only on
/// geometry change) — shared by the scratch's sequential instance and the
/// per-chunk arenas.
fn ensure_cache_slot(slot: &mut Option<CacheSim>, spec: &GpuSpec) {
    let ok = matches!(
        slot,
        Some(c) if c.matches(spec.l1_kb, spec.cache_line_bytes, spec.cache_assoc)
    );
    if !ok {
        *slot = Some(CacheSim::new(spec.l1_kb, spec.cache_line_bytes, spec.cache_assoc));
    }
}

/// Executes schedules against a fixed GPU + cost model.
///
/// Holds only owned, immutable configuration, so it is `Send + Sync`: the
/// multi-GPU coordinator runs one simulation per partition as a shared-pool
/// task every round (`comm::bsp::superstep`), and the pooled simulation's
/// chunk closures capture `&Simulator` across worker threads. The
/// compile-time assertion below keeps that property from regressing
/// silently.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub spec: GpuSpec,
    pub cost: CostModel,
}

const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_send_sync::<Simulator>();
    assert_send_sync::<KernelStats>();
    assert_send_sync::<RoundSim>();
    assert_send::<SimScratch>();
};

impl Simulator {
    pub fn new(spec: GpuSpec, cost: CostModel) -> Self {
        Simulator { spec, cost }
    }

    /// Simulate one round into freshly-allocated buffers. Convenience
    /// wrapper over [`simulate_into`](Self::simulate_into) for tests and
    /// one-shot callers.
    pub fn simulate(&self, sched: &Schedule, push: bool) -> RoundSim {
        let mut scratch = SimScratch::new();
        self.simulate_into(sched, push, &mut scratch);
        scratch.round
    }

    /// Simulate one round into `scratch.round`, reusing every buffer from
    /// the previous round. `push` charges atomic-update cost per edge
    /// (push-style operators write remote labels; pull-style do not).
    pub fn simulate_into(&self, sched: &Schedule, push: bool, scratch: &mut SimScratch) {
        self.simulate_into_capped(sched, push, scratch, None);
    }

    /// [`simulate_into`](Self::simulate_into) with a per-round override of
    /// the LB kernel's sampled-warp budget
    /// ([`CostModel::lb_warp_step_sample_cap`]) — the adaptive controller's
    /// fidelity knob. `None` keeps the configured cap; the override leaves
    /// the (possibly shared) `Simulator` untouched, so per-GPU controllers
    /// can steer independent budgets through one simulator.
    pub fn simulate_into_capped(
        &self,
        sched: &Schedule,
        push: bool,
        scratch: &mut SimScratch,
        sample_cap: Option<u64>,
    ) {
        scratch.recycle();
        let twc = self.sim_twc_into(&sched.twc, push, scratch);
        scratch.round.kernels.push(twc);
        if let Some(lb) = &sched.lb {
            if lb.total_edges() > 0 {
                let k = self.sim_lb_into(lb, push, scratch, sample_cap);
                scratch.round.kernels.push(k);
            }
        }
        let (overhead, total) = self.combine(&scratch.round.kernels, sched);
        scratch.round.overhead_cycles = overhead;
        scratch.round.total_cycles = total;
    }

    /// [`simulate_into`](Self::simulate_into) with the block and warp walks
    /// split into fixed contiguous chunks on `pool` (DESIGN.md §9). Output
    /// is **bit-identical to the sequential walk for any pool width**:
    /// chunks write per-block values into per-chunk arena slots that are
    /// folded in block order, and the only cross-chunk combines are exact
    /// u64 sums. A 1-thread pool takes the sequential path unchanged.
    pub fn simulate_into_pooled(
        &self,
        sched: &Schedule,
        push: bool,
        scratch: &mut SimScratch,
        pool: &Pool,
    ) {
        self.simulate_into_pooled_capped(sched, push, scratch, pool, None);
    }

    /// [`simulate_into_pooled`](Self::simulate_into_pooled) with the
    /// adaptive controller's sampled-warp budget override (see
    /// [`simulate_into_capped`](Self::simulate_into_capped)).
    pub fn simulate_into_pooled_capped(
        &self,
        sched: &Schedule,
        push: bool,
        scratch: &mut SimScratch,
        pool: &Pool,
        sample_cap: Option<u64>,
    ) {
        if pool.threads() <= 1 {
            self.simulate_into_capped(sched, push, scratch, sample_cap);
            return;
        }
        scratch.recycle();
        let twc = self.sim_twc_pooled(&sched.twc, push, scratch, pool);
        scratch.round.kernels.push(twc);
        if let Some(lb) = &sched.lb {
            if lb.total_edges() > 0 {
                let k = self.sim_lb_pooled(lb, push, scratch, pool, sample_cap);
                scratch.round.kernels.push(k);
            }
        }
        let (overhead, total) = self.combine(&scratch.round.kernels, sched);
        scratch.round.overhead_cycles = overhead;
        scratch.round.total_cycles = total;
    }

    /// The golden fresh-allocation reference: same modeled cycles as
    /// [`simulate_into`] (asserted by `rust/tests/parity.rs` and the unit
    /// tests below), implemented with per-call allocations and the
    /// lane-by-lane LB cache walk. Used by the parity gates and as the
    /// pre-optimization baseline in `benches/hotpath.rs`; not a hot path.
    pub fn simulate_reference(&self, sched: &Schedule, push: bool) -> RoundSim {
        let mut kernels = Vec::with_capacity(2);
        kernels.push(self.sim_twc_ref(&sched.twc, push));
        if let Some(lb) = &sched.lb {
            if lb.total_edges() > 0 {
                kernels.push(self.sim_lb_ref(lb, push));
            }
        }
        let (overhead_cycles, total_cycles) = self.combine(&kernels, sched);
        RoundSim { kernels, overhead_cycles, total_cycles }
    }

    /// Fold kernel times + worklist overheads into the round total.
    ///
    /// Concurrent (default): the TWC kernel and the prefix-sum→LB chain run
    /// on separate streams, so the round is their max plus the scan. Serial
    /// ([`CostModel::serial_kernels`]): the historical back-to-back sum.
    fn combine(&self, kernels: &[KernelStats], sched: &Schedule) -> (u64, u64) {
        let scan = sched
            .scan_vertices
            .div_ceil(self.spec.total_threads())
            * self.cost.cycles_scan_vertex;
        // The inspector's prefix sum is itself a parallel scan kernel
        // (paper Fig. 3 line 31, `computePrefixSum`): charged as one launch
        // plus up+down sweeps over the items, spread across all threads.
        let prefix = if sched.prefix_items > 0 {
            self.cost.cycles_launch
                + sched.prefix_items.div_ceil(self.spec.total_threads())
                    * self.cost.cycles_prefix_per_item
                    * 2
        } else {
            0
        };
        let twc_cycles = kernels.first().map_or(0, |k| k.kernel_cycles);
        let lb_cycles = kernels.get(1).map_or(0, |k| k.kernel_cycles);
        let kernel_total = if self.cost.serial_kernels {
            twc_cycles + prefix + lb_cycles
        } else {
            twc_cycles.max(prefix + lb_cycles)
        };
        (scan + prefix, scan + kernel_total)
    }

    /// Per-edge processing cost for this operator class.
    #[inline]
    fn edge_cost(&self, push: bool) -> u64 {
        self.cost.cycles_edge + if push { self.cost.cycles_atomic } else { 0 }
    }

    /// TWC phase 1: exact per-unit round-robin accounting into the
    /// scratch's thread/warp/CTA bins plus per-block edge totals. Stays
    /// sequential even under the pool — the round-robin counters are an
    /// order-dependent walk of the worklist.
    fn twc_bins_into(
        &self,
        items: &[VertexItem],
        push: bool,
        scratch: &mut SimScratch,
        k: &mut KernelStats,
    ) {
        let s = &self.spec;
        let nb = s.num_blocks as usize;
        let tpb = s.threads_per_block as usize;
        let wpb = s.warps_per_block() as usize;
        let nthreads = nb * tpb;
        let nwarps = nb * wpb;
        let warp = s.warp_size as u64;
        let ec = self.edge_cost(push);

        let thread_c = &mut scratch.thread_c;
        let warp_c = &mut scratch.warp_c;
        let cta_c = &mut scratch.cta_c;
        thread_c.clear();
        thread_c.resize(nthreads, 0);
        warp_c.clear();
        warp_c.resize(nwarps, 0);
        cta_c.clear();
        cta_c.resize(nb, 0);
        k.block_edges.resize(nb, 0);

        let (mut ti, mut wi, mut bi) = (0usize, 0usize, 0usize);
        for item in items {
            k.total_edges += item.degree;
            match item.unit {
                Unit::Thread => {
                    let t = ti % nthreads;
                    thread_c[t] += item.degree * ec;
                    k.block_edges[t / tpb] += item.degree;
                    ti += 1;
                }
                Unit::Warp => {
                    let w = wi % nwarps;
                    warp_c[w] += item.degree.div_ceil(warp) * ec;
                    k.block_edges[w / wpb] += item.degree;
                    wi += 1;
                }
                Unit::Block => {
                    let b = bi % nb;
                    cta_c[b] += item.degree.div_ceil(tpb as u64) * ec;
                    k.block_edges[b] += item.degree;
                    bi += 1;
                }
            }
        }
    }

    /// `simulate_chunk`, TWC leg (DESIGN.md §9): the per-block bottleneck
    /// reduction for blocks `[b0, b1)`, one value per block in block order
    /// into `out` (cleared first). Pure per-block arithmetic — shared by
    /// the sequential walk (one chunk covering every block) and the pooled
    /// chunks, so the two cannot drift.
    /// §Perf (DESIGN.md §13): the walk hoists the per-warp and per-block
    /// invariants out of the thread loop — `warp_c[w]` is constant across a
    /// warp's lanes and `cta_c[b]` across the block, so the reduction is
    /// `cta + max over warps (warp + max over lanes thread)` — and runs the
    /// lane max 8 threads per iteration through a `[u64; 8]` accumulator
    /// block (branch-free max lanes the compiler can keep in registers).
    /// `max` over u64 is order-independent, so the output is bit-identical
    /// to [`twc_block_chunk_ref`](Self::twc_block_chunk_ref).
    fn twc_block_chunk(
        &self,
        thread_c: &[u64],
        warp_c: &[u64],
        cta_c: &[u64],
        b0: usize,
        b1: usize,
        out: &mut Vec<u64>,
    ) {
        let tpb = self.spec.threads_per_block as usize;
        let ws = self.spec.warp_size as usize;
        out.clear();
        for b in b0..b1 {
            let block = &thread_c[b * tpb..(b + 1) * tpb];
            let mut worst = 0u64;
            for (wo, lanes) in block.chunks(ws).enumerate() {
                let w = (b * tpb + wo * ws) / ws;
                let mut m = [0u64; 8];
                let mut groups = lanes.chunks_exact(8);
                for g in groups.by_ref() {
                    for (slot, &c) in m.iter_mut().zip(g) {
                        *slot = (*slot).max(c);
                    }
                }
                let mut wmax =
                    groups.remainder().iter().copied().fold(0u64, u64::max);
                for &c in &m {
                    wmax = wmax.max(c);
                }
                worst = worst.max(wmax + warp_c[w]);
            }
            out.push(worst + cta_c[b]);
        }
    }

    /// The pre-SWAR scalar tally (one thread per iteration, the invariant
    /// re-added on every lane), kept in-binary as the `-ref` twin for the
    /// oracle tests and `benches/hotpath.rs`. Not a hot path.
    fn twc_block_chunk_ref(
        &self,
        thread_c: &[u64],
        warp_c: &[u64],
        cta_c: &[u64],
        b0: usize,
        b1: usize,
        out: &mut Vec<u64>,
    ) {
        let tpb = self.spec.threads_per_block as usize;
        let ws = self.spec.warp_size as usize;
        out.clear();
        for b in b0..b1 {
            let mut worst = 0u64;
            for t in b * tpb..(b + 1) * tpb {
                let w = t / ws;
                let c = thread_c[t] + warp_c[w] + cta_c[b];
                worst = worst.max(c);
            }
            out.push(worst);
        }
    }

    /// Bench entry point for the degree-tally SWAR path: the full-grid
    /// per-block bottleneck reduction over caller-supplied accounting
    /// arrays (`benches/hotpath.rs` `degree-tally` case).
    #[doc(hidden)]
    pub fn bench_degree_tally(
        &self,
        thread_c: &[u64],
        warp_c: &[u64],
        cta_c: &[u64],
        out: &mut Vec<u64>,
    ) {
        let nb = self.spec.num_blocks as usize;
        self.twc_block_chunk(thread_c, warp_c, cta_c, 0, nb, out);
    }

    /// [`bench_degree_tally`](Self::bench_degree_tally)'s scalar `-ref`
    /// twin.
    #[doc(hidden)]
    pub fn bench_degree_tally_ref(
        &self,
        thread_c: &[u64],
        warp_c: &[u64],
        cta_c: &[u64],
        out: &mut Vec<u64>,
    ) {
        let nb = self.spec.num_blocks as usize;
        self.twc_block_chunk_ref(thread_c, warp_c, cta_c, 0, nb, out);
    }

    /// TWC kernel: exact per-thread accounting of the three bins, into the
    /// scratch's reused arrays.
    fn sim_twc_into(
        &self,
        items: &[VertexItem],
        push: bool,
        scratch: &mut SimScratch,
    ) -> KernelStats {
        let mut k = scratch.fresh_kernel("twc");
        self.twc_bins_into(items, push, scratch, &mut k);
        let nb = self.spec.num_blocks as usize;
        let SimScratch { thread_c, warp_c, cta_c, .. } = scratch;
        self.twc_block_chunk(thread_c, warp_c, cta_c, 0, nb, &mut k.block_cycles);
        k.kernel_cycles =
            self.cost.cycles_launch + k.block_cycles.iter().max().copied().unwrap_or(0);
        k
    }

    /// TWC kernel with the per-block bottleneck walk chunked onto the pool;
    /// bit-identical to [`sim_twc_into`](Self::sim_twc_into).
    fn sim_twc_pooled(
        &self,
        items: &[VertexItem],
        push: bool,
        scratch: &mut SimScratch,
        pool: &Pool,
    ) -> KernelStats {
        let mut k = scratch.fresh_kernel("twc");
        self.twc_bins_into(items, push, scratch, &mut k);
        let nb = self.spec.num_blocks as usize;
        let nchunks = pool.threads().min(nb).max(1);
        let per = nb.div_ceil(nchunks);
        scratch.ensure_chunks(nchunks);
        {
            let SimScratch { thread_c, warp_c, cta_c, chunks, .. } = &*scratch;
            let chunks = &chunks[..nchunks];
            pool.run(nchunks, &|ci| {
                let b0 = (ci * per).min(nb);
                let b1 = ((ci + 1) * per).min(nb);
                let mut c = chunks[ci].lock().unwrap();
                self.twc_block_chunk(thread_c, warp_c, cta_c, b0, b1, &mut c.block_cycles);
            });
        }
        // Fold per-block results in block (= chunk) order.
        k.block_cycles.clear();
        for m in &scratch.chunks[..nchunks] {
            k.block_cycles.extend_from_slice(&m.lock().unwrap().block_cycles);
        }
        k.kernel_cycles =
            self.cost.cycles_launch + k.block_cycles.iter().max().copied().unwrap_or(0);
        k
    }

    /// Warp-sampling geometry for an LB launch of `total` edges:
    /// `(w, warp_stride, n_sampled)` — edges per thread (paper line 15),
    /// stride between sampled warps, and how many warps the walk simulates
    /// (whole warps, so intra-warp cache state stays faithful).
    /// `sample_cap` overrides [`CostModel::lb_warp_step_sample_cap`] for
    /// this launch (the adaptive controller's per-round budget).
    fn lb_sampling(&self, total: u64, sample_cap: Option<u64>) -> (u64, u64, u64) {
        let p = self.spec.total_threads();
        let w = total.div_ceil(p);
        let nwarps = self.spec.total_warps();
        let total_warp_steps = nwarps.saturating_mul(w);
        let cap = sample_cap.unwrap_or(self.cost.lb_warp_step_sample_cap).max(1);
        let warps_to_sim = if total_warp_steps <= cap {
            nwarps
        } else {
            (cap / w.max(1)).clamp(1, nwarps)
        };
        let warp_stride = (nwarps / warps_to_sim.max(1)).max(1);
        // The walk stops at the earlier of the sample budget and the end of
        // the warp range: sampled warp `j` is warp `j * warp_stride`.
        let n_sampled = warps_to_sim.min(nwarps.div_ceil(warp_stride));
        (w, warp_stride, n_sampled)
    }

    /// `simulate_chunk`, LB leg (DESIGN.md §9): one sampled warp's LB-kernel
    /// walk. Resets `cache` (each sampled warp starts cold, exactly like the
    /// sequential walk), replays warp `widx`'s `w` lockstep steps through
    /// the cache model, and returns the warp's modeled search cycles; the
    /// caller reads the warp's hit/miss counts off `cache` afterwards.
    /// Shared verbatim by the sequential and pooled paths.
    ///
    /// The cyclic distribution takes a segment-jumping fast path that
    /// reproduces the lane-by-lane walk's probe sequence and line set
    /// exactly (asserted against [`Simulator::simulate_reference`] by the
    /// tests below): within one warp step the lane edge ids are
    /// consecutive, so the probe path re-searches only at prefix-segment
    /// boundaries and the touched edge-data lines form one contiguous
    /// range.
    fn lb_warp(
        &self,
        lb: &LbLaunch,
        widx: u64,
        w: u64,
        cache: &mut CacheSim,
        line_buf: &mut Vec<u64>,
    ) -> u64 {
        let s = &self.spec;
        let p = s.total_threads();
        let total = lb.total_edges();
        let warp_lanes = s.warp_size as u64;
        let line_bytes = s.cache_line_bytes as u64;
        let do_search = lb.search;
        let mut sim_search_cycles = 0u64;
        cache.reset_all();
        for j in 0..w {
            line_buf.clear();
            match lb.distribution {
                Distribution::Cyclic => {
                    // Fast path: this step's active edge ids are the
                    // contiguous range [start, end) — identical probe
                    // trajectories compress to one search per prefix
                    // segment, and the edge-data lines are one run.
                    let start = widx * warp_lanes + j * p;
                    if start >= total {
                        continue;
                    }
                    let end = (start + warp_lanes).min(total);
                    if do_search {
                        let mut eid = start;
                        while eid < end {
                            let idx =
                                probe_lines(&lb.prefix, eid, line_bytes, line_buf);
                            // Next search happens at the first edge id
                            // beyond this source's segment (the lane
                            // that leaves the segment re-searches).
                            eid = lb.prefix[idx];
                        }
                    }
                    let lo = (start * 8) / line_bytes;
                    let hi = ((end - 1) * 8) / line_bytes;
                    for line in lo..=hi {
                        line_buf.push(EDGE_REGION + line);
                    }
                }
                Distribution::Blocked => {
                    // Lane-by-lane walk with identical-trajectory
                    // compression: a lane whose eid falls in the
                    // previous lane's prefix segment contributes no new
                    // probe lines (the sort+dedup below would drop them
                    // anyway).
                    let (mut seg_lo, mut seg_hi) = (u64::MAX, u64::MAX);
                    let mut lanes_active = 0u64;
                    for lane in 0..warp_lanes {
                        let t = widx * warp_lanes + lane;
                        let eid = t * w + j;
                        if eid >= total {
                            continue;
                        }
                        lanes_active += 1;
                        if do_search && !(seg_lo <= eid && eid < seg_hi) {
                            let idx =
                                probe_lines(&lb.prefix, eid, line_bytes, line_buf);
                            seg_lo = if idx == 0 { 0 } else { lb.prefix[idx - 1] };
                            seg_hi = lb.prefix[idx];
                        }
                        // Edge-data touch (col_idx + weight, 8 B at eid)
                        // in a region disjoint from the prefix array.
                        line_buf.push(EDGE_REGION + (eid * 8) / line_bytes);
                    }
                    if lanes_active == 0 {
                        continue;
                    }
                }
            }
            // Coalescing: lanes touching the same line in the same
            // lockstep issue one transaction; prefix probes go through
            // the per-SM cache (aligned trajectories -> hits — the
            // cyclic case), edge-data lines amortize across each lane's
            // contiguous walk. One coalesced edge transaction per step
            // is already priced into `cycles_edge`, so the first
            // edge-region line is free.
            line_buf.sort_unstable();
            line_buf.dedup();
            let mut first_edge = true;
            for &line in line_buf.iter() {
                let hit = cache.access(line * line_bytes);
                if line >= EDGE_REGION && first_edge {
                    first_edge = false;
                    continue; // the baseline coalesced transaction
                }
                sim_search_cycles += if hit {
                    self.cost.cycles_mem_hit
                } else {
                    self.cost.cycles_mem_miss
                };
            }
        }
        sim_search_cycles
    }

    /// `simulate_chunk`'s LB per-block edge tally for blocks `[b0, b1)`:
    /// pure per-block arithmetic, one value per block in block order into
    /// `out` (cleared first).
    ///
    /// §Perf (DESIGN.md §13): the thread loop runs 8 threads per iteration
    /// into a `[u64; 8]` accumulator block summed once at the end — u64
    /// addition is exact and commutative, so the per-block total is
    /// bit-identical to the scalar
    /// [`lb_block_edges_chunk_ref`](Self::lb_block_edges_chunk_ref).
    fn lb_block_edges_chunk(
        &self,
        lb: &LbLaunch,
        w: u64,
        b0: usize,
        b1: usize,
        out: &mut Vec<u64>,
    ) {
        let tpb = self.spec.threads_per_block as u64;
        let p = self.spec.total_threads();
        let total = lb.total_edges();
        let per_thread = |t: u64| -> u64 {
            match lb.distribution {
                Distribution::Cyclic => {
                    if t < total {
                        (total - t).div_ceil(p)
                    } else {
                        0
                    }
                }
                Distribution::Blocked => {
                    let lo = t * w;
                    if lo < total {
                        w.min(total - lo)
                    } else {
                        0
                    }
                }
            }
        };
        out.clear();
        for b in b0 as u64..b1 as u64 {
            let t1 = (b + 1) * tpb;
            let mut acc = [0u64; 8];
            let mut t = b * tpb;
            while t + 8 <= t1 {
                for (k, slot) in acc.iter_mut().enumerate() {
                    *slot += per_thread(t + k as u64);
                }
                t += 8;
            }
            let mut edges: u64 = acc.iter().sum();
            while t < t1 {
                edges += per_thread(t);
                t += 1;
            }
            out.push(edges);
        }
    }

    /// The pre-SWAR scalar tally (one thread per iteration, single
    /// accumulator), kept in-binary as the `-ref` twin for the oracle
    /// tests. Not a hot path.
    #[cfg_attr(not(test), allow(dead_code))]
    fn lb_block_edges_chunk_ref(
        &self,
        lb: &LbLaunch,
        w: u64,
        b0: usize,
        b1: usize,
        out: &mut Vec<u64>,
    ) {
        let tpb = self.spec.threads_per_block as u64;
        let p = self.spec.total_threads();
        let total = lb.total_edges();
        out.clear();
        for b in b0 as u64..b1 as u64 {
            let mut edges = 0u64;
            for t in b * tpb..(b + 1) * tpb {
                edges += match lb.distribution {
                    Distribution::Cyclic => {
                        if t < total {
                            (total - t).div_ceil(p)
                        } else {
                            0
                        }
                    }
                    Distribution::Blocked => {
                        let lo = t * w;
                        if lo < total {
                            w.min(total - lo)
                        } else {
                            0
                        }
                    }
                };
            }
            out.push(edges);
        }
    }

    /// Shared epilogue of the sequential and pooled LB kernels: fold the
    /// sampled-warp partials into the kernel's cycle/cache accounting and
    /// per-block cycles (the per-block edge tally is already shared via
    /// [`lb_block_edges_chunk`](Self::lb_block_edges_chunk)). One
    /// implementation so the cost accounting cannot drift between the two
    /// paths.
    #[allow(clippy::too_many_arguments)]
    fn lb_finish(
        &self,
        k: &mut KernelStats,
        lb: &LbLaunch,
        w: u64,
        ec: u64,
        sim_search_cycles: u64,
        hits: u64,
        misses: u64,
        simulated: u64,
    ) {
        let nb = self.spec.num_blocks as usize;
        let nwarps = self.spec.total_warps();
        let search_per_warp = if simulated > 0 {
            sim_search_cycles / simulated
        } else {
            0
        };
        // Extrapolate sampled hit/miss counts to the full launch.
        let scale = nwarps as f64 / simulated.max(1) as f64;
        k.cache_hits = (hits as f64 * scale) as u64;
        k.cache_misses = (misses as f64 * scale) as u64;
        k.block_cycles.clear();
        k.block_cycles.resize(nb, w * ec + search_per_warp);
        // Enterprise-style grid launches pay one launch per processed
        // vertex (no shared prefix kernel); the searched LB kernel is one
        // launch total.
        let launches = if lb.search { 1 } else { lb.vertices.len().max(1) as u64 };
        k.kernel_cycles = launches * self.cost.cycles_launch
            + k.block_cycles.iter().max().copied().unwrap_or(0);
        k.total_edges = lb.total_edges();
    }

    /// LB kernel: even edge split + cache-modeled binary search, into the
    /// scratch's reused buffers (the per-warp body lives in
    /// [`lb_warp`](Self::lb_warp)).
    fn sim_lb_into(
        &self,
        lb: &LbLaunch,
        push: bool,
        scratch: &mut SimScratch,
        sample_cap: Option<u64>,
    ) -> KernelStats {
        let s = &self.spec;
        let nb = s.num_blocks as usize;
        let (w, warp_stride, n_sampled) = self.lb_sampling(lb.total_edges(), sample_cap);
        let ec = self.edge_cost(push);

        let mut k = scratch.fresh_kernel("lb");
        scratch.ensure_cache(s);
        // Split borrows: the cache and the line buffer live in different
        // scratch fields.
        let SimScratch { line_buf, cache, .. } = scratch;
        let cache = cache.as_mut().expect("built by ensure_cache");

        let mut sim_search_cycles = 0u64;
        let (mut hits, mut misses) = (0u64, 0u64);
        for j in 0..n_sampled {
            sim_search_cycles += self.lb_warp(lb, j * warp_stride, w, cache, line_buf);
            hits += cache.hits();
            misses += cache.misses();
        }
        self.lb_block_edges_chunk(lb, w, 0, nb, &mut k.block_edges);
        self.lb_finish(&mut k, lb, w, ec, sim_search_cycles, hits, misses, n_sampled);
        k
    }

    /// LB kernel with the sampled-warp walk and the per-block edge tally
    /// chunked onto the pool; bit-identical to
    /// [`sim_lb_into`](Self::sim_lb_into) — chunk partials are exact u64
    /// sums and per-block values fold in block order.
    fn sim_lb_pooled(
        &self,
        lb: &LbLaunch,
        push: bool,
        scratch: &mut SimScratch,
        pool: &Pool,
        sample_cap: Option<u64>,
    ) -> KernelStats {
        let s = &self.spec;
        let nb = s.num_blocks as usize;
        let (w, warp_stride, n_sampled) = self.lb_sampling(lb.total_edges(), sample_cap);
        let ec = self.edge_cost(push);
        let mut k = scratch.fresh_kernel("lb");

        let wchunks = pool.threads().min(n_sampled.max(1) as usize).max(1);
        let per_w = n_sampled.div_ceil(wchunks as u64).max(1);
        let bchunks = pool.threads().min(nb).max(1);
        let per_b = nb.div_ceil(bchunks);
        scratch.ensure_chunks(wchunks.max(bchunks));

        // --- warp sampling, chunked over the sampled-warp list ---
        {
            let chunks = &scratch.chunks[..wchunks];
            pool.run(wchunks, &|ci| {
                let mut c = chunks[ci].lock().unwrap();
                let c = &mut *c;
                c.search_cycles = 0;
                c.hits = 0;
                c.misses = 0;
                c.simulated = 0;
                ensure_cache_slot(&mut c.cache, s);
                let ChunkSim { cache, line_buf, search_cycles, hits, misses, simulated, .. } =
                    c;
                let cache = cache.as_mut().expect("built by ensure_cache_slot");
                let lo = ci as u64 * per_w;
                let hi = (lo + per_w).min(n_sampled);
                for j in lo..hi {
                    *search_cycles += self.lb_warp(lb, j * warp_stride, w, cache, line_buf);
                    *hits += cache.hits();
                    *misses += cache.misses();
                    *simulated += 1;
                }
            });
        }
        // Fold the warp partials in chunk order (exact integer sums).
        let (mut sim_search_cycles, mut hits, mut misses, mut simulated) =
            (0u64, 0u64, 0u64, 0u64);
        for m in &scratch.chunks[..wchunks] {
            let c = m.lock().unwrap();
            sim_search_cycles += c.search_cycles;
            hits += c.hits;
            misses += c.misses;
            simulated += c.simulated;
        }

        // --- per-block edges, chunked over contiguous block ranges ---
        {
            let chunks = &scratch.chunks[..bchunks];
            pool.run(bchunks, &|ci| {
                let b0 = (ci * per_b).min(nb);
                let b1 = ((ci + 1) * per_b).min(nb);
                let mut c = chunks[ci].lock().unwrap();
                self.lb_block_edges_chunk(lb, w, b0, b1, &mut c.block_edges);
            });
        }
        k.block_edges.clear();
        for m in &scratch.chunks[..bchunks] {
            k.block_edges.extend_from_slice(&m.lock().unwrap().block_edges);
        }

        self.lb_finish(&mut k, lb, w, ec, sim_search_cycles, hits, misses, simulated);
        k
    }

    // ------------------------------------------------ reference (golden)

    /// TWC kernel, fresh-allocation reference implementation.
    fn sim_twc_ref(&self, items: &[VertexItem], push: bool) -> KernelStats {
        let s = &self.spec;
        let nb = s.num_blocks as usize;
        let tpb = s.threads_per_block as usize;
        let wpb = s.warps_per_block() as usize;
        let nthreads = nb * tpb;
        let nwarps = nb * wpb;
        let warp = s.warp_size as u64;
        let ec = self.edge_cost(push);

        let mut thread_c = vec![0u64; nthreads];
        let mut warp_c = vec![0u64; nwarps];
        let mut cta_c = vec![0u64; nb];
        let mut block_edges = vec![0u64; nb];
        let (mut ti, mut wi, mut bi) = (0usize, 0usize, 0usize);
        let mut total_edges = 0u64;

        for item in items {
            total_edges += item.degree;
            match item.unit {
                Unit::Thread => {
                    let t = ti % nthreads;
                    thread_c[t] += item.degree * ec;
                    block_edges[t / tpb] += item.degree;
                    ti += 1;
                }
                Unit::Warp => {
                    let w = wi % nwarps;
                    warp_c[w] += item.degree.div_ceil(warp) * ec;
                    block_edges[w / wpb] += item.degree;
                    wi += 1;
                }
                Unit::Block => {
                    let b = bi % nb;
                    cta_c[b] += item.degree.div_ceil(tpb as u64) * ec;
                    block_edges[b] += item.degree;
                    bi += 1;
                }
            }
        }

        let mut block_cycles = vec![0u64; nb];
        for b in 0..nb {
            let mut worst = 0u64;
            for t in b * tpb..(b + 1) * tpb {
                let w = t / s.warp_size as usize;
                let c = thread_c[t] + warp_c[w] + cta_c[b];
                worst = worst.max(c);
            }
            block_cycles[b] = worst;
        }
        let kernel_cycles =
            self.cost.cycles_launch + block_cycles.iter().max().copied().unwrap_or(0);
        KernelStats {
            label: "twc",
            block_edges,
            block_cycles,
            kernel_cycles,
            total_edges,
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// LB kernel, fresh-allocation lane-by-lane reference implementation.
    fn sim_lb_ref(&self, lb: &LbLaunch, push: bool) -> KernelStats {
        let s = &self.spec;
        let nb = s.num_blocks as usize;
        let tpb = s.threads_per_block as u64;
        let p = s.total_threads();
        let total = lb.total_edges();
        let w = total.div_ceil(p);
        let ec = self.edge_cost(push);

        let warp_lanes = s.warp_size as u64;
        let nwarps = s.total_warps();
        let total_warp_steps = nwarps.saturating_mul(w);
        let cap = self.cost.lb_warp_step_sample_cap.max(1);
        let warps_to_sim = if total_warp_steps <= cap {
            nwarps
        } else {
            (cap / w.max(1)).clamp(1, nwarps)
        };
        let warp_stride = (nwarps / warps_to_sim).max(1);

        let mut sim_search_cycles = 0u64;
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut simulated = 0u64;
        let line_bytes = s.cache_line_bytes as u64;
        let do_search = lb.search;
        let mut line_buf: Vec<u64> = Vec::with_capacity(s.warp_size as usize * 24);
        let mut widx = 0u64;
        while widx < nwarps && simulated < warps_to_sim {
            let mut cache =
                CacheSim::new(s.l1_kb, s.cache_line_bytes, s.cache_assoc);
            for j in 0..w {
                line_buf.clear();
                let (mut seg_lo, mut seg_hi) = (u64::MAX, u64::MAX);
                let mut lanes_active = 0u64;
                for lane in 0..warp_lanes {
                    let t = widx * warp_lanes + lane;
                    let eid = match lb.distribution {
                        Distribution::Cyclic => t + j * p,
                        Distribution::Blocked => t * w + j,
                    };
                    if eid >= total {
                        continue;
                    }
                    lanes_active += 1;
                    if do_search && !(seg_lo <= eid && eid < seg_hi) {
                        let idx = probe_lines(&lb.prefix, eid, line_bytes, &mut line_buf);
                        seg_lo = if idx == 0 { 0 } else { lb.prefix[idx - 1] };
                        seg_hi = lb.prefix[idx];
                    }
                    line_buf.push(EDGE_REGION + (eid * 8) / line_bytes);
                }
                if lanes_active == 0 {
                    continue;
                }
                line_buf.sort_unstable();
                line_buf.dedup();
                let mut first_edge = true;
                for &line in &line_buf {
                    let hit = cache.access_ref(line * line_bytes);
                    if line >= EDGE_REGION && first_edge {
                        first_edge = false;
                        continue;
                    }
                    sim_search_cycles += if hit {
                        self.cost.cycles_mem_hit
                    } else {
                        self.cost.cycles_mem_miss
                    };
                }
            }
            hits += cache.hits();
            misses += cache.misses();
            simulated += 1;
            widx += warp_stride;
        }
        let search_per_warp = if simulated > 0 {
            sim_search_cycles / simulated
        } else {
            0
        };
        let scale = nwarps as f64 / simulated.max(1) as f64;
        hits = (hits as f64 * scale) as u64;
        misses = (misses as f64 * scale) as u64;

        let mut block_edges = vec![0u64; nb];
        for b in 0..nb as u64 {
            let mut edges = 0u64;
            for t in b * tpb..(b + 1) * tpb {
                edges += match lb.distribution {
                    Distribution::Cyclic => {
                        if t < total {
                            (total - t).div_ceil(p)
                        } else {
                            0
                        }
                    }
                    Distribution::Blocked => {
                        let lo = t * w;
                        if lo < total {
                            w.min(total - lo)
                        } else {
                            0
                        }
                    }
                };
            }
            block_edges[b as usize] = edges;
        }
        let block_cycles: Vec<u64> = (0..nb)
            .map(|_| w * ec + search_per_warp)
            .collect();
        let launches = if lb.search { 1 } else { lb.vertices.len().max(1) as u64 };
        let kernel_cycles = launches * self.cost.cycles_launch
            + block_cycles.iter().max().copied().unwrap_or(0);
        KernelStats {
            label: "lb",
            block_edges,
            block_cycles,
            kernel_cycles,
            total_edges: total,
            cache_hits: hits,
            cache_misses: misses,
        }
    }
}


/// Line-id offset separating the edge-data region from the prefix array in
/// the LB-kernel cache simulation.
const EDGE_REGION: u64 = 1 << 40;


/// Collect the cache-line ids a binary search for `eid` touches in the
/// inclusive prefix array (`u64` entries) and return the owner index.
/// Mirrors `ref.edge_to_src`'s semantics: first index with `prefix[i] > eid`.
#[inline]
fn probe_lines(prefix: &[u64], eid: u64, line_bytes: u64, out: &mut Vec<u64>) -> usize {
    let (mut lo, mut hi) = (0usize, prefix.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        out.push((mid as u64 * 8) / line_bytes);
        if prefix[mid] <= eid {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> Simulator {
        Simulator::new(GpuSpec::default_sim(), CostModel::default())
    }

    fn thread_items(n: usize, deg: u64) -> Vec<VertexItem> {
        (0..n)
            .map(|v| VertexItem { vertex: v as u32, degree: deg, unit: Unit::Thread })
            .collect()
    }

    #[test]
    fn empty_schedule_costs_one_launch() {
        let s = sim();
        let r = s.simulate(
            &Schedule { twc: vec![], lb: None, scan_vertices: 0, prefix_items: 0 },
            true,
        );
        assert_eq!(r.kernels.len(), 1);
        assert_eq!(r.total_cycles, s.cost.cycles_launch);
    }

    #[test]
    fn single_cta_item_loads_one_block() {
        let s = sim();
        let items = vec![VertexItem { vertex: 0, degree: 100_000, unit: Unit::Block }];
        let r = s.simulate(
            &Schedule { twc: items, lb: None, scan_vertices: 0, prefix_items: 0 },
            true,
        );
        let k = &r.kernels[0];
        assert_eq!(k.block_edges[0], 100_000);
        assert!(k.block_edges[1..].iter().all(|&e| e == 0));
        assert!(k.imbalance_factor() > 10.0);
    }

    #[test]
    fn lb_launch_balances_blocks() {
        let s = sim();
        let lb = LbLaunch {
            vertices: vec![0],
            prefix: vec![100_000],
            distribution: Distribution::Cyclic,
            search: true,
        };
        let r = s.simulate(
            &Schedule { twc: vec![], lb: Some(lb), scan_vertices: 0, prefix_items: 1 },
            true,
        );
        let k = r.kernels.iter().find(|k| k.label == "lb").unwrap();
        assert_eq!(k.block_edges.iter().sum::<u64>(), 100_000);
        let max = *k.block_edges.iter().max().unwrap();
        let min = *k.block_edges.iter().min().unwrap();
        assert!(max - min <= s.spec.threads_per_block as u64, "max {max} min {min}");
        assert!(k.imbalance_factor() < 1.05);
    }

    #[test]
    fn lb_beats_single_cta_on_hub() {
        // The paper's core claim at kernel granularity: distributing a huge
        // vertex's edges across all blocks beats one CTA walking them.
        let s = sim();
        let hub = 1_000_000u64;
        let cta = s.simulate(
            &Schedule {
                twc: vec![VertexItem { vertex: 0, degree: hub, unit: Unit::Block }],
                lb: None,
                scan_vertices: 0,
                prefix_items: 0,
            },
            true,
        );
        let lb = s.simulate(
            &Schedule {
                twc: vec![],
                lb: Some(LbLaunch {
                    vertices: vec![0],
                    prefix: vec![hub],
                    distribution: Distribution::Cyclic,
                    search: true,
                }),
                scan_vertices: 0,
                prefix_items: 1,
            },
            true,
        );
        assert!(
            lb.total_cycles * 3 < cta.total_cycles,
            "lb {} vs cta {}",
            lb.total_cycles,
            cta.total_cycles
        );
    }

    #[test]
    fn cyclic_cheaper_than_blocked() {
        // Paper §4.1/Fig 8: cyclic's coalesced binary searches must come out
        // faster through the cache model, not by fiat.
        let s = sim();
        let prefix: Vec<u64> = (1..=512u64).map(|i| i * 2000).collect();
        let mk = |d| {
            Schedule {
                twc: vec![],
                lb: Some(LbLaunch {
                    vertices: (0..512).collect(),
                    prefix: prefix.clone(),
                    distribution: d,
                    search: true,
                }),
                scan_vertices: 0,
                prefix_items: 512,
            }
        };
        let cyc = s.simulate(&mk(Distribution::Cyclic), true);
        let blk = s.simulate(&mk(Distribution::Blocked), true);
        assert!(
            cyc.total_cycles < blk.total_cycles,
            "cyclic {} must beat blocked {}",
            cyc.total_cycles,
            blk.total_cycles
        );
    }

    #[test]
    fn push_costs_more_than_pull() {
        let s = sim();
        let sched = Schedule {
            twc: thread_items(1000, 8),
            lb: None,
            scan_vertices: 0,
            prefix_items: 0,
        };
        let push = s.simulate(&sched, true);
        let pull = s.simulate(&sched, false);
        assert!(push.total_cycles > pull.total_cycles);
    }

    #[test]
    fn thread_items_round_robin_evenly() {
        let s = sim();
        let n = s.spec.total_threads() as usize * 2; // two per thread
        let r = s.simulate(
            &Schedule { twc: thread_items(n, 5), lb: None, scan_vertices: 0, prefix_items: 0 },
            false,
        );
        let k = &r.kernels[0];
        let per_block = 2 * 5 * s.spec.threads_per_block as u64;
        assert!(k.block_edges.iter().all(|&e| e == per_block));
        assert!(k.imbalance_factor() <= 1.0 + 1e-9);
    }

    #[test]
    fn warp_items_split_degree_across_lanes() {
        let s = sim();
        let deg = 320u64;
        let r = s.simulate(
            &Schedule {
                twc: vec![VertexItem { vertex: 0, degree: deg, unit: Unit::Warp }],
                lb: None,
                scan_vertices: 0,
                prefix_items: 0,
            },
            false,
        );
        let k = &r.kernels[0];
        // warp processes 320 edges over 32 lanes -> 10 serial edge slots
        let expect = deg.div_ceil(32) * s.cost.cycles_edge;
        assert_eq!(
            k.kernel_cycles,
            s.cost.cycles_launch + expect
        );
    }

    #[test]
    fn scan_cost_scales_with_vertices() {
        let s = sim();
        let small = s.simulate(
            &Schedule { twc: vec![], lb: None, scan_vertices: 1, prefix_items: 0 },
            false,
        );
        let big = s.simulate(
            &Schedule {
                twc: vec![],
                lb: None,
                scan_vertices: 100 * s.spec.total_threads(),
                prefix_items: 0,
            },
            false,
        );
        assert!(big.total_cycles > small.total_cycles);
    }

    #[test]
    fn lb_block_edges_exact_for_blocked_tail() {
        let s = sim();
        let total = s.spec.total_threads() * 3 + 17; // ragged tail
        let lb = LbLaunch {
            vertices: vec![0],
            prefix: vec![total],
            distribution: Distribution::Blocked,
            search: true,
        };
        let r = s.simulate(
            &Schedule { twc: vec![], lb: Some(lb), scan_vertices: 0, prefix_items: 1 },
            false,
        );
        let k = r.kernels.iter().find(|k| k.label == "lb").unwrap();
        assert_eq!(k.block_edges.iter().sum::<u64>(), total);
    }

    #[test]
    fn swar_degree_tally_oracle_matches_scalar_reference() {
        // Random per-thread/warp/block accounting arrays on both
        // geometries: the warp-hoisted 8-wide tally must reproduce the
        // scalar reference walk bit-for-bit, including all-zero and
        // single-hot-lane extremes.
        let mut x = 0x9e3779b97f4a7c15u64;
        let mut rng = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x >> 33
        };
        for spec in [GpuSpec::default_sim(), GpuSpec::k80_like()] {
            let s = Simulator::new(spec, CostModel::default());
            let nt = s.spec.total_threads() as usize;
            let nw = s.spec.total_warps() as usize;
            let nb = s.spec.num_blocks as usize;
            let mut cases: Vec<(Vec<u64>, Vec<u64>, Vec<u64>)> = vec![
                (vec![0; nt], vec![0; nw], vec![0; nb]),
                (
                    (0..nt).map(|_| rng()).collect(),
                    (0..nw).map(|_| rng()).collect(),
                    (0..nb).map(|_| rng()).collect(),
                ),
            ];
            // Single hot lane in an otherwise-zero grid (the max must be
            // found regardless of which 8-lane group it lands in).
            let mut hot = vec![0u64; nt];
            hot[nt - 3] = u64::MAX / 4;
            cases.push((hot, vec![1; nw], vec![2; nb]));
            for (thread_c, warp_c, cta_c) in &cases {
                let (mut opt, mut rf) = (Vec::new(), Vec::new());
                s.bench_degree_tally(thread_c, warp_c, cta_c, &mut opt);
                s.bench_degree_tally_ref(thread_c, warp_c, cta_c, &mut rf);
                assert_eq!(opt, rf);
                // Partial block ranges go through the same chunk walk.
                s.twc_block_chunk(thread_c, warp_c, cta_c, 1, nb - 1, &mut opt);
                s.twc_block_chunk_ref(thread_c, warp_c, cta_c, 1, nb - 1, &mut rf);
                assert_eq!(opt, rf);
            }
        }
    }

    #[test]
    fn swar_lb_block_edges_oracle_matches_scalar_reference() {
        // Both distributions over totals hitting every tail shape: empty,
        // single edge, fewer edges than threads, exact multiples, ragged
        // remainders, and far beyond the grid.
        for spec in [GpuSpec::default_sim(), GpuSpec::k80_like()] {
            let s = Simulator::new(spec, CostModel::default());
            let p = s.spec.total_threads();
            let nb = s.spec.num_blocks as usize;
            for dist in [Distribution::Cyclic, Distribution::Blocked] {
                for total in [0, 1, 7, p - 1, p, p + 1, p * 3, p * 3 + 17, p * 40 + 5] {
                    let lb = LbLaunch {
                        vertices: vec![0],
                        prefix: vec![total],
                        distribution: dist,
                        search: true,
                    };
                    let w = total.div_ceil(p);
                    let (mut opt, mut rf) = (Vec::new(), Vec::new());
                    s.lb_block_edges_chunk(&lb, w, 0, nb, &mut opt);
                    s.lb_block_edges_chunk_ref(&lb, w, 0, nb, &mut rf);
                    assert_eq!(opt, rf, "dist={dist:?} total={total}");
                    assert_eq!(opt.iter().sum::<u64>(), total, "tally must be exact");
                }
            }
        }
    }

    #[test]
    fn imbalance_factor_of_uniform_is_one() {
        let k = KernelStats {
            label: "x",
            block_edges: vec![5, 5, 5, 5],
            block_cycles: vec![1, 1, 1, 1],
            kernel_cycles: 1,
            total_edges: 20,
            cache_hits: 0,
            cache_misses: 0,
        };
        assert!((k.imbalance_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_factor_of_empty_kernel_is_one() {
        // No recorded blocks (or all-zero blocks) must never produce NaN.
        let empty = KernelStats { label: "x", ..KernelStats::default() };
        assert_eq!(empty.imbalance_factor(), 1.0);
        let zeros = KernelStats {
            label: "x",
            block_edges: vec![0, 0, 0],
            ..KernelStats::default()
        };
        assert_eq!(zeros.imbalance_factor(), 1.0);
        assert!(!empty.imbalance_factor().is_nan());
    }

    // ----------------------- scratch-reuse + reference parity gates

    /// A few structurally-different schedules covering both kernels, both
    /// distributions, search on/off, ragged tails, and empty cases.
    fn assorted_schedules(s: &Simulator) -> Vec<Schedule> {
        let p = s.spec.total_threads();
        let mut out = vec![
            Schedule { twc: vec![], lb: None, scan_vertices: 7, prefix_items: 0 },
            Schedule {
                twc: thread_items(777, 3),
                lb: None,
                scan_vertices: 777,
                prefix_items: 0,
            },
            Schedule {
                twc: vec![
                    VertexItem { vertex: 0, degree: 100, unit: Unit::Warp },
                    VertexItem { vertex: 1, degree: 9_000, unit: Unit::Block },
                ],
                lb: None,
                scan_vertices: 2,
                prefix_items: 0,
            },
        ];
        for dist in [Distribution::Cyclic, Distribution::Blocked] {
            for search in [true, false] {
                let prefix: Vec<u64> = (1..=100u64).map(|i| i * 977).collect();
                out.push(Schedule {
                    twc: thread_items(50, 2),
                    lb: Some(LbLaunch {
                        vertices: (0..100).collect(),
                        prefix,
                        distribution: dist,
                        search,
                    }),
                    scan_vertices: 150,
                    prefix_items: if search { 100 } else { 0 },
                });
            }
            // Ragged tail: total not divisible by p, fewer edges than
            // threads in the last step.
            out.push(Schedule {
                twc: vec![],
                lb: Some(LbLaunch {
                    vertices: vec![0, 1],
                    prefix: vec![p * 2 + 13, p * 2 + 14],
                    distribution: dist,
                    search: true,
                }),
                scan_vertices: 0,
                prefix_items: 2,
            });
        }
        out
    }

    #[test]
    fn scratch_reuse_matches_fresh_simulation() {
        // One scratch threaded through many structurally-different rounds
        // must reproduce the freshly-allocated runs bit-for-bit.
        let s = sim();
        let mut scratch = SimScratch::new();
        for push in [true, false] {
            for sched in assorted_schedules(&s) {
                let fresh = s.simulate(&sched, push);
                s.simulate_into(&sched, push, &mut scratch);
                assert_eq!(scratch.round, fresh, "push={push}");
            }
        }
    }

    #[test]
    fn reference_matches_optimized_simulation() {
        // The lane-by-lane fresh-allocation reference and the optimized
        // scratch path are the same model: identical kernels, cycles, and
        // cache counts on every assorted schedule.
        let s = sim();
        for push in [true, false] {
            for sched in assorted_schedules(&s) {
                let opt = s.simulate(&sched, push);
                let r = s.simulate_reference(&sched, push);
                assert_eq!(opt, r, "push={push}");
            }
        }
    }

    #[test]
    fn reference_matches_on_k80_geometry() {
        // Re-run the parity gate on the paper-faithful geometry so the
        // cyclic fast path is exercised with 26,624 threads too.
        let s = Simulator::new(GpuSpec::k80_like(), CostModel::default());
        for sched in assorted_schedules(&s) {
            assert_eq!(s.simulate(&sched, true), s.simulate_reference(&sched, true));
        }
    }

    #[test]
    fn pooled_simulation_bit_identical_across_pool_widths() {
        // The §9 determinism contract: the chunked pool walk must equal the
        // golden reference bit-for-bit for any worker count, on both GPU
        // geometries, across every assorted schedule (both kernels, both
        // distributions, ragged tails, empty rounds).
        for spec in [GpuSpec::default_sim(), GpuSpec::k80_like()] {
            let s = Simulator::new(spec, CostModel::default());
            let cases: Vec<(Schedule, bool, RoundSim)> = [true, false]
                .into_iter()
                .flat_map(|push| {
                    assorted_schedules(&s).into_iter().map(move |sched| (sched, push))
                })
                .map(|(sched, push)| {
                    let want = s.simulate_reference(&sched, push);
                    (sched, push, want)
                })
                .collect();
            for threads in [1usize, 2, 3, 7] {
                let pool = Pool::new(threads);
                let mut scratch = SimScratch::new();
                for (sched, push, want) in &cases {
                    s.simulate_into_pooled(sched, *push, &mut scratch, &pool);
                    assert_eq!(
                        &scratch.round, want,
                        "threads={threads} push={push} spec={}",
                        s.spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn pooled_chunk_arenas_persist_across_rounds() {
        // One scratch threaded through many pooled rounds keeps its chunk
        // arenas (no regrowth of the chunk list once warmed).
        let s = sim();
        let pool = Pool::new(4);
        let mut scratch = SimScratch::new();
        let sched = Schedule {
            twc: thread_items(100, 4),
            lb: Some(LbLaunch {
                vertices: vec![0],
                prefix: vec![200_000],
                distribution: Distribution::Cyclic,
                search: true,
            }),
            scan_vertices: 100,
            prefix_items: 1,
        };
        s.simulate_into_pooled(&sched, true, &mut scratch, &pool);
        let nchunks = scratch.chunks.len();
        assert!(nchunks >= 1 && nchunks <= pool.threads());
        for _ in 0..5 {
            s.simulate_into_pooled(&sched, true, &mut scratch, &pool);
        }
        assert_eq!(scratch.chunks.len(), nchunks, "chunk arenas must be reused");
    }

    #[test]
    fn concurrent_rounds_cost_launch_plus_max() {
        // With both kernels launched, the default accounting charges
        // scan + max(twc, prefix + lb); serial restores the historical sum.
        let spec = GpuSpec::default_sim();
        let conc = Simulator::new(spec.clone(), CostModel::default());
        let ser = Simulator::new(spec, CostModel::serial());
        let sched = Schedule {
            twc: vec![VertexItem { vertex: 0, degree: 50_000, unit: Unit::Block }],
            lb: Some(LbLaunch {
                vertices: vec![1],
                prefix: vec![200_000],
                distribution: Distribution::Cyclic,
                search: true,
            }),
            scan_vertices: 0,
            prefix_items: 1,
        };
        let c = conc.simulate(&sched, true);
        let s = ser.simulate(&sched, true);
        // Kernels themselves are identical; only the fold differs.
        assert_eq!(c.kernels, s.kernels);
        assert_eq!(c.overhead_cycles, s.overhead_cycles);
        let twc = c.kernels[0].kernel_cycles;
        let lb = c.kernels[1].kernel_cycles;
        let prefix = c.overhead_cycles; // scan_vertices = 0
        assert_eq!(c.total_cycles, twc.max(prefix + lb));
        assert_eq!(s.total_cycles, twc + prefix + lb);
        assert!(c.total_cycles < s.total_cycles);
    }

    #[test]
    fn concurrent_equals_serial_on_single_kernel_rounds() {
        // No LB launch -> the two accountings agree (TWC-only strategies
        // are unaffected by the concurrency fix).
        let spec = GpuSpec::default_sim();
        let conc = Simulator::new(spec.clone(), CostModel::default());
        let ser = Simulator::new(spec, CostModel::serial());
        let sched = Schedule {
            twc: thread_items(500, 9),
            lb: None,
            scan_vertices: 500,
            prefix_items: 0,
        };
        assert_eq!(
            conc.simulate(&sched, true).total_cycles,
            ser.simulate(&sched, true).total_cycles
        );
    }

    #[test]
    fn scratch_pool_recycles_kernel_stats() {
        let s = sim();
        let mut scratch = SimScratch::new();
        let sched = Schedule {
            twc: thread_items(10, 4),
            lb: Some(LbLaunch {
                vertices: vec![0],
                prefix: vec![50_000],
                distribution: Distribution::Cyclic,
                search: true,
            }),
            scan_vertices: 10,
            prefix_items: 1,
        };
        s.simulate_into(&sched, true, &mut scratch);
        assert_eq!(scratch.round.kernels.len(), 2);
        let caps: Vec<usize> =
            scratch.round.kernels.iter().map(|k| k.block_edges.capacity()).collect();
        s.simulate_into(&sched, true, &mut scratch);
        // Same kernels come back out of the pool: no capacity regrowth.
        let caps2: Vec<usize> =
            scratch.round.kernels.iter().map(|k| k.block_edges.capacity()).collect();
        assert_eq!(caps, caps2);
        assert!(scratch.pool.is_empty(), "both pooled kernels back in use");
    }
}
