//! The simulated GPU: SIMT hierarchy sizes and hardware presets.
//!
//! Substitutes for the paper's physical K80 / GTX 1080 / P100 (DESIGN.md §1).
//! The quantity that drives every result in the paper is the *thread
//! hierarchy*: how many thread blocks exist (inter-block imbalance is the
//! problem ALB solves), how many threads a block and a warp hold (TWC's
//! binning boundaries), and the total launched thread count (the paper's
//! huge-degree THRESHOLD, 26,624 on their setup).


/// Dimensions and memory parameters of one simulated GPU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    /// Thread blocks launched per kernel (all resident: one wave).
    pub num_blocks: u32,
    pub threads_per_block: u32,
    pub warp_size: u32,
    /// Clock used to convert cycles to reported milliseconds.
    pub clock_ghz: f64,
    /// Per-SM L1/texture cache modeled for the LB binary search.
    pub l1_kb: u32,
    pub cache_line_bytes: u32,
    pub cache_assoc: u32,
}

impl GpuSpec {
    /// Laptop-scale default: small enough that the bundled inputs exhibit
    /// the paper's imbalance regimes (hub degree >> total threads).
    pub fn default_sim() -> Self {
        GpuSpec {
            name: "sim-default".into(),
            num_blocks: 24,
            threads_per_block: 128,
            warp_size: 32,
            clock_ghz: 1.0,
            l1_kb: 24,
            cache_line_bytes: 128,
            cache_assoc: 4,
        }
    }

    /// Paper-faithful K80 preset: 26,624 launched threads (104 blocks x 256),
    /// the THRESHOLD quoted in §6.3.
    pub fn k80_like() -> Self {
        GpuSpec {
            name: "k80-like".into(),
            num_blocks: 104,
            threads_per_block: 256,
            warp_size: 32,
            clock_ghz: 0.82,
            l1_kb: 48,
            cache_line_bytes: 128,
            cache_assoc: 4,
        }
    }

    /// GTX 1080-like preset (Momentum's consumer cards).
    pub fn gtx1080_like() -> Self {
        GpuSpec {
            name: "gtx1080-like".into(),
            num_blocks: 80,
            threads_per_block: 256,
            warp_size: 32,
            clock_ghz: 1.6,
            l1_kb: 48,
            cache_line_bytes: 128,
            cache_assoc: 4,
        }
    }

    /// P100-like preset (Bridges' cards).
    pub fn p100_like() -> Self {
        GpuSpec {
            name: "p100-like".into(),
            num_blocks: 112,
            threads_per_block: 256,
            warp_size: 32,
            clock_ghz: 1.3,
            l1_kb: 64,
            cache_line_bytes: 128,
            cache_assoc: 4,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "sim-default" => Some(Self::default_sim()),
            "k80-like" => Some(Self::k80_like()),
            "gtx1080-like" => Some(Self::gtx1080_like()),
            "p100-like" => Some(Self::p100_like()),
            _ => None,
        }
    }

    /// Every name [`GpuSpec::by_name`] accepts, for error messages that
    /// name the valid set (the C001 lint rule).
    pub const NAMES: &'static str = "sim-default, k80-like, gtx1080-like, p100-like";

    #[inline]
    pub fn total_threads(&self) -> u64 {
        self.num_blocks as u64 * self.threads_per_block as u64
    }

    #[inline]
    pub fn warps_per_block(&self) -> u32 {
        self.threads_per_block / self.warp_size
    }

    #[inline]
    pub fn total_warps(&self) -> u64 {
        self.num_blocks as u64 * self.warps_per_block() as u64
    }

    /// The paper's huge-vertex THRESHOLD: the launched thread count (§4.2).
    #[inline]
    pub fn huge_threshold(&self) -> u64 {
        self.total_threads()
    }

    /// Convert simulated cycles to reported milliseconds.
    #[inline]
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k80_matches_paper_thread_count() {
        assert_eq!(GpuSpec::k80_like().total_threads(), 26_624);
    }

    #[test]
    fn hierarchy_arithmetic() {
        let s = GpuSpec::default_sim();
        assert_eq!(s.warps_per_block(), 4);
        assert_eq!(s.total_warps(), 96);
        assert_eq!(s.total_threads(), 3072);
        assert_eq!(s.huge_threshold(), 3072);
    }

    #[test]
    fn cycles_to_ms_uses_clock() {
        let s = GpuSpec { clock_ghz: 2.0, ..GpuSpec::default_sim() };
        assert!((s.cycles_to_ms(2_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn presets_resolvable_by_name() {
        for n in ["sim-default", "k80-like", "gtx1080-like", "p100-like"] {
            assert!(GpuSpec::by_name(n).is_some());
        }
        assert!(GpuSpec::by_name("h100").is_none());
    }

    #[test]
    fn threads_per_block_multiple_of_warp() {
        for s in [
            GpuSpec::default_sim(),
            GpuSpec::k80_like(),
            GpuSpec::gtx1080_like(),
            GpuSpec::p100_like(),
        ] {
            assert_eq!(s.threads_per_block % s.warp_size, 0);
        }
    }
}
