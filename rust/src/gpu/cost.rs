//! Cycle-level cost model for the simulated GPU.
//!
//! Constants are calibrated to the relative magnitudes that matter for the
//! paper's comparisons (memory miss >> hit >> ALU; kernel launch >> per-edge
//! work), not to any specific silicon. EXPERIMENTS.md records a sensitivity
//! note: the reproduced *ratios* are stable across +-2x perturbation of
//! these values because every strategy is charged through the same model.


/// Cycle costs charged by the kernel simulator.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Processing one edge: neighbor load + label compare/compute.
    pub cycles_edge: u64,
    /// Extra cost per push-style update (atomicMin + worklist push amortized).
    pub cycles_atomic: u64,
    /// L1 cache hit (binary-search probe that coalesces).
    pub cycles_mem_hit: u64,
    /// Cache miss to global memory.
    pub cycles_mem_miss: u64,
    /// Kernel launch overhead (per launched kernel).
    pub cycles_launch: u64,
    /// Scanning one vertex of a worklist (dense scans all |V|, sparse only
    /// the active ones — the Gunrock-vs-D-IrGL road-USA effect, §6.1).
    pub cycles_scan_vertex: u64,
    /// Prefix-sum cost per huge vertex (inspector overhead).
    pub cycles_prefix_per_item: u64,
    /// Cap on warp-steps fully simulated per LB kernel; beyond this the
    /// cache model samples uniformly and extrapolates.
    pub lb_warp_step_sample_cap: u64,
    /// Charge the round's kernels back-to-back instead of concurrently.
    /// ALB launches the LB kernel *alongside* the TWC kernel (paper §4,
    /// separate streams), so the default charges a round
    /// `scan + max(twc, prefix + lb)` — the prefix sum must finish before
    /// the LB launch but overlaps TWC. `true` restores the historical
    /// serial accounting (`scan + twc + prefix + lb`) so pre-existing
    /// `repro` numbers can be regenerated deliberately.
    pub serial_kernels: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cycles_edge: 4,
            cycles_atomic: 8,
            // Memory probes are charged at BANDWIDTH cost, not latency: a
            // GPU hides miss latency under thousands of resident warps, so
            // what a miss really costs the kernel is its 128 B line of
            // HBM traffic (~12 cycles at ~10 B/cycle/SM). Hits cost an L1
            // access. Charging latency (~100s of cycles) would overstate
            // every search-heavy strategy by an order of magnitude.
            cycles_mem_hit: 2,
            cycles_mem_miss: 12,
            // A real launch is ~3-10k cycles, but the bundled inputs are
            // ~1000x smaller than the paper's: the launch:work ratio — the
            // quantity that decides whether a second (LB) kernel launch pays
            // off — is what must be preserved, so launch scales down with
            // the inputs. `CostModel::paper_scale()` keeps the raw value for
            // paper-sized runs.
            cycles_launch: 100,
            cycles_scan_vertex: 1,
            cycles_prefix_per_item: 2,
            lb_warp_step_sample_cap: 1 << 14,
            serial_kernels: false,
        }
    }
}

impl CostModel {
    /// Unscaled launch cost, for paper-sized inputs (rmat23+, 26k+ threads).
    pub fn paper_scale() -> Self {
        CostModel { cycles_launch: 3000, ..CostModel::default() }
    }

    /// The historical serial-kernel accounting (see `serial_kernels`).
    pub fn serial() -> Self {
        CostModel { serial_kernels: true, ..CostModel::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_orderings_hold() {
        let c = CostModel::default();
        assert!(c.cycles_mem_miss > c.cycles_mem_hit);
        assert!(c.cycles_launch >= c.cycles_edge * 25);
        assert!(c.cycles_atomic >= c.cycles_edge);
    }

    #[test]
    fn paper_scale_restores_launch() {
        assert_eq!(CostModel::paper_scale().cycles_launch, 3000);
        assert_eq!(CostModel::paper_scale().cycles_edge, 4);
    }

    #[test]
    fn concurrent_kernels_are_the_default() {
        assert!(!CostModel::default().serial_kernels);
        assert!(CostModel::serial().serial_kernels);
    }
}
