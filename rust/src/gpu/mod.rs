//! The simulated GPU: SIMT execution model, cycle cost model, cache model,
//! and the kernel simulator that executes load-balancer schedules.
//!
//! This is the hardware substitution for the paper's K80 / GTX 1080 / P100
//! testbeds (DESIGN.md §1): per-thread-block work accounting and bottleneck
//! timing reproduce the quantities the paper's evaluation plots.

pub mod cache;
pub mod cost;
pub mod model;
pub mod sim;

pub use cache::CacheSim;
pub use cost::CostModel;
pub use model::GpuSpec;
pub use sim::{KernelStats, RoundSim, SimScratch, Simulator};
