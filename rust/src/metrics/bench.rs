//! Minimal benchmarking harness (the vendored crate set has no criterion).
//!
//! Each `benches/*.rs` binary drives one paper table/figure through
//! [`time_runs`]: warmup + N timed repetitions, reporting min/mean/max host
//! time alongside the experiment's own simulated-ms output.
//!
//! Results can be persisted as machine-readable JSON (`BENCH_*.json`, the
//! repo's perf trajectory) via [`write_json`] and read back by
//! [`read_json`] — the reader is a line scanner matched to our own
//! [`crate::metrics::Json`] writer's deterministic, sorted-key output, so
//! CI can diff a fresh run against the committed baseline without a JSON
//! dependency.

use std::io;
use std::time::Instant;

use crate::metrics::Json;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u32,
    pub min_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} {:>3} iters  min {:>9.2} ms  mean {:>9.2} ms  max {:>9.2} ms",
            self.name, self.iters, self.min_ms, self.mean_ms, self.max_ms
        )
    }
}

/// Run `f` once for warmup then `iters` timed times.
pub fn time_runs<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> BenchStats {
    let _warmup = f();
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        // Allowlisted D001 host-timing site: the bench harness itself.
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        let _ = f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchStats { name: name.to_string(), iters, min_ms: min, mean_ms: mean, max_ms: max }
}

/// Mean host ms of the named case, if present.
pub fn mean_of(cases: &[BenchStats], name: &str) -> Option<f64> {
    cases.iter().find(|c| c.name == name).map(|c| c.mean_ms)
}

/// In-binary speedup of `optimized` over `reference` (reference mean /
/// optimized mean) — the machine-independent ratio the `speedup_*` metrics
/// record (e.g. `speedup_sim_parallel` = 1-thread mean / pooled mean).
/// `NaN` when either case is missing.
pub fn speedup(cases: &[BenchStats], optimized: &str, reference: &str) -> f64 {
    let new = mean_of(cases, optimized).unwrap_or(f64::NAN);
    let old = mean_of(cases, reference).unwrap_or(f64::NAN);
    old / new
}

/// Build the `BENCH_*.json` document: the timed cases plus free-form
/// numeric metrics (speedups, ratios) at the top level.
pub fn to_json(bench: &str, cases: &[BenchStats], metrics: &[(&str, f64)]) -> Json {
    let case_objs: Vec<Json> = cases
        .iter()
        .map(|c| {
            Json::obj()
                .set("name", c.name.as_str())
                .set("iters", c.iters)
                .set("min_ms", c.min_ms)
                .set("mean_ms", c.mean_ms)
                .set("max_ms", c.max_ms)
        })
        .collect();
    let mut doc = Json::obj()
        .set("bench", bench)
        .set("cases", Json::Arr(case_objs));
    for (k, v) in metrics {
        doc = doc.set(k, *v);
    }
    doc
}

/// Write the bench document to `path` (pretty-printed, trailing newline).
pub fn write_json(
    path: &str,
    bench: &str,
    cases: &[BenchStats],
    metrics: &[(&str, f64)],
) -> io::Result<()> {
    let mut s = to_json(bench, cases, metrics).to_string_pretty();
    s.push('\n');
    std::fs::write(path, s)
}

/// Read the timed cases back out of a `BENCH_*.json` file produced by
/// [`write_json`]. Line scanner, not a general JSON parser: it relies on
/// the writer's one-key-per-line, sorted-key layout (within a case object
/// the keys arrive `iters`, `max_ms`, `mean_ms`, `min_ms`, `name` — `name`
/// closes the record).
pub fn read_json(path: &str) -> io::Result<Vec<BenchStats>> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse_cases(&text))
}

fn parse_cases(text: &str) -> Vec<BenchStats> {
    let mut out = Vec::new();
    let (mut iters, mut min_ms, mut mean_ms, mut max_ms) = (0u32, 0f64, 0f64, 0f64);
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some((key, value)) = line.split_once(':') else { continue };
        let key = key.trim().trim_matches('"');
        let value = value.trim();
        match key {
            "iters" => iters = value.parse().unwrap_or(0),
            "min_ms" => min_ms = value.parse().unwrap_or(0.0),
            "mean_ms" => mean_ms = value.parse().unwrap_or(0.0),
            "max_ms" => max_ms = value.parse().unwrap_or(0.0),
            "name" => {
                out.push(BenchStats {
                    name: value.trim_matches('"').to_string(),
                    iters,
                    min_ms,
                    mean_ms,
                    max_ms,
                });
                (iters, min_ms, mean_ms, max_ms) = (0, 0.0, 0.0, 0.0);
            }
            _ => {}
        }
    }
    out
}

/// A top-level numeric metric (e.g. `speedup_engine_bfs`) from a
/// `BENCH_*.json` file, if present. Case objects also contain numeric keys,
/// so only keys outside the known case fields are considered.
pub fn read_metric(path: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some((k, v)) = line.split_once(':') else { continue };
        if k.trim().trim_matches('"') == key
            && !matches!(key, "iters" | "min_ms" | "mean_ms" | "max_ms")
        {
            return v.trim().parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let s = time_runs("noop", 3, || 1 + 1);
        assert_eq!(s.iters, 3);
        assert!(s.min_ms <= s.mean_ms && s.mean_ms <= s.max_ms);
    }

    #[test]
    fn report_contains_name() {
        let s = time_runs("xyz", 2, || ());
        assert!(s.report().contains("xyz"));
    }

    #[test]
    fn json_roundtrip_preserves_cases_and_metrics() {
        let cases = vec![
            BenchStats {
                name: "hotpath/engine-bfs".into(),
                iters: 5,
                min_ms: 1.25,
                mean_ms: 2.0,
                max_ms: 3.5,
            },
            BenchStats {
                name: "hotpath/engine-sssp".into(),
                iters: 3,
                min_ms: 10.0,
                mean_ms: 11.5,
                max_ms: 13.0,
            },
        ];
        let path = std::env::temp_dir().join(format!(
            "alb-bench-roundtrip-{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        write_json(&path, "hotpath", &cases, &[("speedup_engine_bfs", 2.5)])
            .unwrap();
        let got = read_json(&path).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].name, "hotpath/engine-bfs");
        assert_eq!(got[0].iters, 5);
        assert!((got[0].mean_ms - 2.0).abs() < 1e-12);
        assert!((got[1].max_ms - 13.0).abs() < 1e-12);
        assert_eq!(mean_of(&got, "hotpath/engine-sssp"), Some(11.5));
        assert_eq!(mean_of(&got, "missing"), None);
        let s = speedup(&got, "hotpath/engine-bfs", "hotpath/engine-sssp");
        assert!((s - 5.75).abs() < 1e-12, "{s}");
        assert!(speedup(&got, "hotpath/engine-bfs", "missing").is_nan());
        assert_eq!(read_metric(&path, "speedup_engine_bfs"), Some(2.5));
        assert_eq!(read_metric(&path, "not_there"), None);
        let _ = std::fs::remove_file(&path);
    }
}
