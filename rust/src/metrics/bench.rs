//! Minimal benchmarking harness (the vendored crate set has no criterion).
//!
//! Each `benches/*.rs` binary drives one paper table/figure through
//! [`time_runs`]: warmup + N timed repetitions, reporting min/mean/max host
//! time alongside the experiment's own simulated-ms output.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u32,
    pub min_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} {:>3} iters  min {:>9.2} ms  mean {:>9.2} ms  max {:>9.2} ms",
            self.name, self.iters, self.min_ms, self.mean_ms, self.max_ms
        )
    }
}

/// Run `f` once for warmup then `iters` timed times.
pub fn time_runs<T>(name: &str, iters: u32, mut f: impl FnMut() -> T) -> BenchStats {
    let _warmup = f();
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        let _ = f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchStats { name: name.to_string(), iters, min_ms: min, mean_ms: mean, max_ms: max }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let s = time_runs("noop", 3, || 1 + 1);
        assert_eq!(s.iters, 3);
        assert!(s.min_ms <= s.mean_ms && s.mean_ms <= s.max_ms);
    }

    #[test]
    fn report_contains_name() {
        let s = time_runs("xyz", 2, || ());
        assert!(s.report().contains("xyz"));
    }
}
