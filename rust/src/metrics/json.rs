//! Minimal JSON value builder + serializer (the vendored crate set has no
//! serde, so reports are built explicitly — which also keeps the output
//! schema obvious at the call site).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects keep sorted key order (BTreeMap) so output is
/// deterministic and diffable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — construction bug).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// One-line rendering for line-delimited protocols (`alb serve`): same
    /// sorted-key determinism as [`to_string_pretty`]
    /// (Self::to_string_pretty), no interior newlines ever (strings escape
    /// them), so one reply is always exactly one line.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\"{k}\":");
                    v.write_compact(out);
                }
                out.push('}');
            }
            // Scalars render identically in both modes.
            scalar => scalar.write(out, 0),
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    item.write(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{k}\": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::from(true).to_string_pretty(), "true");
        assert_eq!(Json::from(3u64).to_string_pretty(), "3");
        assert_eq!(Json::from(3.5).to_string_pretty(), "3.5");
        assert_eq!(Json::Null.to_string_pretty(), "null");
    }

    #[test]
    fn string_escaping() {
        let s = Json::from("a\"b\\c\nd");
        assert_eq!(s.to_string_pretty(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn object_sorted_deterministic() {
        let j = Json::obj().set("b", 2u64).set("a", 1u64);
        let out = j.to_string_pretty();
        assert!(out.find("\"a\"").unwrap() < out.find("\"b\"").unwrap());
    }

    #[test]
    fn nested_roundtrip_shape() {
        let j = Json::obj()
            .set("xs", vec![1u64, 2, 3])
            .set("meta", Json::obj().set("name", "run"));
        let out = j.to_string_pretty();
        assert!(out.contains("\"xs\": [\n"));
        assert!(out.contains("\"name\": \"run\""));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
        assert_eq!(Json::obj().to_string_pretty(), "{}");
    }

    #[test]
    fn compact_is_one_line_and_sorted() {
        let j = Json::obj()
            .set("b", vec![1u64, 2])
            .set("a", Json::obj().set("x", "line\nbreak"))
            .set("c", Json::Null);
        let out = j.to_string_compact();
        assert!(!out.contains('\n'), "compact output must be newline-free");
        assert_eq!(out, r#"{"a":{"x":"line\nbreak"},"b":[1,2],"c":null}"#);
    }
}
