//! Fixed-width table printer — the repro harness prints every paper table
//! and figure's data series through this.

/// A simple left-aligned-first-column, right-aligned-rest table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = width[0]));
                } else {
                    line.push_str(&format!("  {:>w$}", c, w = width[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let total: usize = width.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

/// Format milliseconds like the paper's tables (one decimal at paper
/// magnitudes; more precision for the scaled-down simulation values).
pub fn ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.1}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

/// Format a speedup ratio.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["input", "twc", "alb"]);
        t.row(vec!["rmat18".into(), "522.7".into(), "133.0".into()]);
        t.row(vec!["road-s".into(), "3.1".into(), "3.2".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("input"));
        assert!(lines[1].starts_with("---"));
        // right alignment: all rows same length
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ms(133.04), "133.0");
        assert_eq!(ms(3.126), "3.13");
        assert_eq!(ms(0.01234), "0.0123");
        assert_eq!(speedup(3.929), "3.93x");
    }
}
