//! Reporting: a dependency-free JSON writer and fixed-width table printer
//! used by the CLI, the repro harness, and EXPERIMENTS.md generation.

pub mod bench;
pub mod json;
pub mod table;

pub use json::Json;
pub use table::Table;

/// Load-imbalance summary over per-block edge counts (the quantity the
/// paper's Figures 1 and 5 plot).
#[derive(Debug, Clone)]
pub struct Imbalance {
    pub max: u64,
    pub mean: f64,
    pub factor: f64,
}

pub fn imbalance(block_edges: &[u64]) -> Imbalance {
    let max = block_edges.iter().copied().max().unwrap_or(0);
    let sum: u64 = block_edges.iter().sum();
    let mean = sum as f64 / block_edges.len().max(1) as f64;
    let factor = if mean > 0.0 { max as f64 / mean } else { 1.0 };
    Imbalance { max, mean, factor }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_uniform() {
        let i = imbalance(&[10, 10, 10]);
        assert_eq!(i.max, 10);
        assert!((i.factor - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_skewed() {
        let i = imbalance(&[100, 0, 0, 0]);
        assert_eq!(i.max, 100);
        assert!((i.factor - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_empty() {
        let i = imbalance(&[]);
        assert_eq!(i.max, 0);
        assert!((i.factor - 1.0).abs() < 1e-12);
    }
}
