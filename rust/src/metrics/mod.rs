//! Reporting: a dependency-free JSON writer and fixed-width table printer
//! used by the CLI, the repro harness, and EXPERIMENTS.md generation.

pub mod bench;
pub mod json;
pub mod table;

pub use json::Json;
pub use table::Table;

/// FNV-1a offset basis (the hash of an empty label array).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over the little-endian bit patterns of `labels` — the
/// machine-independent per-cell fingerprint the campaign artifacts record.
/// Labels are bit-deterministic for any pool width / exec mode
/// (`rust/tests/parity.rs`), so hashes computed on different machines are
/// directly comparable.
pub fn labels_hash(labels: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &x in labels {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Load-imbalance summary over per-block edge counts (the quantity the
/// paper's Figures 1 and 5 plot).
#[derive(Debug, Clone)]
pub struct Imbalance {
    pub max: u64,
    pub mean: f64,
    pub factor: f64,
}

pub fn imbalance(block_edges: &[u64]) -> Imbalance {
    let max = block_edges.iter().copied().max().unwrap_or(0);
    let sum: u64 = block_edges.iter().sum();
    let mean = sum as f64 / block_edges.len().max(1) as f64;
    let factor = if mean > 0.0 { max as f64 / mean } else { 1.0 };
    Imbalance { max, mean, factor }
}

/// One simulated GPU's utilisation: modeled cycles next to the host
/// wall-clock its rounds actually took (the coordinator records both).
#[derive(Debug, Clone)]
pub struct GpuLoad {
    pub gpu: usize,
    pub comp_cycles: u64,
    pub wall_ns: u64,
}

impl GpuLoad {
    pub fn wall_ms(&self) -> f64 {
        self.wall_ns as f64 / 1e6
    }
}

/// Zip the coordinator's per-GPU modeled cycles with measured wall-clock.
pub fn gpu_loads(comp_cycles: &[u64], wall_ns: &[u64]) -> Vec<GpuLoad> {
    comp_cycles
        .iter()
        .zip(wall_ns)
        .enumerate()
        .map(|(gpu, (&comp_cycles, &wall_ns))| GpuLoad { gpu, comp_cycles, wall_ns })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_uniform() {
        let i = imbalance(&[10, 10, 10]);
        assert_eq!(i.max, 10);
        assert!((i.factor - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_skewed() {
        let i = imbalance(&[100, 0, 0, 0]);
        assert_eq!(i.max, 100);
        assert!((i.factor - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_empty() {
        let i = imbalance(&[]);
        assert_eq!(i.max, 0);
        assert!((i.factor - 1.0).abs() < 1e-12);
    }

    #[test]
    fn labels_hash_is_stable_and_discriminating() {
        assert_eq!(labels_hash(&[]), FNV_OFFSET);
        assert_eq!(labels_hash(&[1.0, 2.0]), labels_hash(&[1.0, 2.0]));
        assert_ne!(labels_hash(&[1.0, 2.0]), labels_hash(&[2.0, 1.0]));
        assert_ne!(labels_hash(&[0.0]), labels_hash(&[]));
        // Bit-pattern sensitive: -0.0 and 0.0 differ.
        assert_ne!(labels_hash(&[-0.0]), labels_hash(&[0.0]));
    }

    #[test]
    fn gpu_loads_zip_by_index() {
        let loads = gpu_loads(&[10, 20], &[1_000_000, 2_500_000]);
        assert_eq!(loads.len(), 2);
        assert_eq!(loads[1].gpu, 1);
        assert_eq!(loads[1].comp_cycles, 20);
        assert!((loads[1].wall_ms() - 2.5).abs() < 1e-12);
    }
}
