//! Deterministic, splittable PRNG (xorshift64* + SplitMix64 seeding).
//!
//! Every stochastic component in the repository (graph generators, workload
//! sampling, cache-model sampling) draws from this RNG so that experiment
//! outputs are bit-reproducible from a seed recorded in the run report.

/// A small, fast, deterministic PRNG. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Seeds are diffused through SplitMix64
    /// so that consecutive small seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        s = (s ^ (s >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        s = (s ^ (s >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        s ^= s >> 31;
        Rng { state: s | 1 } // xorshift state must be nonzero
    }

    /// Derive an independent child stream (e.g., one per partition/GPU).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit value (xorshift64*).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // bias is < 2^-32 for all n used in this codebase.
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn gen_range_respects_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1 << 40] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_domain() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_f64_mean_is_centered() {
        let mut r = Rng::new(123);
        let mean: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
