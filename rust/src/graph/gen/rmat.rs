//! R-MAT graph generator (Chakrabarti, Zhan, Faloutsos).
//!
//! The paper's rmat23–rmat27 inputs come from "an RMAT generator [5]" with
//! edge factor 16 and an extremely skewed out-degree (max Dout 35M at scale
//! 23 — i.e. a handful of vertices own a large constant fraction of all
//! edges, which is what trips TWC's thread-block balance). We reproduce that
//! regime with the classic recursive-quadrant construction using skewed
//! (a, b, c, d) and **no deduplication** (multi-edges kept, as Graph500 and
//! the paper's degree table imply).

use crate::graph::coo::EdgeList;
use crate::graph::rng::Rng;

/// R-MAT parameters. `scale` = log2(num vertices).
#[derive(Debug, Clone)]
pub struct RmatConfig {
    pub scale: u32,
    pub edge_factor: u32,
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Per-level quadrant-probability noise, as in the reference generator.
    pub noise: f64,
    pub seed: u64,
    /// Max integer sssp weight (weights uniform in [1, max_weight]).
    pub max_weight: u32,
}

impl RmatConfig {
    /// The skewed preset that reproduces the paper's degree regime:
    /// a huge out-degree hub at vertex 0 (paper Table 1: max Dout is a
    /// sizable fraction of |E|) while max Din stays orders of magnitude
    /// smaller (so pull-style pr never trips the huge bin — §6.1).
    ///
    /// P(src bit = 0) = a + b = 0.92 per level — at scale 16 the hub owns
    /// ~25% of all edges, the same fraction as the paper's rmat23 (35M of
    /// 134M, Fig. 5a). P(dst bit = 0) = a + c = 0.60 keeps max Din mild.
    pub fn paper(scale: u32, seed: u64) -> Self {
        RmatConfig {
            scale,
            edge_factor: 16,
            a: 0.55,
            b: 0.37,
            c: 0.05,
            noise: 0.0,
            seed,
            max_weight: 100,
        }
    }
}

/// Generate a directed R-MAT multigraph.
///
/// All size arithmetic is u64 before any narrowing: at sc >= 24 the edge
/// count (`n * edge_factor`) no longer fits in u32, and a silent `as u32`
/// on the vertex count would truncate at sc >= 32 — both are checked here
/// instead of wrapping.
pub fn generate(cfg: &RmatConfig) -> EdgeList {
    assert!(
        cfg.scale < 32,
        "rmat scale {} overflows u32 vertex ids",
        cfg.scale
    );
    let n = 1u64 << cfg.scale;
    let m = n * cfg.edge_factor as u64;
    let mut rng = Rng::new(cfg.seed);
    let mut el = EdgeList::new(u32::try_from(n).expect("scale < 32"));
    el.edges
        .reserve(usize::try_from(m).expect("edge count overflows usize"));
    for _ in 0..m {
        let (src, dst) = sample_edge(cfg, &mut rng);
        let w = (1 + rng.gen_range(cfg.max_weight as u64)) as f32;
        el.push(src, dst, w);
    }
    el
}

#[inline]
fn sample_edge(cfg: &RmatConfig, rng: &mut Rng) -> (u32, u32) {
    let (mut src, mut dst) = (0u64, 0u64);
    let d0 = 1.0 - cfg.a - cfg.b - cfg.c;
    for level in 0..cfg.scale {
        // Optional per-level noise keeps the quadrant probabilities from
        // producing a perfectly self-similar graph.
        let jitter = if cfg.noise > 0.0 {
            (rng.gen_f64() - 0.5) * 2.0 * cfg.noise
        } else {
            0.0
        };
        let a = (cfg.a + jitter).clamp(0.0, 1.0);
        let r = rng.gen_f64();
        let bit = 1u64 << (cfg.scale - 1 - level);
        if r < a {
            // quadrant (0, 0): nothing set
        } else if r < a + cfg.b {
            dst |= bit;
        } else if r < a + cfg.b + cfg.c {
            src |= bit;
        } else {
            debug_assert!(d0 >= 0.0);
            src |= bit;
            dst |= bit;
        }
    }
    (src as u32, dst as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;

    #[test]
    fn sizes_match_config() {
        let el = generate(&RmatConfig::paper(10, 1));
        assert_eq!(el.num_vertices, 1024);
        assert_eq!(el.num_edges(), 1024 * 16);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&RmatConfig::paper(8, 7));
        let b = generate(&RmatConfig::paper(8, 7));
        assert_eq!(a.edges.len(), b.edges.len());
        assert!(a.edges.iter().zip(&b.edges).all(|(x, y)| x == y));
    }

    #[test]
    fn out_degree_is_heavily_skewed() {
        // The paper regime: max Dout is a large fraction of |E|; the degree
        // distribution must be power-law-ish, not uniform.
        let el = generate(&RmatConfig::paper(12, 3));
        let g = CsrGraph::from_edge_list(&el);
        let max_d = (0..g.num_vertices() as u32)
            .map(|v| g.out_degree(v))
            .max()
            .unwrap();
        let avg = g.num_edges() as u64 / g.num_vertices() as u64;
        assert!(
            max_d > 50 * avg,
            "expected heavy skew: max {max_d} vs avg {avg}"
        );
    }

    #[test]
    fn vertex_zero_is_the_hub() {
        // With a=0.57 the all-zero prefix is the most likely, so vertex 0
        // collects the largest out-degree — the huge vertex ALB must catch.
        let el = generate(&RmatConfig::paper(12, 3));
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(g.max_out_degree_vertex(), 0);
    }

    #[test]
    #[should_panic(expected = "overflows u32 vertex ids")]
    fn scale_32_is_rejected_not_truncated() {
        generate(&RmatConfig::paper(32, 1));
    }

    #[test]
    fn weights_in_declared_range() {
        let cfg = RmatConfig { max_weight: 5, ..RmatConfig::paper(8, 2) };
        let el = generate(&cfg);
        assert!(el.edges.iter().all(|e| (1.0..=5.0).contains(&e.weight)));
    }

    #[test]
    fn in_degree_much_less_skewed_than_out() {
        // Paper Table 1: rmat graphs have max Din orders of magnitude below
        // max Dout. This asymmetry (from b > c) is what makes push apps
        // (bfs/sssp/cc) trip the huge bin while pull apps (pr) do not.
        let el = generate(&RmatConfig::paper(12, 9));
        let mut g = CsrGraph::from_edge_list(&el);
        g.build_csc();
        let max_out = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        let max_in = (0..g.num_vertices() as u32).map(|v| g.in_degree(v)).max().unwrap();
        assert!(max_out >= 8 * max_in, "out {max_out} in {max_in}");
    }
}
