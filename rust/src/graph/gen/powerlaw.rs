//! Configuration-model power-law generator — the orkut / twitter40 / uk2007
//! analogues.
//!
//! Unlike R-MAT (whose hub is an emergent property), this generator gives
//! direct control over the degree distribution: out-degrees are drawn from a
//! truncated Zipf with exponent `alpha`, capped at `max_degree`, and
//! destinations are sampled uniformly. That lets each paper input's regime
//! be pinned exactly (see `inputs.rs`):
//!
//! * orkut:    symmetric, moderate max degree (33,313 at |V| = 3.1M), high
//!             E/V — a power-law graph whose hub stays *below* the huge
//!             threshold on the paper's GPU, so ALB must not trigger.
//! * twitter:  directed, max Dout ~ 3M — triggers ALB.
//! * uk2007:   high E/V but max Dout (15,402) below the launched-thread
//!             count — the paper's "no huge vertex in any round" case.

use crate::graph::coo::EdgeList;
use crate::graph::rng::Rng;

#[derive(Debug, Clone)]
pub struct PowerLawConfig {
    pub num_vertices: u32,
    /// Target average out-degree (E/V).
    pub avg_degree: u32,
    /// Zipf exponent for the out-degree distribution (typ. 1.8–2.4).
    pub alpha: f64,
    /// Hard cap on any vertex's out-degree.
    pub max_degree: u32,
    /// Add the reverse of every edge (orkut is undirected).
    pub symmetric: bool,
    pub max_weight: u32,
    pub seed: u64,
}

/// Generate by drawing a degree sequence then sampling destinations.
pub fn generate(cfg: &PowerLawConfig) -> EdgeList {
    let n = cfg.num_vertices as u64;
    let mut rng = Rng::new(cfg.seed);

    // Draw raw Zipf degrees: P(deg = k) ~ k^-alpha on [1, max_degree] via
    // inverse-transform on the (approximate) continuous CDF.
    let mut degrees = vec![0u32; n as usize];
    let amin1 = cfg.alpha - 1.0;
    let kmax = cfg.max_degree as f64;
    let mut total: u64 = 0;
    for d in degrees.iter_mut() {
        let u = rng.gen_f64().max(1e-12);
        // Inverse CDF of the truncated Pareto with tail index alpha-1.
        let k = (1.0 - u * (1.0 - kmax.powf(-amin1))).powf(-1.0 / amin1);
        *d = (k as u32).clamp(1, cfg.max_degree);
        total += *d as u64;
    }

    // Rescale toward the requested average degree by thinning/boosting with
    // the cap respected (hubs keep their relative rank).
    let want: u64 = n * cfg.avg_degree as u64;
    let scale = want as f64 / total as f64;
    let mut m: u64 = 0;
    for d in degrees.iter_mut() {
        let s = ((*d as f64 * scale).round() as u32).clamp(1, cfg.max_degree);
        *d = s;
        m += s as u64;
    }

    // Reservation arithmetic stays in u64 until the final checked cast: at
    // the sc >= 24 analogue (|V| in the millions, avg degree in the tens)
    // `2 * m` no longer fits in u32, and a wrapping cast would
    // under-reserve or, on a 32-bit host, truncate.
    let reserve = if cfg.symmetric { 2 * m } else { m };
    let mut el = EdgeList::new(cfg.num_vertices);
    el.edges
        .reserve(usize::try_from(reserve).expect("edge count overflows usize"));
    for (v, &d) in degrees.iter().enumerate() {
        for _ in 0..d {
            let dst = rng.gen_range(n) as u32;
            let w = (1 + rng.gen_range(cfg.max_weight as u64)) as f32;
            el.push(v as u32, dst, w);
        }
    }
    if cfg.symmetric {
        el.symmetrize();
    }
    el
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;

    fn base(n: u32, seed: u64) -> PowerLawConfig {
        PowerLawConfig {
            num_vertices: n,
            avg_degree: 16,
            alpha: 2.0,
            max_degree: 10_000,
            symmetric: false,
            max_weight: 64,
            seed,
        }
    }

    #[test]
    fn average_degree_near_target() {
        let el = generate(&base(10_000, 1));
        let avg = el.num_edges() as f64 / el.num_vertices as f64;
        assert!((avg - 16.0).abs() < 4.0, "avg degree {avg}");
    }

    #[test]
    fn max_degree_cap_respected() {
        let mut cfg = base(10_000, 2);
        cfg.max_degree = 100;
        let el = generate(&cfg);
        let g = CsrGraph::from_edge_list(&el);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_d <= 100);
    }

    #[test]
    fn distribution_is_heavy_tailed() {
        let el = generate(&base(20_000, 3));
        let g = CsrGraph::from_edge_list(&el);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        let avg = g.num_edges() as u64 / g.num_vertices() as u64;
        assert!(max_d > 10 * avg, "max {max_d} vs avg {avg}");
    }

    #[test]
    fn symmetric_doubles_and_mirrors() {
        let mut cfg = base(1_000, 4);
        cfg.symmetric = true;
        let el = generate(&cfg);
        let mut g = CsrGraph::from_edge_list(&el);
        g.build_csc();
        // In a symmetrized graph every vertex has in-degree == out-degree.
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(g.out_degree(v), g.in_degree(v), "vertex {v}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&base(2_000, 5));
        let b = generate(&base(2_000, 5));
        assert!(a.edges.iter().zip(&b.edges).all(|(x, y)| x == y));
    }

    #[test]
    fn every_vertex_has_at_least_one_out_edge() {
        let el = generate(&base(5_000, 6));
        let g = CsrGraph::from_edge_list(&el);
        for v in 0..g.num_vertices() as u32 {
            assert!(g.out_degree(v) >= 1);
        }
    }
}
