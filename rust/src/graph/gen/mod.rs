//! Graph generators: R-MAT (paper's rmat23–27), road grids (road-USA),
//! and configuration-model power-law graphs (orkut / twitter40 / uk2007).

pub mod powerlaw;
pub mod rmat;
pub mod road;
