//! Road-network generator — the road-USA analogue.
//!
//! Paper Table 1 characterizes road-USA as: E/V = 2, max degree 9, huge
//! diameter (6261), uniform low degrees. We reproduce that regime with a
//! W x H grid: each cell connects to its right/down neighbors (both
//! directions, so E/V ~= 4 before trimming) plus a sparse sprinkle of
//! diagonal "shortcut" streets, capped so no vertex exceeds degree 8.
//! Weights are small integers (road segment lengths).

use crate::graph::coo::EdgeList;
use crate::graph::rng::Rng;

#[derive(Debug, Clone)]
pub struct RoadConfig {
    pub width: u32,
    pub height: u32,
    /// Probability a cell gets a diagonal edge pair.
    pub diagonal_p: f64,
    /// Probability an axis edge is dropped (models missing street links and
    /// brings E/V down toward the road-USA ratio).
    pub drop_p: f64,
    pub max_weight: u32,
    pub seed: u64,
}

impl RoadConfig {
    /// road-USA-like defaults at a given side length.
    pub fn paper(side: u32, seed: u64) -> Self {
        RoadConfig {
            width: side,
            height: side,
            diagonal_p: 0.05,
            drop_p: 0.25,
            max_weight: 1000,
            seed,
        }
    }
}

/// Generate a bidirected grid road network.
pub fn generate(cfg: &RoadConfig) -> EdgeList {
    let n = cfg.width as u64 * cfg.height as u64;
    assert!(n <= u32::MAX as u64, "grid too large");
    let mut rng = Rng::new(cfg.seed);
    let mut el = EdgeList::new(n as u32);
    let id = |x: u32, y: u32| y * cfg.width + x;
    let both = |el: &mut EdgeList, a: u32, b: u32, rng: &mut Rng| {
        let w = (1 + rng.gen_range(cfg.max_weight as u64)) as f32;
        el.push(a, b, w);
        el.push(b, a, w);
    };
    for y in 0..cfg.height {
        for x in 0..cfg.width {
            let v = id(x, y);
            if x + 1 < cfg.width && !rng.gen_bool(cfg.drop_p) {
                both(&mut el, v, id(x + 1, y), &mut rng);
            }
            if y + 1 < cfg.height && !rng.gen_bool(cfg.drop_p) {
                both(&mut el, v, id(x, y + 1), &mut rng);
            }
            if x + 1 < cfg.width && y + 1 < cfg.height && rng.gen_bool(cfg.diagonal_p)
            {
                both(&mut el, v, id(x + 1, y + 1), &mut rng);
            }
        }
    }
    el
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;

    #[test]
    fn degree_is_bounded_like_road_usa() {
        let el = generate(&RoadConfig::paper(64, 1));
        let g = CsrGraph::from_edge_list(&el);
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_d <= 9, "road max degree {max_d} > 9");
    }

    #[test]
    fn edge_ratio_near_paper() {
        let el = generate(&RoadConfig::paper(128, 2));
        let ratio = el.num_edges() as f64 / el.num_vertices as f64;
        assert!((1.5..4.0).contains(&ratio), "E/V = {ratio}");
    }

    #[test]
    fn edges_are_symmetric() {
        let el = generate(&RoadConfig::paper(32, 3));
        let mut set = std::collections::HashSet::new();
        for e in &el.edges {
            set.insert((e.src, e.dst));
        }
        for e in &el.edges {
            assert!(set.contains(&(e.dst, e.src)));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate(&RoadConfig::paper(32, 9));
        let b = generate(&RoadConfig::paper(32, 9));
        assert!(a.edges.iter().zip(&b.edges).all(|(x, y)| x == y));
    }

    #[test]
    fn grid_is_locally_connected() {
        // Neighbor ids only differ by +-1, +-W, or +-(W+1).
        let cfg = RoadConfig::paper(16, 4);
        let el = generate(&cfg);
        for e in &el.edges {
            let d = (e.src as i64 - e.dst as i64).unsigned_abs();
            assert!(
                d == 1 || d == cfg.width as u64 || d == cfg.width as u64 + 1,
                "non-local edge {} -> {}",
                e.src,
                e.dst
            );
        }
    }
}
