//! Compressed-sparse-row graph — the runtime representation.
//!
//! Matches the paper's storage decision (§4.1): the graph stays in CSR (plus
//! an optional CSC view for pull-style operators); the LB kernel recovers an
//! edge's endpoints from its global edge id with a binary search over the
//! huge-vertex prefix array instead of materializing COO.

use super::coo::EdgeList;

/// CSR graph with out-edges; optionally carries the transposed (CSC) view
/// for pull-style applications (pagerank, k-core).
#[derive(Debug, Clone)]
pub struct CsrGraph {
    /// `row_offsets[v]..row_offsets[v+1]` indexes `col_idx`/`weights`.
    pub row_offsets: Vec<u64>,
    /// Destination vertex of each out-edge.
    pub col_idx: Vec<u32>,
    /// Weight of each out-edge.
    pub weights: Vec<f32>,
    /// Transposed view (in-edges), built on demand.
    pub csc: Option<Box<CscView>>,
}

/// The in-edge (CSC) view: `in_offsets[v]..in_offsets[v+1]` indexes
/// `in_src`/`in_weights`, giving vertex `v`'s in-neighbors.
#[derive(Debug, Clone)]
pub struct CscView {
    pub in_offsets: Vec<u64>,
    pub in_src: Vec<u32>,
    pub in_weights: Vec<f32>,
}

impl CsrGraph {
    /// Build from an edge list (counting sort by source; stable within a
    /// source in input order).
    pub fn from_edge_list(el: &EdgeList) -> Self {
        let n = el.num_vertices as usize;
        let m = el.edges.len();
        let mut counts = vec![0u64; n + 1];
        for e in &el.edges {
            counts[e.src as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let row_offsets = counts.clone();
        let mut cursor = counts;
        let mut col_idx = vec![0u32; m];
        let mut weights = vec![0f32; m];
        for e in &el.edges {
            let p = cursor[e.src as usize] as usize;
            col_idx[p] = e.dst;
            weights[p] = e.weight;
            cursor[e.src as usize] += 1;
        }
        CsrGraph { row_offsets, col_idx, weights, csc: None }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.row_offsets.len() - 1
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.col_idx.len()
    }

    #[inline]
    pub fn out_degree(&self, v: u32) -> u64 {
        self.row_offsets[v as usize + 1] - self.row_offsets[v as usize]
    }

    /// Out-neighbors of `v` as parallel (dst, weight) slices.
    #[inline]
    pub fn out_edges(&self, v: u32) -> (&[u32], &[f32]) {
        let lo = self.row_offsets[v as usize] as usize;
        let hi = self.row_offsets[v as usize + 1] as usize;
        (&self.col_idx[lo..hi], &self.weights[lo..hi])
    }

    /// Global edge-id range owned by `v` (the LB kernel's CSR <-> edge-id map).
    #[inline]
    pub fn edge_range(&self, v: u32) -> std::ops::Range<u64> {
        self.row_offsets[v as usize]..self.row_offsets[v as usize + 1]
    }

    /// Destination and weight of global edge id `e`.
    #[inline]
    pub fn edge(&self, e: u64) -> (u32, f32) {
        (self.col_idx[e as usize], self.weights[e as usize])
    }

    /// Build (and cache) the transposed view. Idempotent.
    pub fn build_csc(&mut self) {
        if self.csc.is_some() {
            return;
        }
        let n = self.num_vertices();
        let m = self.num_edges();
        let mut counts = vec![0u64; n + 1];
        for &d in &self.col_idx {
            counts[d as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let in_offsets = counts.clone();
        let mut cursor = counts;
        let mut in_src = vec![0u32; m];
        let mut in_weights = vec![0f32; m];
        for v in 0..n as u32 {
            let (dsts, ws) = {
                let lo = self.row_offsets[v as usize] as usize;
                let hi = self.row_offsets[v as usize + 1] as usize;
                (&self.col_idx[lo..hi], &self.weights[lo..hi])
            };
            for (&d, &w) in dsts.iter().zip(ws) {
                let p = cursor[d as usize] as usize;
                in_src[p] = v;
                in_weights[p] = w;
                cursor[d as usize] += 1;
            }
        }
        self.csc = Some(Box::new(CscView { in_offsets, in_src, in_weights }));
    }

    #[inline]
    pub fn in_degree(&self, v: u32) -> u64 {
        let c = self.csc.as_ref().expect("build_csc() first");
        c.in_offsets[v as usize + 1] - c.in_offsets[v as usize]
    }

    /// In-neighbors of `v` as parallel (src, weight) slices.
    #[inline]
    pub fn in_edges(&self, v: u32) -> (&[u32], &[f32]) {
        let c = self.csc.as_ref().expect("build_csc() first");
        let lo = c.in_offsets[v as usize] as usize;
        let hi = c.in_offsets[v as usize + 1] as usize;
        (&c.in_src[lo..hi], &c.in_weights[lo..hi])
    }

    /// Highest-out-degree vertex (the paper's bfs/sssp source on power-law
    /// inputs).
    pub fn max_out_degree_vertex(&self) -> u32 {
        (0..self.num_vertices() as u32)
            .max_by_key(|&v| self.out_degree(v))
            .unwrap_or(0)
    }

    /// In-memory size estimate in bytes (CSR arrays only), for Table 1.
    pub fn size_bytes(&self) -> u64 {
        (self.row_offsets.len() * 8 + self.col_idx.len() * 4
            + self.weights.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::EdgeList;

    fn diamond() -> CsrGraph {
        // 0 -> {1, 2}, 1 -> 3, 2 -> 3
        let mut el = EdgeList::new(4);
        el.push(0, 1, 1.0);
        el.push(0, 2, 2.0);
        el.push(1, 3, 3.0);
        el.push(2, 3, 4.0);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn build_counts() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(3), 0);
    }

    #[test]
    fn out_edges_contents() {
        let g = diamond();
        let (dsts, ws) = g.out_edges(0);
        assert_eq!(dsts, &[1, 2]);
        assert_eq!(ws, &[1.0, 2.0]);
        let (dsts, _) = g.out_edges(3);
        assert!(dsts.is_empty());
    }

    #[test]
    fn edge_range_and_lookup_agree() {
        let g = diamond();
        let r = g.edge_range(2);
        assert_eq!(r, 3..4);
        assert_eq!(g.edge(3), (3, 4.0));
    }

    #[test]
    fn csc_transpose_roundtrip() {
        let mut g = diamond();
        g.build_csc();
        assert_eq!(g.in_degree(3), 2);
        assert_eq!(g.in_degree(0), 0);
        let (srcs, ws) = g.in_edges(3);
        assert_eq!(srcs, &[1, 2]);
        assert_eq!(ws, &[3.0, 4.0]);
    }

    #[test]
    fn csc_preserves_edge_count() {
        let mut g = diamond();
        g.build_csc();
        let c = g.csc.as_ref().unwrap();
        assert_eq!(c.in_src.len(), g.num_edges());
        assert_eq!(*c.in_offsets.last().unwrap(), g.num_edges() as u64);
    }

    #[test]
    fn build_csc_idempotent() {
        let mut g = diamond();
        g.build_csc();
        let before = g.csc.as_ref().unwrap().in_src.clone();
        g.build_csc();
        assert_eq!(g.csc.as_ref().unwrap().in_src, before);
    }

    #[test]
    fn max_out_degree_vertex_found() {
        let g = diamond();
        assert_eq!(g.max_out_degree_vertex(), 0);
    }

    #[test]
    fn empty_vertex_graph() {
        let el = EdgeList::new(3);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.out_degree(1), 0);
    }

    #[test]
    fn size_bytes_counts_arrays() {
        let g = diamond();
        assert_eq!(g.size_bytes(), (5 * 8 + 4 * 4 + 4 * 4) as u64);
    }
}
