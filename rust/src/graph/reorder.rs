//! Cache-aware vertex reordering (DESIGN.md §13).
//!
//! Osama et al. (arXiv:2212.08964) treat locality-oriented reordering as a
//! preprocessing dimension orthogonal to the load balancer: renaming
//! vertices so that vertices referenced together sit close in memory cuts
//! cache misses without touching the schedule. This module applies a
//! permutation at build time and keeps the old<->new mapping so results are
//! always reported in original vertex ids.
//!
//! Legality (DESIGN.md §13): relabeling is a graph isomorphism, so any
//! per-vertex quantity that does not *encode* vertex ids is bit-identical
//! after mapping back — BFS depths, delta-stepping SSSP distances (the
//! bucket order is distance-driven), and k-core flags. CC labels (min
//! vertex id in component) and PageRank (f32 summation order) are not; the
//! parity suite pins the invariant apps only.

use super::coo::EdgeList;
use super::csr::CsrGraph;

/// Which permutation to apply at graph-build time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Reorder {
    /// Identity: keep generator order.
    #[default]
    None,
    /// Sort by (out-degree descending, id ascending): hubs — exactly the
    /// vertices the LB kernel's prefix array and the frontier touch most —
    /// share leading cache lines.
    Degree,
    /// Reverse Cuthill-McKee-style BFS ordering: min-(degree, id) seeds,
    /// neighbors enqueued in (degree, id) order, final order reversed.
    /// Clusters each BFS level's vertices, shrinking label-array stride.
    Rcm,
}

/// Valid `--reorder` values, in the order [`Reorder::parse`] accepts them.
pub const REORDER_NAMES: &[&str] = &["none", "degree", "rcm"];

impl Reorder {
    pub fn parse(s: &str) -> Option<Reorder> {
        match s {
            "none" => Some(Reorder::None),
            "degree" => Some(Reorder::Degree),
            "rcm" => Some(Reorder::Rcm),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Reorder::None => "none",
            Reorder::Degree => "degree",
            Reorder::Rcm => "rcm",
        }
    }
}

/// Old<->new vertex-id mapping produced by [`reorder`]. Kept alongside the
/// renamed graph so sources map forward and labels map back.
#[derive(Debug, Clone)]
pub struct Permutation {
    /// `order[new] = old`: the vertex placed at each new id.
    order: Vec<u32>,
    /// `rank[old] = new`: inverse of `order`.
    rank: Vec<u32>,
}

impl Permutation {
    /// Identity permutation over `n` vertices (the `Reorder::None` case).
    pub fn identity(n: usize) -> Permutation {
        let order: Vec<u32> = (0..n as u32).collect();
        Permutation { rank: order.clone(), order }
    }

    fn from_order(order: Vec<u32>) -> Permutation {
        let mut rank = vec![0u32; order.len()];
        for (new, &old) in order.iter().enumerate() {
            rank[old as usize] = new as u32;
        }
        Permutation { order, rank }
    }

    /// New id of original vertex `old` (forward map, e.g. for the source).
    #[inline]
    pub fn to_new(&self, old: u32) -> u32 {
        self.rank[old as usize]
    }

    /// Original id of renamed vertex `new`.
    #[inline]
    pub fn to_old(&self, new: u32) -> u32 {
        self.order[new as usize]
    }

    /// Map per-vertex labels from renamed ids back to original ids:
    /// `out[old] = new_labels[rank[old]]`.
    pub fn labels_to_original(&self, new_labels: &[f32], out: &mut Vec<f32>) {
        assert_eq!(new_labels.len(), self.rank.len());
        out.clear();
        out.extend(self.rank.iter().map(|&new| new_labels[new as usize]));
    }
}

/// Rename `g`'s vertices per `kind`, returning the renamed graph and the
/// permutation. Deterministic: all orderings break ties by vertex id, and
/// per-vertex adjacency keeps its relative order (only endpoints are
/// renamed), so the result is a pure function of `(g, kind)`.
pub fn reorder(g: &CsrGraph, kind: Reorder) -> (CsrGraph, Permutation) {
    let n = g.num_vertices();
    let perm = match kind {
        Reorder::None => return (g.clone(), Permutation::identity(n)),
        Reorder::Degree => Permutation::from_order(degree_order(g)),
        Reorder::Rcm => Permutation::from_order(rcm_order(g)),
    };
    let mut el = EdgeList::new(n as u32);
    el.edges.reserve(g.num_edges());
    for new_u in 0..n as u32 {
        let (dsts, ws) = g.out_edges(perm.to_old(new_u));
        for (&old_v, &w) in dsts.iter().zip(ws) {
            el.push(new_u, perm.to_new(old_v), w);
        }
    }
    (CsrGraph::from_edge_list(&el), perm)
}

fn degree_order(g: &CsrGraph) -> Vec<u32> {
    let mut order: Vec<u32> = (0..g.num_vertices() as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(g.out_degree(v)), v));
    order
}

fn rcm_order(g: &CsrGraph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut seeds: Vec<u32> = (0..n as u32).collect();
    seeds.sort_by_key(|&v| (g.out_degree(v), v));
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut nbrs: Vec<u32> = Vec::new();
    for &seed in &seeds {
        if visited[seed as usize] {
            continue;
        }
        // BFS from this component's min-(degree, id) seed; `order` doubles
        // as the queue (cursor walks it as vertices are appended).
        let cursor0 = order.len();
        visited[seed as usize] = true;
        order.push(seed);
        let mut cursor = cursor0;
        while cursor < order.len() {
            let u = order[cursor];
            cursor += 1;
            nbrs.clear();
            nbrs.extend_from_slice(g.out_edges(u).0);
            nbrs.sort_by_key(|&v| (g.out_degree(v), v));
            for &v in &nbrs {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    order.push(v);
                }
            }
        }
    }
    order.reverse();
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_with_hub() -> CsrGraph {
        // 0-1-2-3 path plus hub 4 -> {0,1,2,3}.
        let mut el = EdgeList::new(5);
        for v in 0..3u32 {
            el.push(v, v + 1, 1.0);
            el.push(v + 1, v, 1.0);
        }
        for v in 0..4u32 {
            el.push(4, v, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    fn is_permutation(p: &Permutation, n: usize) -> bool {
        let mut seen = vec![false; n];
        for new in 0..n as u32 {
            let old = p.to_old(new);
            if seen[old as usize] || p.to_new(old) != new {
                return false;
            }
            seen[old as usize] = true;
        }
        seen.iter().all(|&s| s)
    }

    /// Edge multiset in original ids, sorted — the isomorphism invariant.
    fn canonical_edges(g: &CsrGraph, p: &Permutation) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::with_capacity(g.num_edges());
        for u in 0..g.num_vertices() as u32 {
            let (dsts, ws) = g.out_edges(u);
            for (&v, &w) in dsts.iter().zip(ws) {
                out.push((p.to_old(u), p.to_old(v), w.to_bits()));
            }
        }
        out.sort_unstable();
        out
    }

    #[test]
    fn every_kind_is_an_isomorphism() {
        let g = chain_with_hub();
        let id = Permutation::identity(g.num_vertices());
        let want = canonical_edges(&g, &id);
        for kind in [Reorder::None, Reorder::Degree, Reorder::Rcm] {
            let (rg, p) = reorder(&g, kind);
            assert!(is_permutation(&p, g.num_vertices()), "{kind:?}");
            assert_eq!(rg.num_vertices(), g.num_vertices());
            assert_eq!(rg.num_edges(), g.num_edges());
            assert_eq!(canonical_edges(&rg, &p), want, "{kind:?}");
            assert_eq!(rg.out_degree(p.to_new(4)), g.out_degree(4), "{kind:?}");
        }
    }

    #[test]
    fn none_is_identity() {
        let g = chain_with_hub();
        let (rg, p) = reorder(&g, Reorder::None);
        assert_eq!(rg.row_offsets, g.row_offsets);
        assert_eq!(rg.col_idx, g.col_idx);
        for v in 0..5 {
            assert_eq!(p.to_new(v), v);
        }
    }

    #[test]
    fn degree_puts_hub_first() {
        let g = chain_with_hub();
        let (rg, p) = reorder(&g, Reorder::Degree);
        assert_eq!(p.to_new(4), 0, "hub gets new id 0");
        assert_eq!(rg.out_degree(0), 4);
        let degs: Vec<u64> =
            (0..rg.num_vertices() as u32).map(|v| rg.out_degree(v)).collect();
        assert!(degs.windows(2).all(|w| w[0] >= w[1]), "{degs:?}");
    }

    #[test]
    fn rcm_covers_disconnected_components() {
        // Two components: an isolated pair {5,6} plus the chain+hub.
        let mut el = EdgeList::new(7);
        for v in 0..3u32 {
            el.push(v, v + 1, 1.0);
            el.push(v + 1, v, 1.0);
        }
        for v in 0..4u32 {
            el.push(4, v, 1.0);
        }
        el.push(5, 6, 1.0);
        el.push(6, 5, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        let (_, p) = reorder(&g, Reorder::Rcm);
        assert!(is_permutation(&p, 7));
    }

    #[test]
    fn labels_round_trip_through_permutation() {
        let g = chain_with_hub();
        let (_, p) = reorder(&g, Reorder::Degree);
        // Label each renamed vertex with its original id; mapping back must
        // give out[old] = old.
        let new_labels: Vec<f32> =
            (0..5u32).map(|new| p.to_old(new) as f32).collect();
        let mut out = Vec::new();
        p.labels_to_original(&new_labels, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reorder_is_deterministic() {
        let g = chain_with_hub();
        for kind in [Reorder::Degree, Reorder::Rcm] {
            let (a, pa) = reorder(&g, kind);
            let (b, pb) = reorder(&g, kind);
            assert_eq!(a.col_idx, b.col_idx);
            assert_eq!(pa.order, pb.order);
        }
    }

    #[test]
    fn parse_and_names_agree() {
        for &name in REORDER_NAMES {
            assert_eq!(Reorder::parse(name).unwrap().name(), name);
        }
        assert!(Reorder::parse("bogus").is_none());
        assert_eq!(Reorder::default(), Reorder::None);
    }
}
