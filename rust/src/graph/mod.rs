//! Graph substrate: COO/CSR representations, generators (R-MAT, road,
//! power-law), property extraction (Table 1), binary I/O, and the
//! deterministic RNG every stochastic component shares.

pub mod coo;
pub mod csr;
pub mod disk;
pub mod gen;
pub mod inputs;
pub mod io;
pub mod props;
pub mod reorder;
pub mod rng;

pub use coo::{Edge, EdgeList};
pub use csr::CsrGraph;
pub use props::GraphProps;
pub use rng::Rng;
