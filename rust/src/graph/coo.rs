//! Edge-list (COO) representation — the generators' output format and the
//! input to the CSR builder. Kept separate from CSR because the paper's
//! discussion (§3.1) of edge-based balancing hinges on the COO-vs-CSR space
//! trade-off: COO stores both endpoints per edge, CSR does not.

use super::rng::Rng;

/// One directed, weighted edge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: u32,
    pub dst: u32,
    pub weight: f32,
}

/// A graph as a bag of directed edges plus a vertex-count bound.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    pub num_vertices: u32,
    pub edges: Vec<Edge>,
}

impl EdgeList {
    pub fn new(num_vertices: u32) -> Self {
        EdgeList { num_vertices, edges: Vec::new() }
    }

    pub fn push(&mut self, src: u32, dst: u32, weight: f32) {
        debug_assert!(src < self.num_vertices && dst < self.num_vertices);
        self.edges.push(Edge { src, dst, weight });
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Add the reverse of every edge (used to build undirected inputs like
    /// the orkut analogue). Weights are preserved.
    pub fn symmetrize(&mut self) {
        let fwd = self.edges.clone();
        self.edges.reserve(fwd.len());
        for e in fwd {
            self.edges.push(Edge { src: e.dst, dst: e.src, weight: e.weight });
        }
    }

    /// Remove duplicate (src, dst) pairs, keeping the smallest weight.
    /// Self-loops are kept iff `keep_self_loops`.
    pub fn dedup(&mut self, keep_self_loops: bool) {
        self.edges.retain(|e| keep_self_loops || e.src != e.dst);
        self.edges.sort_unstable_by_key(|e| ((e.src as u64) << 32) | e.dst as u64);
        self.edges.dedup_by(|a, b| {
            if a.src == b.src && a.dst == b.dst {
                b.weight = b.weight.min(a.weight);
                true
            } else {
                false
            }
        });
    }

    /// Assign uniform-random integer weights in `[1, max_w]` (the standard
    /// sssp workload prep; bfs ignores weights, cc uses 0-cost propagation).
    pub fn randomize_weights(&mut self, max_w: u32, rng: &mut Rng) {
        for e in &mut self.edges {
            e.weight = (1 + rng.gen_range(max_w as u64)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> EdgeList {
        let mut el = EdgeList::new(4);
        el.push(0, 1, 1.0);
        el.push(0, 2, 2.0);
        el.push(2, 3, 3.0);
        el
    }

    #[test]
    fn push_and_count() {
        let el = tiny();
        assert_eq!(el.num_edges(), 3);
        assert_eq!(el.edges[1], Edge { src: 0, dst: 2, weight: 2.0 });
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let mut el = tiny();
        el.symmetrize();
        assert_eq!(el.num_edges(), 6);
        assert!(el.edges.iter().any(|e| e.src == 1 && e.dst == 0));
        assert!(el.edges.iter().any(|e| e.src == 3 && e.dst == 2));
    }

    #[test]
    fn dedup_keeps_min_weight() {
        let mut el = EdgeList::new(3);
        el.push(0, 1, 5.0);
        el.push(0, 1, 2.0);
        el.push(1, 1, 1.0); // self loop
        el.dedup(false);
        assert_eq!(el.num_edges(), 1);
        assert_eq!(el.edges[0].weight, 2.0);
    }

    #[test]
    fn dedup_can_keep_self_loops() {
        let mut el = EdgeList::new(2);
        el.push(1, 1, 1.0);
        el.dedup(true);
        assert_eq!(el.num_edges(), 1);
    }

    #[test]
    fn randomize_weights_in_range() {
        let mut el = tiny();
        let mut rng = Rng::new(3);
        el.randomize_weights(8, &mut rng);
        for e in &el.edges {
            assert!((1.0..=8.0).contains(&e.weight));
            assert_eq!(e.weight.fract(), 0.0);
        }
    }
}
