//! Binary graph serialization (`.albg`) so generated inputs can be shared
//! across runs and benches without regeneration.
//!
//! Format (little-endian): magic `ALBG` + u32 version, u64 n, u64 m,
//! `(n+1) x u64` row offsets, `m x u32` column indices, `m x f32` weights.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::csr::CsrGraph;

const MAGIC: &[u8; 4] = b"ALBG";
const VERSION: u32 = 1;

/// Write a CSR graph (out-edges only; CSC is rebuilt on load when needed).
pub fn save(g: &CsrGraph, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    let n = (g.row_offsets.len() - 1) as u64;
    let m = g.col_idx.len() as u64;
    w.write_all(&n.to_le_bytes())?;
    w.write_all(&m.to_le_bytes())?;
    for &o in &g.row_offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &c in &g.col_idx {
        w.write_all(&c.to_le_bytes())?;
    }
    for &x in &g.weights {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()
}

/// Load a `.albg` file.
pub fn load(path: &Path) -> io::Result<CsrGraph> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported version {version}"),
        ));
    }
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let mut row_offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        row_offsets.push(read_u64(&mut r)?);
    }
    if row_offsets.last().copied() != Some(m as u64) {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "offset/m mismatch"));
    }
    let mut col_idx = Vec::with_capacity(m);
    for _ in 0..m {
        col_idx.push(read_u32(&mut r)?);
    }
    let mut weights = Vec::with_capacity(m);
    for _ in 0..m {
        weights.push(f32::from_le_bytes(read4(&mut r)?));
    }
    Ok(CsrGraph { row_offsets, col_idx, weights, csc: None })
}

fn read4<R: Read>(r: &mut R) -> io::Result<[u8; 4]> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(b)
}

fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    Ok(u32::from_le_bytes(read4(r)?))
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::EdgeList;
    use crate::graph::gen::rmat::{self, RmatConfig};

    /// Unique temp path that cleans itself up on drop (no tempfile crate in
    /// the vendored set).
    struct TmpPath(std::path::PathBuf);
    impl TmpPath {
        fn new(tag: &str) -> Self {
            let p = std::env::temp_dir().join(format!(
                "albg-test-{tag}-{}-{:?}",
                std::process::id(),
                std::thread::current().id()
            ));
            TmpPath(p)
        }
        fn path(&self) -> &Path {
            &self.0
        }
    }
    impl Drop for TmpPath {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    #[test]
    fn roundtrip_small() {
        let mut el = EdgeList::new(3);
        el.push(0, 1, 1.5);
        el.push(1, 2, 2.5);
        let g = CsrGraph::from_edge_list(&el);
        let tmp = TmpPath::new("small");
        save(&g, tmp.path()).unwrap();
        let g2 = load(tmp.path()).unwrap();
        assert_eq!(g.row_offsets, g2.row_offsets);
        assert_eq!(g.col_idx, g2.col_idx);
        assert_eq!(g.weights, g2.weights);
    }

    #[test]
    fn roundtrip_rmat() {
        let el = rmat::generate(&RmatConfig::paper(8, 1));
        let g = CsrGraph::from_edge_list(&el);
        let tmp = TmpPath::new("rmat");
        save(&g, tmp.path()).unwrap();
        let g2 = load(tmp.path()).unwrap();
        assert_eq!(g.col_idx, g2.col_idx);
        assert_eq!(g.weights, g2.weights);
    }

    #[test]
    fn rejects_bad_magic() {
        let tmp = TmpPath::new("magic");
        std::fs::write(tmp.path(), b"NOPE0000000000000000").unwrap();
        assert!(load(tmp.path()).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut el = EdgeList::new(3);
        el.push(0, 1, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        let tmp = TmpPath::new("trunc");
        save(&g, tmp.path()).unwrap();
        let bytes = std::fs::read(tmp.path()).unwrap();
        std::fs::write(tmp.path(), &bytes[..bytes.len() - 3]).unwrap();
        assert!(load(tmp.path()).is_err());
    }
}
