//! Named input presets — the paper's Table 1 graphs, reproduced at
//! simulation scale.
//!
//! The paper's inputs are up to 3.7 B edges on 16 data-center GPUs; this
//! repository targets a laptop-scale simulator, so each preset reproduces its
//! paper counterpart's *regime* (the properties the evaluation actually
//! depends on) rather than its absolute size:
//!
//! | preset      | paper input | regime preserved                                     |
//! |-------------|-------------|------------------------------------------------------|
//! | `rmat18`    | rmat23      | out-hub >> THRESHOLD, in-degree flat, E/V = 16       |
//! | `rmat20`    | rmat25      | same, 4x larger                                      |
//! | `orkut-s`   | orkut       | power-law but max Dout < THRESHOLD, high E/V, sym.   |
//! | `road-s`    | road-USA    | max deg <= 9, E/V ~ 2.4, huge diameter               |
//! | `rmat21`    | rmat26      | multi-GPU scale hub graph                            |
//! | `rmat22`    | rmat27      | same, 2x larger                                      |
//! | `twitter-s` | twitter40   | directed power-law, hub >> THRESHOLD                 |
//! | `uk-s`      | uk2007      | high E/V, max Dout just *below* THRESHOLD            |
//!
//! `--scale-delta` on the CLI shifts every preset up or down in lockstep.

use super::coo::EdgeList;
use super::csr::CsrGraph;
use super::gen::{powerlaw, rmat, road};

/// All preset names, in Table 1 order.
pub const ALL_INPUTS: [&str; 8] = [
    "rmat18", "rmat20", "orkut-s", "road-s", "rmat21", "rmat22", "twitter-s",
    "uk-s",
];

/// Opt-in oversize presets: accepted by [`generate`]/[`build`] and by
/// `--inputs` filters, but *not* part of [`ALL_INPUTS`] — they are far too
/// large for the full campaign matrix (rmat24 at delta 0 is 1 M vertices /
/// 16.7 M edges) and exist for the disk-CSR cache path (`--graph-cache`)
/// and scaling studies.
pub const EXTRA_INPUTS: [&str; 1] = ["rmat24"];

/// Every preset name accepted by [`generate`]/[`build`] — [`ALL_INPUTS`]
/// plus the opt-in [`EXTRA_INPUTS`] — joined for error messages that name
/// the valid set (the C001 lint rule).
pub fn preset_names() -> String {
    let mut names: Vec<&str> = ALL_INPUTS.to_vec();
    names.extend(EXTRA_INPUTS);
    names.join(", ")
}

/// Single-host (Momentum / Table 2) inputs.
pub const SINGLE_HOST_INPUTS: [&str; 4] = ["rmat18", "rmat20", "orkut-s", "road-s"];

/// Multi-host (Bridges / Fig 10) inputs.
pub const MULTI_HOST_INPUTS: [&str; 4] = ["rmat21", "rmat22", "twitter-s", "uk-s"];

/// Presets whose hubs exceed THRESHOLD so the ALB inspector actually
/// fires — the regime the paper targets (Fig. 1), the inputs CI's
/// `adaptive-gate` sweeps, and the scope of the adaptive-dominance
/// campaign invariant. `orkut-s`, `road-s`, and `uk-s` are deliberately
/// excluded: their max degree sits below THRESHOLD, so adaptive-vs-static
/// there is a tie the invariant must not over-constrain.
pub const HIGH_IMBALANCE_INPUTS: [&str; 5] =
    ["rmat18", "rmat20", "rmat21", "rmat22", "twitter-s"];

/// The paper input each preset stands in for.
pub fn paper_name(preset: &str) -> &'static str {
    match preset {
        "rmat18" => "rmat23",
        "rmat20" => "rmat25",
        "orkut-s" => "orkut",
        "road-s" => "road-USA",
        "rmat21" => "rmat26",
        "rmat22" => "rmat27",
        "rmat24" => "rmat29",
        "twitter-s" => "twitter40",
        "uk-s" => "uk2007",
        _ => "?",
    }
}

/// Generate a preset input. `scale_delta` shifts the size exponent
/// (+1 ~= 2x vertices); `seed` keys the generator streams.
pub fn generate(name: &str, scale_delta: i32, seed: u64) -> Option<EdgeList> {
    let sc = |base: u32| (base as i64 + scale_delta as i64).max(6) as u32;
    let nv = |base: u32| {
        let shifted = (base as i64) << scale_delta.max(0);
        (shifted >> (-scale_delta).max(0)).max(1 << 6) as u32
    };
    let el = match name {
        "rmat18" => rmat::generate(&rmat::RmatConfig::paper(sc(14), seed)),
        "rmat20" => rmat::generate(&rmat::RmatConfig::paper(sc(16), seed ^ 1)),
        "rmat21" => rmat::generate(&rmat::RmatConfig::paper(sc(17), seed ^ 2)),
        "rmat22" => rmat::generate(&rmat::RmatConfig::paper(sc(18), seed ^ 3)),
        "rmat24" => rmat::generate(&rmat::RmatConfig::paper(sc(20), seed ^ 8)),
        "orkut-s" => powerlaw::generate(&powerlaw::PowerLawConfig {
            num_vertices: nv(40_000),
            avg_degree: 60,
            alpha: 2.2,
            max_degree: 900, // below THRESHOLD: ALB must stay dormant
            symmetric: true,
            max_weight: 100,
            seed: seed ^ 4,
        }),
        "road-s" => road::generate(&road::RoadConfig::paper(
            1 << sc(8).min(12),
            seed ^ 5,
        )),
        "twitter-s" => powerlaw::generate(&powerlaw::PowerLawConfig {
            num_vertices: nv(120_000),
            avg_degree: 35,
            alpha: 1.9,
            max_degree: 60_000, // hub >> THRESHOLD: ALB triggers
            symmetric: false,
            max_weight: 100,
            seed: seed ^ 6,
        }),
        "uk-s" => powerlaw::generate(&powerlaw::PowerLawConfig {
            num_vertices: nv(100_000),
            avg_degree: 35,
            alpha: 2.1,
            max_degree: 600, // paper: max Dout < launched threads
            symmetric: false,
            max_weight: 100,
            seed: seed ^ 7,
        }),
        _ => return None,
    };
    Some(el)
}

/// Generate + build CSR in one step.
pub fn build(name: &str, scale_delta: i32, seed: u64) -> Option<CsrGraph> {
    generate(name, scale_delta, seed).map(|el| CsrGraph::from_edge_list(&el))
}

/// The paper's bfs/sssp source policy: highest out-degree vertex, except
/// road networks where it is vertex 0 (§5).
pub fn source_vertex(name: &str, g: &CsrGraph) -> u32 {
    if name.starts_with("road") {
        0
    } else {
        g.max_out_degree_vertex()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_generate() {
        for name in ALL_INPUTS {
            let el = generate(name, -4, 1).unwrap_or_else(|| panic!("{name}"));
            assert!(el.num_edges() > 0, "{name} empty");
        }
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(generate("nope", 0, 1).is_none());
    }

    #[test]
    fn road_source_is_zero() {
        let g = build("road-s", -4, 1).unwrap();
        assert_eq!(source_vertex("road-s", &g), 0);
    }

    #[test]
    fn rmat_source_is_hub() {
        let g = build("rmat18", -4, 1).unwrap();
        let s = source_vertex("rmat18", &g);
        assert_eq!(g.out_degree(s), (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap());
    }

    #[test]
    fn orkut_hub_below_threshold_regime() {
        let g = build("orkut-s", 0, 1).unwrap();
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_d < 1024, "orkut-s hub {max_d} must stay under THRESHOLD");
    }

    #[test]
    fn rmat_hub_above_threshold_regime() {
        let g = build("rmat18", 0, 1).unwrap();
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(max_d >= 1024, "rmat18 hub {max_d} must exceed THRESHOLD");
    }

    #[test]
    fn rmat22_hub_above_threshold_at_bench_scale() {
        // The hotpath bench's sim-par-rmat22 case runs this preset at
        // delta 0 / seed 7 and needs the hub to cross the sim-default
        // THRESHOLD (3072 launched threads) so the LB kernel — the
        // parallelized block/warp walk (DESIGN.md §9) — actually launches
        // where the speedup is measured.
        let g = build("rmat22", 0, 7).unwrap();
        let max_d = (0..g.num_vertices() as u32).map(|v| g.out_degree(v)).max().unwrap();
        assert!(
            max_d >= 3072,
            "rmat22 hub {max_d} must exceed the sim-default THRESHOLD"
        );
    }

    #[test]
    fn scale_delta_changes_size() {
        let small = generate("rmat18", -4, 1).unwrap();
        let big = generate("rmat18", -2, 1).unwrap();
        assert!(big.num_vertices > small.num_vertices);
    }

    #[test]
    fn paper_names_complete() {
        for name in ALL_INPUTS {
            assert_ne!(paper_name(name), "?");
        }
        for name in EXTRA_INPUTS {
            assert_ne!(paper_name(name), "?");
        }
    }

    #[test]
    fn extra_presets_generate_but_stay_out_of_the_matrix() {
        for name in EXTRA_INPUTS {
            assert!(generate(name, -6, 1).unwrap().num_edges() > 0, "{name}");
            assert!(!ALL_INPUTS.contains(&name), "{name} must stay opt-in");
        }
    }

    #[test]
    fn rmat24_counts_and_hub_pinned() {
        // The sc>=20 regime the u64 generator guards exist for: exact
        // vertex/edge counts at delta 0, and a hub that clears the
        // sim-default THRESHOLD (3072 launched threads).
        let el = generate("rmat24", 0, 1).unwrap();
        assert_eq!(el.num_vertices, 1 << 20);
        assert_eq!(el.num_edges(), 16 << 20);
        let mut deg = vec![0u32; el.num_vertices as usize];
        for e in &el.edges {
            deg[e.src as usize] += 1;
        }
        let hub = deg.iter().copied().max().unwrap() as u64;
        assert!(hub >= 3072, "rmat24 hub {hub} must exceed THRESHOLD");
    }
}
