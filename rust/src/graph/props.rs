//! Graph property extraction — everything Table 1 reports.


use super::csr::CsrGraph;

/// The Table 1 row for one input.
#[derive(Debug, Clone)]
pub struct GraphProps {
    pub num_vertices: u64,
    pub num_edges: u64,
    pub avg_degree: f64,
    pub max_dout: u64,
    pub max_din: u64,
    pub approx_diameter: u32,
    pub size_bytes: u64,
}

/// Compute all properties. Builds the CSC view if absent (needed for
/// max Din and for treating the graph as undirected in the diameter sweep).
pub fn compute(g: &mut CsrGraph) -> GraphProps {
    g.build_csc();
    let n = g.num_vertices() as u64;
    let m = g.num_edges() as u64;
    let max_dout = (0..n as u32).map(|v| g.out_degree(v)).max().unwrap_or(0);
    let max_din = (0..n as u32).map(|v| g.in_degree(v)).max().unwrap_or(0);
    GraphProps {
        num_vertices: n,
        num_edges: m,
        avg_degree: if n > 0 { m as f64 / n as f64 } else { 0.0 },
        max_dout,
        max_din,
        approx_diameter: approx_diameter(g),
        size_bytes: g.size_bytes(),
    }
}

/// Approximate (unweighted, undirected) diameter by the classic double-sweep
/// lower bound: BFS from an arbitrary vertex, then BFS again from the
/// farthest vertex found. Uses out+in edges so directed inputs behave like
/// their underlying undirected topology (matches how diameters are usually
/// quoted for web/social graphs).
pub fn approx_diameter(g: &CsrGraph) -> u32 {
    let n = g.num_vertices();
    if n == 0 {
        return 0;
    }
    let (far, _) = bfs_ecc(g, 0);
    let (_, ecc) = bfs_ecc(g, far);
    ecc
}

/// BFS over the undirected closure; returns (farthest vertex, eccentricity).
fn bfs_ecc(g: &CsrGraph, src: u32) -> (u32, u32) {
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    dist[src as usize] = 0;
    queue.push_back(src);
    let (mut far, mut ecc) = (src, 0);
    while let Some(v) = queue.pop_front() {
        let d = dist[v as usize];
        if d > ecc {
            ecc = d;
            far = v;
        }
        let (outs, _) = g.out_edges(v);
        let (ins, _) = g.in_edges(v);
        for &u in outs.iter().chain(ins) {
            if dist[u as usize] == u32::MAX {
                dist[u as usize] = d + 1;
                queue.push_back(u);
            }
        }
    }
    (far, ecc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::EdgeList;

    fn path(n: u32) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for i in 0..n - 1 {
            el.push(i, i + 1, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn path_diameter_exact() {
        let mut g = path(10);
        g.build_csc();
        assert_eq!(approx_diameter(&g), 9);
    }

    #[test]
    fn star_properties() {
        let mut el = EdgeList::new(6);
        for i in 1..6 {
            el.push(0, i, 1.0);
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let p = compute(&mut g);
        assert_eq!(p.max_dout, 5);
        assert_eq!(p.max_din, 1);
        assert_eq!(p.approx_diameter, 2);
        assert_eq!(p.num_edges, 5);
    }

    #[test]
    fn diameter_uses_undirected_closure() {
        // Directed path 0->1->2: reachable both ways via in-edges.
        let mut g = path(3);
        g.build_csc();
        assert_eq!(approx_diameter(&g), 2);
    }

    #[test]
    fn avg_degree_computed() {
        let mut g = path(5);
        let p = compute(&mut g);
        assert!((p.avg_degree - 0.8).abs() < 1e-9);
    }

    #[test]
    fn empty_graph_props() {
        let el = EdgeList::new(1);
        let mut g = CsrGraph::from_edge_list(&el);
        let p = compute(&mut g);
        assert_eq!(p.num_edges, 0);
        assert_eq!(p.approx_diameter, 0);
    }

    #[test]
    fn road_like_regime_matches_table1() {
        use crate::graph::gen::road;
        let el = road::generate(&road::RoadConfig::paper(64, 1));
        let mut g = CsrGraph::from_edge_list(&el);
        let p = compute(&mut g);
        assert!(p.max_dout <= 9);
        // Long diameter relative to vertex count is the road signature.
        assert!(p.approx_diameter >= 64, "diameter {}", p.approx_diameter);
    }
}
