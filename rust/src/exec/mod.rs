//! The shared intra-process execution substrate (DESIGN.md §9).
//!
//! [`Pool`] is a persistent worker pool — std-only, consistent with the §7
//! offline policy — that the round engine and the multi-GPU coordinator use
//! to parallelize the *simulation itself*: the kernel simulator's block and
//! warp walks split into fixed contiguous chunks and run as pool tasks
//! ([`crate::gpu::sim`]), the ALB inspector's threshold probe pass splits the
//! active set the same way ([`crate::lb::alb`]), and the BSP superstep
//! dispatches whole per-GPU rounds onto the *same* pool
//! ([`crate::comm::bsp::superstep`]) so a multi-GPU run never oversubscribes
//! the host with nested spawning.
//!
//! Design points:
//!
//! * **Caller participation.** [`Pool::run`] enqueues a job and then claims
//!   task indices itself alongside the workers, so a pool of `t` threads is
//!   the caller plus `t - 1` spawned workers and `Pool::new(1)` spawns
//!   nothing — `--sim-threads 1` is bit-for-bit the historical sequential
//!   walk on the calling thread.
//! * **Reentrancy.** A task may itself call [`Pool::run`] on the same pool
//!   (a per-GPU BSP task parallelizing its kernel simulation): the nested
//!   job is pushed onto the shared queue, the nesting caller participates in
//!   its own job, and idle workers help — no nested spawning, no deadlock
//!   (leaf tasks always complete).
//! * **Determinism is the callers' contract, made easy.** Tasks write to
//!   per-chunk slots and callers fold the slots in chunk order after `run`
//!   returns, so results are bit-identical for *any* worker count and any
//!   scheduling (asserted across `sim_threads ∈ {1, 2, 4, 7}` by
//!   `rust/tests/parity.rs`).
//! * **Steady-state zero allocation** (§8): `run` keeps the job on the
//!   caller's stack and the queue reuses its capacity, so a warmed round
//!   loop performs no heap allocation on the submitting thread
//!   (`rust/tests/alloc.rs`).
//!
//! Safety: the queue stores raw pointers to stack-owned [`Job`]s with a
//! lifetime-erased task closure. The protocol that keeps this sound is
//! documented on [`Pool::run`]; the short version is that `run` cannot
//! return (or unwind) before every claimed task has finished and the job has
//! been deregistered under the queue lock.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Upper bound on pool lanes. Beyond this, thread-spawn cost and scheduler
/// churn can only hurt a simulation whose chunk count is bounded by blocks
/// and sampled warps — and a typo'd huge `--sim-threads` value must fail at
/// parse time, not abort mid-run when an OS thread spawn fails.
pub const MAX_THREADS: usize = 512;

/// A persistent worker pool; see the module docs.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Workers sleep here when no job has claimable tasks.
    work: Condvar,
    /// Submitters sleep here while workers drain their job's last tasks.
    done: Condvar,
}

struct State {
    /// Jobs with (possibly) unclaimed task indices, in submission order.
    /// Exhausted entries are pruned lazily by workers and eagerly by the
    /// submitter before [`Pool::run`] returns.
    jobs: Vec<JobPtr>,
    shutdown: bool,
}

/// Pointer to a [`Job`] living on some submitter's stack. Only dereferenced
/// while that submitter is blocked inside [`Pool::run`] (see the liveness
/// protocol there).
#[derive(Clone, Copy, PartialEq, Eq)]
struct JobPtr(*const Job);

// SAFETY: the pointee is kept alive by the Pool::run protocol; the pointer
// itself is just an address.
unsafe impl Send for JobPtr {}

/// One `Pool::run` invocation: `n` tasks dispatched through a lifetime-
/// erased closure, plus claim/completion accounting.
struct Job {
    /// The task body. Valid until `pending` reaches zero (the submitter
    /// owns the closure and cannot leave `run` earlier).
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    /// Next unclaimed task index; values `>= n` mean exhausted.
    next: AtomicUsize,
    /// Claimed-or-unclaimed tasks not yet finished. `run` returns only
    /// after this hits zero.
    pending: AtomicUsize,
    /// Set when a worker's task panicked (the submitter re-raises).
    panicked: AtomicBool,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Pool {
    /// A pool of `threads` total execution lanes: the calling thread plus
    /// `threads - 1` spawned workers, clamped to `1..=`[`MAX_THREADS`].
    /// `Pool::new(1)` (and `new(0)`) spawns nothing and every
    /// [`run`](Self::run) executes inline.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.clamp(1, MAX_THREADS);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { jobs: Vec::new(), shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|wi| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("alb-exec-{wi}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn exec::Pool worker")
            })
            .collect();
        Pool { shared, workers, threads }
    }

    /// Total execution lanes (caller + spawned workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute tasks `f(0) .. f(n-1)` to completion, in parallel with the
    /// caller participating. Returns (or unwinds) only after every task has
    /// finished, so `f` may borrow locals and write to per-task slots the
    /// caller reads afterwards.
    ///
    /// # Liveness / safety protocol
    ///
    /// The job lives on this stack frame and the queue holds a raw pointer
    /// to it, so the following invariants keep workers' dereferences valid:
    ///
    /// 1. A worker discovers the job and claims a task index under the
    ///    queue lock; the submitter deregisters the job under the same lock,
    ///    *after* `pending` reached zero — so a job found in the queue is
    ///    alive for the duration of the claim.
    /// 2. A claimed-but-unfinished task keeps `pending > 0`, which keeps
    ///    the submitter blocked (job alive) until the worker's completion
    ///    decrement — the worker's last touch of the job.
    /// 3. On unwind (a panicking task), the drop guard performs the same
    ///    claim-drain + wait + deregister before the frame dies.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.workers.is_empty() || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // SAFETY: the transmute erases the borrow's lifetime so the closure
        // can sit in the queue as a raw pointer. Sound per the liveness
        // protocol above (invariants 1-3): the job is deregistered under
        // the queue lock before this frame — and thus `f` — dies, so no
        // worker dereference outlives the borrow.
        let f_ptr: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(f)
        };
        let job = Job {
            f: f_ptr,
            n,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.push(JobPtr(&job));
            self.shared.work.notify_all();
        }
        {
            // Drains, waits, and deregisters on scope exit — normal or
            // unwinding (invariant 3).
            let _guard = JobGuard { shared: &self.shared, job: &job };
            loop {
                let i = job.next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // Decrements `pending` even if f unwinds, so the guard's
                // wait cannot deadlock on our own in-flight task.
                let _p = PendingGuard { shared: &self.shared, job: &job };
                f(i);
            }
        }
        if job.panicked.load(Ordering::Acquire) {
            panic!("exec::Pool worker task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Decrement one finished (or abandoned) task; wake the submitter on zero.
fn finish_one(shared: &Shared, job: &Job) {
    if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Lock the state mutex so the wake cannot slip between the
        // submitter's pending check and its condvar wait.
        let _st = shared.state.lock().unwrap();
        shared.done.notify_all();
    }
}

/// Completion guard for one task execution on the submitting thread.
struct PendingGuard<'a> {
    shared: &'a Shared,
    job: &'a Job,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        finish_one(self.shared, self.job);
    }
}

/// End-of-job guard: claims (without running) any tasks left unclaimed,
/// waits for workers' in-flight tasks, and deregisters the job — on both
/// the normal and the unwinding exit path of [`Pool::run`].
struct JobGuard<'a> {
    shared: &'a Shared,
    job: &'a Job,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        // On the normal path every index is already claimed and this loop
        // exits immediately; on unwind it abandons the remainder so
        // `pending` can drain.
        loop {
            let i = self.job.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.job.n {
                break;
            }
            finish_one(self.shared, self.job);
        }
        let mut st = self.shared.state.lock().unwrap();
        while self.job.pending.load(Ordering::Acquire) > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        let addr: *const Job = self.job;
        st.jobs.retain(|&p| p != JobPtr(addr));
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // Find a claimable task under the state lock (see Pool::run's
        // invariant 1), pruning exhausted jobs along the way.
        let claimed: Option<(JobPtr, usize)> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let mut found: Option<(JobPtr, usize)> = None;
                st.jobs.retain(|&ptr| {
                    let JobPtr(p) = ptr;
                    // SAFETY: the job is still registered, so its
                    // submitter is blocked in Pool::run (invariant 1).
                    let job = unsafe { &*p };
                    if found.is_none() {
                        let i = job.next.fetch_add(1, Ordering::Relaxed);
                        if i < job.n {
                            found = Some((ptr, i));
                            return i + 1 < job.n;
                        }
                        false
                    } else {
                        job.next.load(Ordering::Relaxed) < job.n
                    }
                });
                if found.is_some() {
                    break found;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        if let Some((JobPtr(p), i)) = claimed {
            // SAFETY: task `i`'s pending slot is not yet released, so the
            // submitter is still blocked and the job + closure are alive
            // (invariant 2).
            let job = unsafe { &*p };
            // SAFETY: the same invariant 2 covers the closure pointer: it
            // was erased from a borrow that `Pool::run` keeps alive until
            // `pending` drains, which cannot happen before this task's
            // completion decrement below.
            let f = unsafe { &*job.f };
            if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                job.panicked.store(true, Ordering::Release);
            }
            finish_one(shared, job);
        }
    }
}

/// Default pool width: the `ALB_SIM_THREADS` environment override when set
/// to a positive integer (the CI sequential-reference leg exports `1`),
/// otherwise the host's available parallelism. Clamped to [`MAX_THREADS`].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ALB_SIM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(MAX_THREADS);
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// Parse a `--sim-threads` CLI value. `None` (flag absent) resolves to
/// [`default_threads`]; `0`, values above [`MAX_THREADS`], and non-numbers
/// are errors that name the valid range, so `alb run --sim-threads 0` (or
/// a typo'd `10000000`) fails loudly instead of silently misconfiguring
/// the pool or aborting mid-run on thread-spawn failure.
pub fn parse_threads(arg: Option<&str>) -> Result<usize, String> {
    match arg {
        None => Ok(default_threads()),
        Some(s) => match s.parse::<usize>() {
            Ok(0) => Err(format!(
                "--sim-threads 0 is invalid: need an integer in \
                 1..={MAX_THREADS} (1 = the sequential reference walk; \
                 default = available parallelism, or the ALB_SIM_THREADS \
                 env override)"
            )),
            Ok(v) if v > MAX_THREADS => Err(format!(
                "--sim-threads {v} is too large: need an integer in \
                 1..={MAX_THREADS} (the simulation's chunk count is bounded \
                 by blocks and sampled warps — more lanes cannot help)"
            )),
            Ok(v) => Ok(v),
            Err(_) => Err(format!(
                "--sim-threads '{s}' is not a number: need an integer in \
                 1..={MAX_THREADS} (1 = the sequential reference walk; \
                 default = available parallelism, or the ALB_SIM_THREADS \
                 env override)"
            )),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;
    use std::thread::ThreadId;
    use std::time::Duration;

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let order = Mutex::new(Vec::new());
        let me = std::thread::current().id();
        let tid = Mutex::new(None::<ThreadId>);
        pool.run(5, &|i| {
            order.lock().unwrap().push(i);
            *tid.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
        assert_eq!(tid.lock().unwrap().unwrap(), me);
    }

    #[test]
    fn every_index_runs_exactly_once() {
        let pool = Pool::new(4);
        let n = 257;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        for _ in 0..10 {
            pool.run(n, &|i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 10, "index {i}");
        }
    }

    #[test]
    fn run_is_a_barrier() {
        let pool = Pool::new(3);
        let done = AtomicUsize::new(0);
        pool.run(16, &|_| {
            std::thread::sleep(Duration::from_millis(1));
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn workers_actually_join_the_job() {
        let pool = Pool::new(4);
        let ids = Mutex::new(HashSet::new());
        pool.run(64, &|_| {
            std::thread::sleep(Duration::from_millis(2));
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        let ids = ids.lock().unwrap();
        assert!(ids.len() >= 2, "expected >= 2 threads, saw {}", ids.len());
    }

    #[test]
    fn nested_run_on_the_same_pool_completes() {
        // A task calling Pool::run on its own pool (the coordinator's
        // per-GPU rounds parallelizing their kernel simulation) must not
        // deadlock or lose tasks.
        let pool = Pool::new(3);
        let leaf = AtomicUsize::new(0);
        pool.run(3, &|_| {
            pool.run(5, &|_| {
                leaf.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(leaf.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = Pool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the submitter");
        // The pool stays usable afterwards.
        let ok = AtomicUsize::new(0);
        pool.run(4, &|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        let pool = Pool::new(2);
        pool.run(0, &|_| panic!("must not run"));
    }

    #[test]
    fn parse_threads_accepts_positive_integers() {
        assert_eq!(parse_threads(Some("1")), Ok(1));
        assert_eq!(parse_threads(Some("7")), Ok(7));
        assert!(parse_threads(None).unwrap() >= 1);
    }

    #[test]
    fn parse_threads_rejects_zero_garbage_and_huge_with_guidance() {
        let e = parse_threads(Some("0")).unwrap_err();
        assert!(e.contains("1..=512"), "{e}");
        assert!(e.contains("--sim-threads 0"), "{e}");
        let e = parse_threads(Some("many")).unwrap_err();
        assert!(e.contains("many"), "{e}");
        assert!(e.contains("1..=512"), "{e}");
        let e = parse_threads(Some("10000000")).unwrap_err();
        assert!(e.contains("too large"), "{e}");
        assert!(e.contains("1..=512"), "{e}");
        assert_eq!(parse_threads(Some("512")), Ok(MAX_THREADS));
    }

    #[test]
    fn pool_width_is_clamped() {
        let p = Pool::new(0);
        assert_eq!(p.threads(), 1);
        assert!(default_threads() <= MAX_THREADS);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    // ---------------------------------------------- race-freedom stress
    //
    // The claim protocol (`job.next.fetch_add`) must hand every index to
    // exactly one lane under contention, across pool widths, nesting,
    // and mid-job panics. These hammer the schedule rather than mock it:
    // many short rounds maximize overlap between submission, stealing,
    // and teardown.

    #[test]
    fn stress_exactly_once_across_worker_counts() {
        for threads in [2usize, 4, 7] {
            let pool = Pool::new(threads);
            let n = 331usize;
            let rounds = 20u64;
            let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            for r in 1..=rounds {
                pool.run(n, &|i| {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                });
                // `run` is a barrier, so per-round totals are exact —
                // a lost or double-claimed job shows up immediately.
                let total: u64 = counts.iter().map(|c| c.load(Ordering::Relaxed)).sum();
                assert_eq!(total, r * n as u64, "threads={threads} round={r}");
            }
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), rounds, "threads={threads} index={i}");
            }
        }
    }

    #[test]
    fn stress_nested_submit_no_lost_or_double_claims() {
        for threads in [2usize, 4, 7] {
            let pool = Pool::new(threads);
            let outer = 7usize;
            let inner = 23usize;
            let grid: Vec<AtomicU64> =
                (0..outer * inner).map(|_| AtomicU64::new(0)).collect();
            pool.run(outer, &|o| {
                pool.run(inner, &|i| {
                    grid[o * inner + i].fetch_add(1, Ordering::Relaxed);
                });
            });
            for (k, c) in grid.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "threads={threads} cell={k}");
            }
        }
    }

    #[test]
    fn stress_panic_mid_job_keeps_claims_exact() {
        for threads in [2usize, 4, 7] {
            let pool = Pool::new(threads);
            let n = 64usize;
            let ran: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.run(n, &|i| {
                    ran[i].fetch_add(1, Ordering::Relaxed);
                    if i == 5 {
                        panic!("mid-job failure");
                    }
                });
            }));
            assert!(r.is_err(), "panic must reach the submitter (threads={threads})");
            assert_eq!(ran[5].load(Ordering::Relaxed), 1, "threads={threads}");
            for (i, c) in ran.iter().enumerate() {
                assert!(
                    c.load(Ordering::Relaxed) <= 1,
                    "double claim at index {i} (threads={threads})"
                );
            }
            // The pool stays usable afterwards, with exact counts.
            let again: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|i| {
                again[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                again.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                "pool lost exactness after a panic (threads={threads})"
            );
        }
    }

    #[test]
    fn forced_overlap_submitter_and_workers_share_one_job() {
        // Pin an overlap window: the lane that claims index 0 spins until
        // some other lane finishes the last index, proving lanes drain
        // one job concurrently. The spin is bounded, and with >= 2
        // executors the remaining indices always get claimed, so this
        // cannot deadlock.
        let pool = Pool::new(4);
        let n = 8usize;
        let ran: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let last_done = AtomicUsize::new(0);
        pool.run(n, &|i| {
            if i == n - 1 {
                last_done.store(1, Ordering::SeqCst);
            } else if i == 0 {
                let mut spins = 0u32;
                while last_done.load(Ordering::SeqCst) == 0 && spins < 5_000_000 {
                    std::thread::yield_now();
                    spins += 1;
                }
            }
            ran[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(ran.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }
}
