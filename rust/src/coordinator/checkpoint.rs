//! Round checkpoints for fault-tolerant distributed runs (ISSUE 8).
//!
//! Every `k` rounds the faulty coordinator snapshots the *global* view of
//! the run — per-partition master labels reassembled into one global array,
//! plus the frontier / iteration state the app needs to resume — under a
//! monotonically increasing **consistency epoch**. The snapshot is taken at
//! the BSP barrier after broadcast, where every copy of every vertex equals
//! its master value, so restoring master labels restores every local copy
//! exactly no matter how the survivors are re-partitioned.
//!
//! Checkpoints live in memory (recovery never touches the disk on the hot
//! path); `--checkpoint-dir` additionally persists each epoch as an
//! `.albk` file with the same discipline as the `.albc` graph cache
//! ([`crate::graph::disk`]): little-endian payload, trailing FNV-1a
//! checksum, atomic temp-file + rename writes, validation before trust.
//!
//! Format:
//!
//! ```text
//! magic "ALBK" | u32 version | u32 aux tag (0 push, 1 kcore)
//! u64 epoch | u64 round | u64 n_labels | u64 n_frontier
//! [tag 1: u64 n_deg | u64 n_alive | u64 n_dying]
//! payload arrays (labels as f32 bits, alive as bytes)
//! u64 FNV-1a checksum over every header+payload byte after the magic
//! ```

use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

use crate::comm::fault::fnv64;

const MAGIC: &[u8; 4] = b"ALBK";
const VERSION: u32 = 1;

/// App-specific resume state carried alongside labels and frontier.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckpointAux {
    /// Push apps (bfs / sssp / cc): labels + frontier are the whole state.
    Push,
    /// K-core's central peeling state: in-degrees, liveness, and the dying
    /// list entering the checkpointed round. All three are global (owned by
    /// the coordinator, not the partitions), which is what makes k-core
    /// recovery exact under any survivor re-partitioning.
    Kcore {
        deg: Vec<u32>,
        alive: Vec<bool>,
        dying: Vec<u32>,
    },
}

/// One consistent snapshot of a distributed run.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Consistency epoch: 0 is the implicit initial-state checkpoint taken
    /// before round 0; every later snapshot increments it.
    pub epoch: u64,
    /// Logical round the snapshot resumes at (rounds `0..round` are done).
    pub round: u64,
    /// Global master labels after round `round - 1` (or initial values).
    pub labels: Vec<f32>,
    /// Sorted global ids active entering round `round` (push apps; k-core
    /// keeps its dying list in [`CheckpointAux::Kcore`] instead).
    pub frontier: Vec<u32>,
    pub aux: CheckpointAux,
}

impl Checkpoint {
    /// In-memory snapshot size in bytes — what `checkpoint_bytes`
    /// accumulates per snapshot in `DistRunResult`.
    pub fn bytes(&self) -> u64 {
        let aux = match &self.aux {
            CheckpointAux::Push => 0,
            CheckpointAux::Kcore { deg, alive, dying } => {
                (deg.len() * 4 + alive.len() + dying.len() * 4) as u64
            }
        };
        16 + (self.labels.len() * 4 + self.frontier.len() * 4) as u64 + aux
    }

    /// The on-disk file name of this epoch under a checkpoint directory.
    pub fn entry_path(dir: &Path, epoch: u64) -> PathBuf {
        dir.join(format!("epoch-{epoch:06}.v{VERSION}.albk"))
    }

    /// Serialize header (post-magic) + payload into one buffer — the byte
    /// range the trailing checksum covers.
    fn body(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&VERSION.to_le_bytes());
        let tag: u32 = match self.aux {
            CheckpointAux::Push => 0,
            CheckpointAux::Kcore { .. } => 1,
        };
        b.extend_from_slice(&tag.to_le_bytes());
        b.extend_from_slice(&self.epoch.to_le_bytes());
        b.extend_from_slice(&self.round.to_le_bytes());
        b.extend_from_slice(&(self.labels.len() as u64).to_le_bytes());
        b.extend_from_slice(&(self.frontier.len() as u64).to_le_bytes());
        if let CheckpointAux::Kcore { deg, alive, dying } = &self.aux {
            b.extend_from_slice(&(deg.len() as u64).to_le_bytes());
            b.extend_from_slice(&(alive.len() as u64).to_le_bytes());
            b.extend_from_slice(&(dying.len() as u64).to_le_bytes());
        }
        for x in &self.labels {
            b.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        for x in &self.frontier {
            b.extend_from_slice(&x.to_le_bytes());
        }
        if let CheckpointAux::Kcore { deg, alive, dying } = &self.aux {
            for x in deg {
                b.extend_from_slice(&x.to_le_bytes());
            }
            for &a in alive {
                b.push(a as u8);
            }
            for x in dying {
                b.extend_from_slice(&x.to_le_bytes());
            }
        }
        b
    }

    /// Write atomically (temp file + rename), trailing checksum last.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        let body = self.body();
        let mut w = File::create(&tmp)?;
        w.write_all(MAGIC)?;
        w.write_all(&body)?;
        w.write_all(&fnv64(&body).to_le_bytes())?;
        w.flush()?;
        drop(w);
        fs::rename(&tmp, path)
    }

    /// Load and validate: magic, version, tag, plausible sizes, checksum.
    pub fn load(path: &Path) -> io::Result<Checkpoint> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        if bytes.len() < 4 + 8 || &bytes[..4] != MAGIC {
            return Err(bad("bad magic"));
        }
        let body = &bytes[4..bytes.len() - 8];
        let stored = u64::from_le_bytes(
            bytes[bytes.len() - 8..].try_into().expect("8-byte trailer"),
        );
        if stored != fnv64(body) {
            return Err(bad("checksum mismatch"));
        }
        let mut cur = Cursor { b: body, at: 0 };
        let version = cur.u32()?;
        if version != VERSION {
            return Err(bad(&format!("unsupported version {version}")));
        }
        let tag = cur.u32()?;
        let epoch = cur.u64()?;
        let round = cur.u64()?;
        let n_labels = cur.u64()? as usize;
        let n_frontier = cur.u64()? as usize;
        if n_labels > (1 << 33) || n_frontier > (1 << 33) {
            return Err(bad("implausible header sizes"));
        }
        let aux_sizes = if tag == 1 {
            let nd = cur.u64()? as usize;
            let na = cur.u64()? as usize;
            let ny = cur.u64()? as usize;
            if nd > (1 << 33) || na > (1 << 33) || ny > (1 << 33) {
                return Err(bad("implausible aux sizes"));
            }
            Some((nd, na, ny))
        } else if tag == 0 {
            None
        } else {
            return Err(bad(&format!("unknown aux tag {tag}")));
        };
        let labels: Vec<f32> =
            (0..n_labels).map(|_| cur.u32().map(f32::from_bits)).collect::<io::Result<_>>()?;
        let frontier: Vec<u32> =
            (0..n_frontier).map(|_| cur.u32()).collect::<io::Result<_>>()?;
        let aux = match aux_sizes {
            None => CheckpointAux::Push,
            Some((nd, na, ny)) => {
                let deg: Vec<u32> =
                    (0..nd).map(|_| cur.u32()).collect::<io::Result<_>>()?;
                let mut alive = Vec::with_capacity(na);
                for _ in 0..na {
                    alive.push(cur.u8()? != 0);
                }
                let dying: Vec<u32> =
                    (0..ny).map(|_| cur.u32()).collect::<io::Result<_>>()?;
                CheckpointAux::Kcore { deg, alive, dying }
            }
        };
        if cur.at != body.len() {
            return Err(bad("trailing bytes after payload"));
        }
        Ok(Checkpoint { epoch, round, labels, frontier, aux })
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Bounds-checked little-endian reader over the body slice.
struct Cursor<'a> {
    b: &'a [u8],
    at: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> io::Result<&[u8]> {
        if self.at + n > self.b.len() {
            return Err(bad("truncated payload"));
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TmpDir(PathBuf);
    impl TmpDir {
        fn new(tag: &str) -> Self {
            let p = std::env::temp_dir().join(format!(
                "albk-test-{tag}-{}-{:?}",
                std::process::id(),
                std::thread::current().id()
            ));
            let _ = fs::remove_dir_all(&p);
            fs::create_dir_all(&p).unwrap();
            TmpDir(p)
        }
        fn path(&self) -> &Path {
            &self.0
        }
    }
    impl Drop for TmpDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn push_ckpt() -> Checkpoint {
        Checkpoint {
            epoch: 3,
            round: 12,
            labels: vec![0.0, 1.5, f32::INFINITY, -0.0, 7.25],
            frontier: vec![1, 3, 4],
            aux: CheckpointAux::Push,
        }
    }

    fn kcore_ckpt() -> Checkpoint {
        Checkpoint {
            epoch: 1,
            round: 4,
            labels: vec![1.0, 0.0, 1.0],
            frontier: Vec::new(),
            aux: CheckpointAux::Kcore {
                deg: vec![5, 0, 9],
                alive: vec![true, false, true],
                dying: vec![2],
            },
        }
    }

    #[test]
    fn roundtrip_is_bit_exact_for_both_aux_kinds() {
        let tmp = TmpDir::new("rt");
        for (name, ck) in [("p", push_ckpt()), ("k", kcore_ckpt())] {
            let path = tmp.path().join(format!("{name}.albk"));
            ck.save(&path).unwrap();
            let got = Checkpoint::load(&path).unwrap();
            // PartialEq on f32 misses NaN/-0.0 bit identity; compare bits.
            let bits =
                |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got.labels), bits(&ck.labels));
            assert_eq!(got, ck);
        }
    }

    #[test]
    fn every_truncation_fails_validation() {
        let tmp = TmpDir::new("trunc");
        let path = tmp.path().join("t.albk");
        kcore_ckpt().save(&path).unwrap();
        let bytes = fs::read(&path).unwrap();
        for len in 0..bytes.len() {
            fs::write(&path, &bytes[..len]).unwrap();
            assert!(
                Checkpoint::load(&path).is_err(),
                "truncation at {len}/{} must be detected",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_bit_flip_fails_validation() {
        let tmp = TmpDir::new("flip");
        let path = tmp.path().join("f.albk");
        push_ckpt().save(&path).unwrap();
        let bytes = fs::read(&path).unwrap();
        for i in 0..bytes.len() {
            let mut m = bytes.clone();
            m[i] ^= 0x40;
            fs::write(&path, &m).unwrap();
            assert!(
                Checkpoint::load(&path).is_err(),
                "bit flip at byte {i} must be detected"
            );
        }
    }

    #[test]
    fn epoch_paths_are_distinct_and_versioned() {
        let dir = Path::new("/tmp/ck");
        let a = Checkpoint::entry_path(dir, 1);
        let b = Checkpoint::entry_path(dir, 2);
        assert_ne!(a, b);
        assert!(a.to_str().unwrap().contains(".albk"));
    }

    #[test]
    fn bytes_reflect_payload_size() {
        let p = push_ckpt();
        assert_eq!(p.bytes(), 16 + 5 * 4 + 3 * 4);
        let k = kcore_ckpt();
        assert_eq!(k.bytes(), 16 + 3 * 4 + 3 * 4 + 3 + 1 * 4);
    }
}
