//! The distributed multi-GPU coordinator.
//!
//! Drives the bulk-synchronous execution the paper's multi-GPU evaluation
//! (§6.2–6.3) uses: every round, each simulated GPU runs its local kernels
//! on its partition (in parallel, one OS thread per GPU), then the
//! Gluon-style sync ([`crate::comm`]) reconciles boundary vertices. Round
//! time = slowest GPU's compute + non-overlapping communication — exactly
//! the accounting behind Figures 6/7/10/11. Intra-GPU thread-block imbalance
//! on *one* GPU therefore stalls the whole machine, which is why ALB's
//! per-GPU fix shows up at cluster scale.

use anyhow::{anyhow, Result};

use crate::apps::engine::{self, ComputeMode, EngineConfig};
use crate::apps::worklist::NextWorklist;
use crate::apps::{pr, App, INF};
use crate::comm::{NetworkModel, BYTES_PER_UPDATE};
use crate::gpu::Simulator;
use crate::graph::CsrGraph;
use crate::lb::Direction;
use crate::partition::{partition, DistGraph, Policy};
use crate::runtime::PjrtRuntime;

/// Cluster-level configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub num_gpus: u32,
    pub policy: Policy,
    pub net: NetworkModel,
}

impl ClusterConfig {
    /// Momentum-like single host with `k` GPUs, CVC partitioning (§5).
    pub fn single_host(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::single_host(),
        }
    }

    /// Bridges-like cluster: 2 GPUs per host.
    pub fn bridges(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::cluster(2),
        }
    }
}

/// One BSP round's record.
#[derive(Debug, Clone)]
pub struct DistRoundRecord {
    pub round: u32,
    /// Global active count entering the round.
    pub active: u64,
    /// Slowest GPU's compute cycles.
    pub comp_cycles: u64,
    /// Communication cycles (non-overlapping).
    pub comm_cycles: u64,
    pub comm_bytes: u64,
    /// GPUs whose LB kernel launched this round.
    pub lb_gpus: u32,
}

/// A completed distributed run.
#[derive(Debug, Clone)]
pub struct DistRunResult {
    pub app: App,
    /// Reconciled per-global-vertex labels (master values).
    pub labels: Vec<f32>,
    pub rounds: Vec<DistRoundRecord>,
    pub total_cycles: u64,
    pub comp_cycles: u64,
    pub comm_cycles: u64,
    /// Per-GPU total compute cycles (for balance reporting).
    pub per_gpu_comp: Vec<u64>,
}

impl DistRunResult {
    pub fn ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.total_cycles)
    }

    pub fn comp_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comp_cycles)
    }

    pub fn comm_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comm_cycles)
    }
}

/// Run `app` on `g` across `cluster.num_gpus` simulated GPUs.
pub fn run_distributed(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<DistRunResult> {
    if cfg.compute == ComputeMode::Pjrt && pjrt.is_none() {
        return Err(anyhow!("compute=Pjrt requires a loaded PjrtRuntime"));
    }
    let dg = partition(g, cluster.num_gpus, cluster.policy);
    match app {
        App::Bfs | App::Sssp | App::Cc => {
            run_push_dist(app, g, &dg, source, cfg, cluster, pjrt)
        }
        App::Pr => run_pr_dist(g, &dg, cfg, cluster, pjrt),
        App::Kcore => run_kcore_dist(g, &dg, cfg, cluster),
    }
}

// -------------------------------------------------------------------- push

/// Output of one partition's local compute round.
struct LocalRound {
    cycles: u64,
    #[allow(dead_code)] // recorded for debugging / future per-GPU reports
    edges: u64,
    lb: bool,
    /// Changed (local id, new value) pairs.
    changed: Vec<(u32, f32)>,
}

fn local_push_round(
    app: App,
    part: &CsrGraph,
    active: &[u32],
    labels: &mut [f32],
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<LocalRound> {
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let n = part.num_vertices();
    let scan = cfg.worklist.scan_cost(n as u64, active.len() as u64);
    let sched = cfg.balancer.schedule(active, part, Direction::Push, &cfg.spec, scan);
    let simr = sim.simulate(&sched, true);

    let mut next = NextWorklist::new(n);
    if let (ComputeMode::Pjrt, Some(rt), Some(lb)) = (cfg.compute, pjrt, &sched.lb) {
        engine::relax_huge_pjrt(rt, part, &lb.vertices, app, labels, &mut next)?;
        for item in &sched.twc {
            engine::relax_native(part, app, item.vertex, labels, &mut next);
        }
    } else {
        for &v in active {
            engine::relax_native(part, app, v, labels, &mut next);
        }
    }
    let changed = next
        .take_sorted()
        .into_iter()
        .map(|l| (l, labels[l as usize]))
        .collect();
    Ok(LocalRound {
        cycles: simr.total_cycles,
        edges: sched.total_edges(),
        lb: sched.lb.is_some(),
        changed,
    })
}

fn run_push_dist(
    app: App,
    g: &CsrGraph,
    dg: &DistGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    // Reconciled master state.
    let mut master: Vec<f32> = match app {
        App::Cc => (0..n).map(|v| v as f32).collect(),
        _ => {
            let mut m = vec![INF; n];
            m[source as usize] = 0.0;
            m
        }
    };
    // Per-partition local labels + active sets.
    let mut labels: Vec<Vec<f32>> = dg
        .parts
        .iter()
        .map(|p| p.l2g.iter().map(|&gid| master[gid as usize]).collect())
        .collect();
    let mut active: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| match app {
            App::Cc => (0..p.graph.num_vertices() as u32).collect(),
            _ => dg.g2l[p.id as usize].get(&source).map(|&l| vec![l]).unwrap_or_default(),
        })
        .collect();

    let mut rounds = Vec::new();
    let (mut total, mut comp_total, mut comm_total) = (0u64, 0u64, 0u64);
    let mut per_gpu_comp = vec![0u64; k];

    for round in 0..cfg.max_rounds {
        let global_active: u64 = active.iter().map(|a| a.len() as u64).sum();
        if global_active == 0 {
            break;
        }
        // --- parallel local compute ---
        let results: Vec<LocalRound> = if pjrt.is_some() {
            // PJRT client is not Sync: partitions run sequentially.
            let mut out = Vec::with_capacity(k);
            for (pi, part) in dg.parts.iter().enumerate() {
                out.push(local_push_round(
                    app, &part.graph, &active[pi], &mut labels[pi], cfg, pjrt,
                )?);
            }
            out
        } else {
            let mut out: Vec<Option<LocalRound>> = (0..k).map(|_| None).collect();
            std::thread::scope(|s| {
                for ((part, act, lab), slot) in dg
                    .parts
                    .iter()
                    .zip(&active)
                    .zip(labels.iter_mut())
                    .map(|((p, a), l)| (p, a, l))
                    .zip(out.iter_mut())
                {
                    s.spawn(move || {
                        *slot = Some(
                            local_push_round(app, &part.graph, act, lab, cfg, None)
                                .expect("native round cannot fail"),
                        );
                    });
                }
            });
            out.into_iter().map(|o| o.unwrap()).collect()
        };

        let comp = results.iter().map(|r| r.cycles).max().unwrap_or(0);
        for (pi, r) in results.iter().enumerate() {
            per_gpu_comp[pi] += r.cycles;
        }
        let lb_gpus = results.iter().filter(|r| r.lb).count() as u32;

        // --- Gluon sync: reduce (min to master) ---
        let mut bytes = 0u64;
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut touched: Vec<u32> = Vec::new();
        for (pi, r) in results.iter().enumerate() {
            let part = &dg.parts[pi];
            let mut to_owner = vec![0u64; k];
            for &(l, val) in &r.changed {
                let gid = part.l2g[l as usize];
                let owner = dg.owner[gid as usize] as usize;
                if val < master[gid as usize] {
                    master[gid as usize] = val;
                }
                touched.push(gid);
                if owner != pi {
                    to_owner[owner] += BYTES_PER_UPDATE;
                }
            }
            for (o, b) in to_owner.iter().enumerate() {
                if *b > 0 {
                    flows.push((pi as u32, o as u32, *b));
                    bytes += *b;
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();

        // --- broadcast (master to every stale copy) + activation ---
        let mut bcast = vec![0u64; k * k];
        let mut next_active: Vec<Vec<u32>> = vec![Vec::new(); k];
        for &gid in &touched {
            let owner = dg.owner[gid as usize] as usize;
            let val = master[gid as usize];
            for pi in 0..k {
                if let Some(&l) = dg.g2l[pi].get(&gid) {
                    if val < labels[pi][l as usize] {
                        labels[pi][l as usize] = val;
                        if owner != pi {
                            bcast[owner * k + pi] += BYTES_PER_UPDATE;
                        }
                    }
                    // A copy whose value just changed (here or locally) is
                    // active next round if it has out-edges to relax.
                    if labels[pi][l as usize] <= val
                        && (labels[pi][l as usize] - val).abs() < f32::EPSILON
                        && dg.parts[pi].graph.out_degree(l) > 0
                    {
                        next_active[pi].push(l);
                    }
                }
            }
        }
        for o in 0..k {
            for pi in 0..k {
                let b = bcast[o * k + pi];
                if b > 0 {
                    flows.push((o as u32, pi as u32, b));
                    bytes += b;
                }
            }
        }
        for a in next_active.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        active = next_active;

        let comm = cluster.net.round_cycles(&flows);
        total += comp + comm;
        comp_total += comp;
        comm_total += comm;
        rounds.push(DistRoundRecord {
            round,
            active: global_active,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
    }
    Ok(DistRunResult {
        app,
        labels: master,
        rounds,
        total_cycles: total,
        comp_cycles: comp_total,
        comm_cycles: comm_total,
        per_gpu_comp,
    })
}

// ---------------------------------------------------------------------- pr

fn run_pr_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    let out_deg: Vec<u32> = (0..n as u32).map(|v| g.out_degree(v) as u32).collect();
    let mut ranks = pr::init_ranks(n);
    // Local CSC views for the pull traversal.
    let mut parts: Vec<CsrGraph> = dg.parts.iter().map(|p| p.graph.clone()).collect();
    for p in parts.iter_mut() {
        p.build_csc();
    }
    let base = (1.0 - pr::DAMPING) / n as f32;

    let mut rounds = Vec::new();
    let (mut total, mut comp_total, mut comm_total) = (0u64, 0u64, 0u64);
    let mut per_gpu_comp = vec![0u64; k];

    for round in 0..cfg.max_rounds {
        // Broadcast: every mirror refreshes its rank copy (topology-driven:
        // all ranks move every round).
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut bytes = 0u64;
        for (pi, p) in dg.parts.iter().enumerate() {
            let b = p.num_mirrors() as u64 * BYTES_PER_UPDATE;
            if b > 0 {
                // All owners collectively feed this partition; attribute to
                // the heaviest link pattern by splitting evenly.
                flows.push((((pi + 1) % k) as u32, pi as u32, b));
                bytes += b;
            }
        }

        // Local compute: per-partition contribution gather.
        let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut acc_global = vec![0f32; n];
        for (pi, p) in dg.parts.iter().enumerate() {
            let lg = &parts[pi];
            let nl = lg.num_vertices();
            let all: Vec<u32> = (0..nl as u32).collect();
            let scan = cfg.worklist.scan_cost(nl as u64, nl as u64);
            let sched = cfg.balancer.schedule(&all, lg, Direction::Pull, &cfg.spec, scan);
            let simr = sim.simulate(&sched, false);
            comp = comp.max(simr.total_cycles);
            per_gpu_comp[pi] += simr.total_cycles;
            lb_gpus += sched.lb.is_some() as u32;

            // Contributions of local src copies (kernel in Pjrt mode).
            let src_ranks: Vec<f32> =
                p.l2g.iter().map(|&gid| ranks[gid as usize]).collect();
            let src_degs: Vec<u32> =
                p.l2g.iter().map(|&gid| out_deg[gid as usize]).collect();
            let contrib: Vec<f32> = match (cfg.compute, pjrt) {
                (ComputeMode::Pjrt, Some(rt)) => {
                    let mut c = Vec::with_capacity(nl);
                    let tile = 16_384.min(nl.max(1));
                    for start in (0..nl).step_by(tile) {
                        let end = (start + tile).min(nl);
                        c.extend(rt.pr_pull(
                            &src_ranks[start..end],
                            &src_degs[start..end],
                            pr::DAMPING,
                        )?);
                    }
                    c
                }
                _ => src_ranks
                    .iter()
                    .zip(&src_degs)
                    .map(|(&r, &d)| pr::DAMPING * r / d.max(1) as f32)
                    .collect(),
            };
            // Pull along local in-edges; accumulate into the dst's global
            // slot (reduce-add of the partial sums).
            for lv in 0..nl as u32 {
                let (srcs, _) = lg.in_edges(lv);
                if srcs.is_empty() {
                    continue;
                }
                let mut acc = 0f32;
                for &lu in srcs {
                    acc += contrib[lu as usize];
                }
                let gid = p.l2g[lv as usize];
                acc_global[gid as usize] += acc;
                // Partial sums on non-owner partitions travel to the master.
                if dg.owner[gid as usize] as usize != pi {
                    bytes += BYTES_PER_UPDATE;
                }
            }
        }
        // The reduce traffic: approximate per-partition aggregate flow.
        if k > 1 {
            flows.push((1, 0, bytes / k as u64));
        }

        let mut delta = 0f32;
        for v in 0..n {
            let new_rank = base + acc_global[v];
            delta = delta.max((new_rank - ranks[v]).abs());
            ranks[v] = new_rank;
        }

        let comm = cluster.net.round_cycles(&flows);
        total += comp + comm;
        comp_total += comp;
        comm_total += comm;
        rounds.push(DistRoundRecord {
            round,
            active: n as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
        if delta < cfg.pr_tol {
            break;
        }
    }
    Ok(DistRunResult {
        app: App::Pr,
        labels: ranks,
        rounds,
        total_cycles: total,
        comp_cycles: comp_total,
        comm_cycles: comm_total,
        per_gpu_comp,
    })
}

// ------------------------------------------------------------------- kcore

fn run_kcore_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k_parts = dg.num_parts();
    let k = cfg.kcore_k;
    let mut g2 = g.clone();
    g2.build_csc();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g2.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];
    let parts: Vec<CsrGraph> = dg.parts.iter().map(|p| p.graph.clone()).collect();
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());

    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| (deg[v as usize]) < k).collect();
    for &v in &dying {
        alive[v as usize] = false;
    }

    let mut rounds = Vec::new();
    let (mut total, mut comp_total, mut comm_total) = (0u64, 0u64, 0u64);
    let mut per_gpu_comp = vec![0u64; k_parts];
    let mut round = 0u32;

    while !dying.is_empty() && round < cfg.max_rounds {
        // Per-partition: local copies of dying vertices drive in-edge scans.
        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut decr = vec![0u32; n];
        let mut bytes = 0u64;
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        for (pi, _p) in dg.parts.iter().enumerate() {
            let lg = &parts[pi];
            let local_dying: Vec<u32> = dying
                .iter()
                .filter_map(|&gv| dg.g2l[pi].get(&gv).copied())
                .collect();
            if local_dying.is_empty() {
                continue;
            }
            let scan = cfg
                .worklist
                .scan_cost(lg.num_vertices() as u64, local_dying.len() as u64);
            let sched =
                cfg.balancer.schedule(&local_dying, lg, Direction::Push, &cfg.spec, scan);
            let simr = sim.simulate(&sched, true);
            comp = comp.max(simr.total_cycles);
            per_gpu_comp[pi] += simr.total_cycles;
            lb_gpus += sched.lb.is_some() as u32;

            let mut remote = 0u64;
            for &lv in &local_dying {
                let (dsts, _) = lg.out_edges(lv);
                for &lu in dsts {
                    let gid = dg.parts[pi].l2g[lu as usize];
                    if alive[gid as usize] {
                        decr[gid as usize] += 1;
                        if dg.owner[gid as usize] as usize != pi {
                            remote += BYTES_PER_UPDATE;
                        }
                    }
                }
            }
            if remote > 0 {
                flows.push((pi as u32, ((pi + 1) % k_parts) as u32, remote));
                bytes += remote;
            }
        }

        let mut next = Vec::new();
        for v in 0..n {
            if alive[v] && decr[v] > 0 {
                deg[v] -= decr[v].min(deg[v]);
                if deg[v] < k {
                    alive[v] = false;
                    next.push(v as u32);
                }
            }
        }
        let comm = cluster.net.round_cycles(&flows);
        total += comp + comm;
        comp_total += comp;
        comm_total += comm;
        rounds.push(DistRoundRecord {
            round,
            active: dying.len() as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
        dying = next;
        round += 1;
    }
    let labels = alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
    Ok(DistRunResult {
        app: App::Kcore,
        labels,
        rounds,
        total_cycles: total,
        comp_cycles: comp_total,
        comm_cycles: comm_total,
        per_gpu_comp,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{bfs, cc, kcore, sssp};
    use crate::graph::gen::rmat::{self, RmatConfig};

    fn test_graph(scale: u32, seed: u64) -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, seed)))
    }

    fn cfg() -> EngineConfig {
        EngineConfig { max_rounds: 100_000, ..EngineConfig::default() }
    }

    #[test]
    fn dist_bfs_matches_oracle_all_policies_and_sizes() {
        let g = test_graph(9, 21);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            for k in [1u32, 2, 4] {
                let cluster = ClusterConfig {
                    num_gpus: k,
                    policy,
                    net: NetworkModel::single_host(),
                };
                let r = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None)
                    .unwrap();
                assert_eq!(r.labels, want, "{policy:?} k={k}");
            }
        }
    }

    #[test]
    fn dist_sssp_matches_oracle() {
        let g = test_graph(9, 22);
        let src = g.max_out_degree_vertex();
        let want = sssp::oracle(&g, src);
        let r = run_distributed(
            App::Sssp,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_cc_matches_oracle() {
        let g = test_graph(8, 23);
        let want = cc::oracle(&g);
        let r = run_distributed(
            App::Cc,
            &g,
            0,
            &cfg(),
            &ClusterConfig::single_host(3),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_pr_matches_oracle_within_fp_tolerance() {
        let mut g = test_graph(8, 24);
        let c = EngineConfig { max_rounds: 100, ..EngineConfig::default() };
        let r = run_distributed(
            App::Pr,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = pr::oracle(&mut g, c.pr_tol, 100);
        for (a, b) in r.labels.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn dist_kcore_matches_oracle() {
        let mut g = test_graph(8, 25);
        let c = EngineConfig { kcore_k: 8, max_rounds: 100_000, ..EngineConfig::default() };
        let r = run_distributed(
            App::Kcore,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = kcore::oracle(&mut g, 8);
        let got: Vec<bool> = r.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let g = test_graph(8, 26);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(1),
            None,
        )
        .unwrap();
        assert_eq!(r.comm_cycles, 0);
        assert!(r.rounds.iter().all(|x| x.comm_bytes == 0));
    }

    #[test]
    fn multi_gpu_communicates() {
        let g = test_graph(9, 27);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert!(r.comm_cycles > 0);
        assert!(r.rounds.iter().any(|x| x.comm_bytes > 0));
    }

    #[test]
    fn cluster_comm_costs_more_than_single_host() {
        let g = test_graph(9, 28);
        let src = g.max_out_degree_vertex();
        let single = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        let cluster = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::bridges(4), None,
        )
        .unwrap();
        assert_eq!(single.labels, cluster.labels);
        assert!(cluster.comm_cycles > single.comm_cycles);
    }

    #[test]
    fn more_gpus_reduce_per_round_compute() {
        let g = test_graph(11, 29);
        let src = g.max_out_degree_vertex();
        let one = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(1), None,
        )
        .unwrap();
        let four = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert_eq!(one.labels, four.labels);
        // Compute shrinks with more GPUs (comm is extra, but this asserts
        // the partitioned work itself spreads).
        assert!(four.comp_cycles < one.comp_cycles * 2);
    }

    #[test]
    fn timing_identity_holds() {
        let g = test_graph(9, 30);
        let r = run_distributed(
            App::Bfs,
            &g,
            g.max_out_degree_vertex(),
            &cfg(),
            &ClusterConfig::single_host(2),
            None,
        )
        .unwrap();
        assert_eq!(r.total_cycles, r.comp_cycles + r.comm_cycles);
        let sum: u64 = r.rounds.iter().map(|x| x.comp_cycles + x.comm_cycles).sum();
        assert_eq!(r.total_cycles, sum);
    }
}
