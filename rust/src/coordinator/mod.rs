//! The distributed multi-GPU coordinator.
//!
//! Drives the bulk-synchronous execution the paper's multi-GPU evaluation
//! (§6.2–6.3) uses: every round, each simulated GPU runs its local kernels
//! on its partition — **concurrently, as tasks on ONE shared
//! [`crate::exec::Pool`]**, through [`crate::comm::superstep_mut`] — then
//! the superstep barrier ends the round and the Gluon-style sync runs over
//! the **precomputed mirror/master schedules**
//! ([`crate::comm::exchange::ExchangePlan`], DESIGN.md §10): reduce ships
//! only this round's changed boundary values to their masters, broadcast
//! returns updated master values to stale copies, and the same pass builds
//! next round's frontier. There is no central reconciliation array and no
//! per-round `g2l` HashMap lookup; every byte on the wire is counted from
//! the schedules and split into intra-host vs inter-host traffic by
//! [`NetworkModel::split_bytes`].
//!
//! Round time = slowest GPU's compute + non-overlapping communication —
//! exactly the accounting behind Figures 6/7/10/11. Intra-GPU thread-block
//! imbalance on *one* GPU therefore stalls the whole machine, which is why
//! ALB's per-GPU fix shows up at cluster scale.
//!
//! Determinism: per-GPU results live in per-partition state folded by
//! partition index, and the exchange walks schedules in (partition, peer,
//! position) order, so a parallel run is bit-identical to the
//! [`ExecMode::Sequential`] reference (asserted by `rust/tests/parity.rs`),
//! and the whole rebuilt sync is asserted bit-identical to the preserved
//! pre-rebuild coordinator ([`run_distributed_reference`]) across every
//! input × policy × app.
//!
//! Hot-path memory discipline (DESIGN.md §8/§10): each simulated GPU owns
//! one [`GpuPush`]-style state (exchange buffers + [`RoundScratch`] arena)
//! for the whole run; [`crate::comm::superstep_mut`] hands task `i`
//! exclusive `&mut` access to state `i` with no per-round task vector or
//! result slots, so steady-state supersteps allocate nothing on the
//! submitting thread (`rust/tests/alloc.rs`).

use std::collections::HashSet;
use std::path::PathBuf;
use std::thread::ThreadId;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::apps::engine::{self, ComputeMode, EngineConfig, RoundScratch};
use crate::apps::{pr, App, INF};
use crate::comm::exchange::{ExchangePlan, Flow, HasPartState, PartState};
use crate::comm::fault::{FaultPlan, FaultSession};
use crate::comm::{
    superstep_mut, superstep_mut_masked, NetworkModel, BYTES_PER_UPDATE,
};
use crate::exec::Pool;
use crate::gpu::Simulator;
use crate::graph::CsrGraph;
use crate::lb::Direction;
use crate::partition::{
    partition, repartition_survivors, DistGraph, Partition, Policy,
};
use crate::runtime::PjrtRuntime;

mod checkpoint;
mod reference;

pub use crate::comm::bsp::ExecMode;
pub use checkpoint::{Checkpoint, CheckpointAux};
pub use reference::run_distributed_reference;

/// Cluster-level configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub num_gpus: u32,
    pub policy: Policy,
    pub net: NetworkModel,
    /// How per-round GPU tasks execute (parallel threads vs the sequential
    /// reference). Output is identical either way.
    pub exec: ExecMode,
}

impl ClusterConfig {
    /// Thin constructor for campaign/CLI cells: `gpus_per_host = None`
    /// models one big host (every link intra-host), `Some(k)` a cluster of
    /// `k`-GPU hosts.
    pub fn new(
        num_gpus: u32,
        policy: Policy,
        gpus_per_host: Option<u32>,
        exec: ExecMode,
    ) -> Self {
        ClusterConfig {
            num_gpus,
            policy,
            net: match gpus_per_host {
                None => NetworkModel::single_host(),
                Some(k) => NetworkModel::cluster(k),
            },
            exec,
        }
    }

    /// Momentum-like single host with `k` GPUs, CVC partitioning (§5).
    pub fn single_host(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::single_host(),
            exec: ExecMode::Parallel,
        }
    }

    /// Bridges-like cluster: 2 GPUs per host.
    pub fn bridges(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::cluster(2),
            exec: ExecMode::Parallel,
        }
    }

    /// Same cluster with a different execution mode.
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }
}

/// One BSP round's record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DistRoundRecord {
    pub round: u32,
    /// Global active count entering the round.
    pub active: u64,
    /// Slowest GPU's compute cycles.
    pub comp_cycles: u64,
    /// Communication cycles (non-overlapping).
    pub comm_cycles: u64,
    /// Total bytes exchanged this round (= intra + inter).
    pub comm_bytes: u64,
    /// Bytes over intra-host (PCIe/NVLink-class) links.
    pub comm_bytes_intra: u64,
    /// Bytes over inter-host (Omni-Path-class) links.
    pub comm_bytes_inter: u64,
    /// GPUs whose LB kernel launched this round.
    pub lb_gpus: u32,
}

/// A completed distributed run.
#[derive(Debug, Clone)]
pub struct DistRunResult {
    pub app: App,
    /// Reconciled per-global-vertex labels (master values).
    pub labels: Vec<f32>,
    pub rounds: Vec<DistRoundRecord>,
    pub total_cycles: u64,
    pub comp_cycles: u64,
    pub comm_cycles: u64,
    /// Total exchanged bytes across the run (= intra + inter).
    pub comm_bytes: u64,
    /// Exchanged bytes over intra-host links.
    pub comm_bytes_intra: u64,
    /// Exchanged bytes over inter-host links.
    pub comm_bytes_inter: u64,
    /// Per-GPU total compute cycles (for balance reporting).
    pub per_gpu_comp: Vec<u64>,
    /// Per-GPU host wall-clock (ns) actually spent in local rounds —
    /// measured time alongside the modeled cycles.
    pub per_gpu_wall_ns: Vec<u64>,
    /// OS threads that executed local rounds. Under [`ExecMode::Parallel`]
    /// with a multi-lane pool this reaches >= 2 distinct ids, and may
    /// include the coordinating thread (the pool submitter participates).
    pub threads: HashSet<ThreadId>,
    /// Did the run reach its fixpoint, or did it exhaust `max_rounds`?
    pub converged: bool,
    /// GPU-death recoveries performed (ISSUE 8 fault layer; 0 without
    /// `--faults`).
    pub recoveries: u32,
    /// Logical rounds replayed after checkpoint restores.
    pub replayed_rounds: u64,
    /// Failed exchange attempts re-shipped by the guarded exchange.
    pub retry_count: u64,
    /// Total bytes snapshotted into round checkpoints (epoch 0 included).
    pub checkpoint_bytes: u64,
}

impl DistRunResult {
    pub fn ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.total_cycles)
    }

    pub fn comp_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comp_cycles)
    }

    pub fn comm_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comm_cycles)
    }

    /// Distinct OS threads that ran local compute.
    pub fn num_threads(&self) -> usize {
        self.threads.len()
    }
}

/// Mutable accounting shared by the per-app drivers.
struct RunAccounting {
    rounds: Vec<DistRoundRecord>,
    total: u64,
    comp_total: u64,
    comm_total: u64,
    bytes_total: u64,
    bytes_intra: u64,
    bytes_inter: u64,
    per_gpu_comp: Vec<u64>,
    per_gpu_wall_ns: Vec<u64>,
    threads: HashSet<ThreadId>,
    converged: bool,
    recoveries: u32,
    replayed_rounds: u64,
    retry_count: u64,
    checkpoint_bytes: u64,
}

impl RunAccounting {
    fn new(k: usize) -> Self {
        RunAccounting {
            rounds: Vec::new(),
            total: 0,
            comp_total: 0,
            comm_total: 0,
            bytes_total: 0,
            bytes_intra: 0,
            bytes_inter: 0,
            per_gpu_comp: vec![0; k],
            per_gpu_wall_ns: vec![0; k],
            threads: HashSet::new(),
            // Degenerate runs (empty graph) converge trivially; real drivers
            // overwrite this from their loop-exit condition.
            converged: true,
            recoveries: 0,
            replayed_rounds: 0,
            retry_count: 0,
            checkpoint_bytes: 0,
        }
    }

    fn record_round(&mut self, rec: DistRoundRecord) {
        self.total += rec.comp_cycles + rec.comm_cycles;
        self.comp_total += rec.comp_cycles;
        self.comm_total += rec.comm_cycles;
        self.bytes_total += rec.comm_bytes;
        self.bytes_intra += rec.comm_bytes_intra;
        self.bytes_inter += rec.comm_bytes_inter;
        self.rounds.push(rec);
    }

    fn finish(self, app: App, labels: Vec<f32>) -> DistRunResult {
        DistRunResult {
            app,
            labels,
            rounds: self.rounds,
            total_cycles: self.total,
            comp_cycles: self.comp_total,
            comm_cycles: self.comm_total,
            comm_bytes: self.bytes_total,
            comm_bytes_intra: self.bytes_intra,
            comm_bytes_inter: self.bytes_inter,
            per_gpu_comp: self.per_gpu_comp,
            per_gpu_wall_ns: self.per_gpu_wall_ns,
            threads: self.threads,
            converged: self.converged,
            recoveries: self.recoveries,
            replayed_rounds: self.replayed_rounds,
            retry_count: self.retry_count,
            checkpoint_bytes: self.checkpoint_bytes,
        }
    }

    /// Record the loop-exit condition; warn loudly on round exhaustion — a
    /// run that silently stops at `max_rounds` reads as a converged answer
    /// when it is not one.
    fn set_converged(&mut self, app: App, converged: bool, max_rounds: u32) {
        self.converged = converged;
        if !converged {
            eprintln!(
                "warning: {} exhausted --max-rounds ({max_rounds}) before \
                 converging; labels are a partial fixpoint",
                app.name()
            );
        }
    }
}

/// Plain per-round outputs of one GPU's local compute (all `Copy` — results
/// cross the barrier inside the per-GPU state, never through fresh Vecs).
#[derive(Clone, Copy)]
struct RoundOut {
    cycles: u64,
    #[allow(dead_code)] // recorded for debugging / future per-GPU reports
    edges: u64,
    lb: bool,
    /// Host wall-clock spent in this round, nanoseconds.
    wall_ns: u64,
    /// OS thread the round ran on.
    thread: ThreadId,
}

impl RoundOut {
    fn idle() -> RoundOut {
        RoundOut {
            cycles: 0,
            edges: 0,
            lb: false,
            wall_ns: 0,
            thread: std::thread::current().id(),
        }
    }
}

/// Price + split one round's flows.
fn price(net: &NetworkModel, flows: &[Flow]) -> (u64, u64, u64) {
    let (intra, inter) = net.split_bytes(flows);
    (net.round_cycles(flows), intra, inter)
}

/// Run `app` on `g` across `cluster.num_gpus` simulated GPUs.
pub fn run_distributed(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<DistRunResult> {
    if cfg.compute == ComputeMode::Pjrt && pjrt.is_none() {
        return Err(anyhow!("compute=Pjrt requires a loaded PjrtRuntime"));
    }
    let dg = partition(g, cluster.num_gpus, cluster.policy);
    if g.num_vertices() == 0 {
        // Degenerate but well-formed: no vertices, no rounds, no labels.
        return Ok(RunAccounting::new(dg.num_parts()).finish(app, Vec::new()));
    }
    // Exchange schedules are fixed at partition time (DESIGN.md §10).
    let plan = ExchangePlan::new(&dg);
    // ONE pool shared by every simulated GPU for the whole run: the
    // superstep dispatches the per-GPU round tasks onto it, and each task's
    // kernel simulation nests onto the same pool (DESIGN.md §9).
    let pool = Pool::new(cfg.sim_threads.max(1));
    match app {
        App::Bfs | App::Sssp | App::Cc => {
            run_push_dist(app, g, &dg, &plan, source, cfg, cluster, pjrt, &pool)
        }
        App::Pr => run_pr_dist(g, &dg, &plan, cfg, cluster, pjrt, &pool),
        App::Kcore => run_kcore_dist(g, &dg, &plan, cfg, cluster, &pool),
    }
}

// -------------------------------------------------------------------- push

/// Everything one simulated GPU owns across a push-app run: the exchange
/// side (labels, frontier, changed buffer, bitmasks) plus the compute
/// scratch arena and the round's plain outputs.
struct GpuPush {
    st: PartState,
    scratch: RoundScratch,
    out: RoundOut,
}

impl HasPartState for GpuPush {
    fn part_state(&mut self) -> &mut PartState {
        &mut self.st
    }
}

/// One partition's local compute round: schedule, simulate, relax, and
/// drain the changed local ids into the persistent exchange buffer.
fn local_push_round(
    app: App,
    part: &CsrGraph,
    cfg: &EngineConfig,
    sim: &Simulator,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    s: &mut GpuPush,
) -> Result<()> {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let n = part.num_vertices();
    let scan = cfg.worklist.scan_cost(n as u64, s.st.active.len() as u64);
    engine::sim_round(
        cfg,
        sim,
        part,
        Direction::Push,
        &s.st.active,
        scan,
        true,
        &s.scratch.adaptive,
        &mut s.scratch.sched,
        &mut s.scratch.sim,
        pool,
    );
    // This GPU's controller steps on its own partition's signal; the trace
    // itself is dropped (per-GPU round records carry plain outputs only).
    let _ = engine::observe_adaptive(&mut s.scratch.adaptive, &s.scratch.sched, &s.scratch.sim);

    if let (ComputeMode::Pjrt, Some(rt), Some(lb)) =
        (cfg.compute, pjrt, &s.scratch.sched.sched.lb)
    {
        engine::relax_huge_pjrt(
            rt,
            part,
            &lb.vertices,
            app,
            &mut s.st.labels,
            &mut s.scratch.next,
        )?;
        for item in &s.scratch.sched.sched.twc {
            engine::relax_native(
                part,
                app,
                item.vertex,
                &mut s.st.labels,
                &mut s.scratch.next,
            );
        }
    } else {
        for &v in &s.st.active {
            engine::relax_native(part, app, v, &mut s.st.labels, &mut s.scratch.next);
        }
    }
    // The changed local ids cross the BSP barrier through the persistent
    // per-partition buffer — no per-round payload allocation.
    s.scratch.next.take_sorted_into(&mut s.st.changed);
    s.out = RoundOut {
        cycles: s.scratch.sim.round.total_cycles,
        edges: s.scratch.sched.sched.total_edges(),
        lb: s.scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread: std::thread::current().id(),
    };
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_push_dist(
    app: App,
    g: &CsrGraph,
    dg: &DistGraph,
    plan: &ExchangePlan,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    // Initial label of every global vertex, used to seed the local copies.
    let init: Vec<f32> = match app {
        App::Cc => (0..n).map(|v| v as f32).collect(),
        _ => {
            let mut m = vec![INF; n];
            m[source as usize] = 0.0;
            m
        }
    };
    let mut gpus: Vec<GpuPush> = dg
        .parts
        .iter()
        .zip(plan.new_states())
        .map(|(p, mut st)| {
            for (l, &gid) in p.l2g.iter().enumerate() {
                st.labels[l] = init[gid as usize];
            }
            GpuPush {
                st,
                scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
                out: RoundOut::idle(),
            }
        })
        .collect();
    // Initial frontier: every local copy of the source (bfs/sssp) or all
    // locals (cc) — scattered through the plan, no g2l lookups.
    match app {
        App::Cc => {
            for (s, p) in gpus.iter_mut().zip(&dg.parts) {
                s.st.active = (0..p.graph.num_vertices() as u32).collect();
            }
        }
        _ => {
            let mut seed: Vec<Vec<u32>> = vec![Vec::new(); k];
            plan.scatter_globals(&[source], &mut seed);
            for (s, locs) in gpus.iter_mut().zip(seed) {
                s.st.active = locs;
            }
        }
    }

    let mut acct = RunAccounting::new(k);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut flows: Vec<Flow> = Vec::new();

    let mut converged = false;
    for round in 0..cfg.max_rounds {
        let global_active: u64 =
            gpus.iter().map(|s| s.st.active.len() as u64).sum();
        if global_active == 0 {
            converged = true;
            break;
        }
        // --- local compute (one pool task per GPU; the return of
        // superstep_mut is the barrier) ---
        if pjrt.is_some() {
            // The PJRT client is not Sync: partitions run sequentially.
            for (pi, s) in gpus.iter_mut().enumerate() {
                local_push_round(
                    app, &dg.parts[pi].graph, cfg, &sim, pjrt, pool, s,
                )?;
            }
        } else {
            let sim_ref = &sim;
            superstep_mut(cluster.exec, pool, &mut gpus, &|pi, s: &mut GpuPush| {
                local_push_round(
                    app, &dg.parts[pi].graph, cfg, sim_ref, None, pool, s,
                )
                .expect("native round cannot fail");
            });
        }

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        for (pi, s) in gpus.iter().enumerate() {
            comp = comp.max(s.out.cycles);
            acct.per_gpu_comp[pi] += s.out.cycles;
            acct.per_gpu_wall_ns[pi] += s.out.wall_ns;
            acct.threads.insert(s.out.thread);
            lb_gpus += s.out.lb as u32;
        }

        // --- Gluon sync over the precomputed schedules: reduce changed
        // mirrors to masters, broadcast updated masters to stale copies,
        // and build next round's frontier in the same pass ---
        flows.clear();
        plan.reduce_min(&mut gpus, &mut flows);
        plan.broadcast_min(&mut gpus, &mut flows);

        let (comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        acct.record_round(DistRoundRecord {
            round,
            active: global_active,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
    }
    // The loop may also end by draining the frontier on its very last
    // permitted round — that still counts as convergence.
    let converged =
        converged || gpus.iter().all(|s| s.st.active.is_empty());
    acct.set_converged(app, converged, cfg.max_rounds);
    // Assemble the global answer from the authoritative master values.
    let mut labels = vec![0f32; n];
    for (s, p) in gpus.iter().zip(&dg.parts) {
        for (l, &gid) in p.l2g[..p.num_masters].iter().enumerate() {
            labels[gid as usize] = s.st.labels[l];
        }
    }
    Ok(acct.finish(app, labels))
}

// ---------------------------------------------------------------------- pr

/// One simulated GPU's pagerank state: compute scratch plus the persistent
/// reduce payload (partial sums in local order) and per-peer flow counters.
struct GpuPr {
    scratch: RoundScratch,
    out: RoundOut,
    /// (global id, partial rank mass pulled into it), in local-vertex order
    /// — the reduce payload, folded by the coordinator in partition order.
    acc: Vec<(u32, f32)>,
    /// Damped contribution of each local src copy.
    contrib: Vec<f32>,
    /// Kernel input staging for Pjrt mode.
    src_ranks: Vec<f32>,
    src_degs: Vec<u32>,
    /// Per-peer count of partial sums travelling to remote masters.
    peer_updates: Vec<u64>,
}

#[allow(clippy::too_many_arguments)]
fn local_pr_round(
    part: &Partition,
    lg: &CsrGraph,
    all: &[u32],
    ranks: &[f32],
    out_deg: &[u32],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    s: &mut GpuPr,
) -> Result<()> {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let nl = lg.num_vertices();
    let scan = cfg.worklist.scan_cost(nl as u64, nl as u64);
    engine::sim_round(
        cfg,
        sim,
        lg,
        Direction::Pull,
        all,
        scan,
        false,
        &s.scratch.adaptive,
        &mut s.scratch.sched,
        &mut s.scratch.sim,
        pool,
    );
    let _ = engine::observe_adaptive(&mut s.scratch.adaptive, &s.scratch.sched, &s.scratch.sim);

    // Contributions of local src copies (kernel in Pjrt mode), into the
    // persistent buffer.
    s.contrib.clear();
    match (cfg.compute, pjrt) {
        (ComputeMode::Pjrt, Some(rt)) => {
            s.src_ranks.clear();
            s.src_degs.clear();
            for &gid in &part.l2g {
                s.src_ranks.push(ranks[gid as usize]);
                s.src_degs.push(out_deg[gid as usize]);
            }
            let tile = 16_384.min(nl.max(1));
            for start in (0..nl).step_by(tile) {
                let end = (start + tile).min(nl);
                s.contrib.extend(rt.pr_pull(
                    &s.src_ranks[start..end],
                    &s.src_degs[start..end],
                    pr::DAMPING,
                )?);
            }
        }
        _ => {
            s.contrib.extend(part.l2g.iter().map(|&gid| {
                pr::DAMPING * ranks[gid as usize]
                    / out_deg[gid as usize].max(1) as f32
            }));
        }
    }
    // Pull along local in-edges; emit per-dst partial sums in local order so
    // the coordinator's merge (partition order, then local order) reproduces
    // the sequential reference bit-for-bit.
    s.acc.clear();
    s.peer_updates.fill(0);
    for lv in 0..nl as u32 {
        let (srcs, _) = lg.in_edges(lv);
        if srcs.is_empty() {
            continue;
        }
        let mut sum = 0f32;
        for &lu in srcs {
            sum += s.contrib[lu as usize];
        }
        let gid = part.l2g[lv as usize];
        s.acc.push((gid, sum));
        // Partial sums computed on mirror copies travel to the master.
        if (lv as usize) >= part.num_masters {
            s.peer_updates[owner[gid as usize] as usize] += 1;
        }
    }
    s.out = RoundOut {
        cycles: s.scratch.sim.round.total_cycles,
        edges: s.scratch.sched.sched.total_edges(),
        lb: s.scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread: std::thread::current().id(),
    };
    Ok(())
}

fn run_pr_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    plan: &ExchangePlan,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    let out_deg: Vec<u32> = (0..n as u32).map(|v| g.out_degree(v) as u32).collect();
    let mut ranks = pr::init_ranks(n);
    // Local CSC views for the pull traversal.
    let mut parts_csc: Vec<CsrGraph> =
        dg.parts.iter().map(|p| p.graph.clone()).collect();
    for p in parts_csc.iter_mut() {
        p.build_csc();
    }
    let base = (1.0 - pr::DAMPING) / n as f32;

    let mut acct = RunAccounting::new(k);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut gpus: Vec<GpuPr> = dg
        .parts
        .iter()
        .map(|p| GpuPr {
            scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
            out: RoundOut::idle(),
            acc: Vec::new(),
            contrib: Vec::new(),
            src_ranks: Vec::new(),
            src_degs: Vec::new(),
            peer_updates: vec![0; k],
        })
        .collect();
    // Topology-driven: every local vertex is active every round.
    let alls: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| (0..p.graph.num_vertices() as u32).collect())
        .collect();
    let mut acc_global = vec![0f32; n];
    let mut flows: Vec<Flow> = Vec::new();
    let mut converged = false;

    for round in 0..cfg.max_rounds {
        // Topology-driven broadcast: every mirror refreshes its rank copy
        // from its owner — the per-pair volumes are schedule constants.
        flows.clear();
        plan.mirror_refresh_flows(&mut flows);

        // Local compute: per-partition contribution gather; the return of
        // superstep_mut barriers before the reduce below.
        if pjrt.is_some() {
            for (pi, s) in gpus.iter_mut().enumerate() {
                local_pr_round(
                    &dg.parts[pi], &parts_csc[pi], &alls[pi], &ranks, &out_deg,
                    &dg.owner, cfg, &sim, pjrt, pool, s,
                )?;
            }
        } else {
            let (ranks_ref, out_deg_ref) = (&ranks, &out_deg);
            let (owner_ref, parts_ref) = (&dg.owner, &parts_csc);
            let (alls_ref, sim_ref) = (&alls, &sim);
            superstep_mut(cluster.exec, pool, &mut gpus, &|pi, s: &mut GpuPr| {
                local_pr_round(
                    &dg.parts[pi], &parts_ref[pi], &alls_ref[pi], ranks_ref,
                    out_deg_ref, owner_ref, cfg, sim_ref, None, pool, s,
                )
                .expect("native pr round cannot fail");
            });
        }

        // Reduce: fold partial sums in partition order (deterministic), and
        // price the per-pair partial-sum traffic from the counters.
        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        acc_global.fill(0.0);
        for (pi, s) in gpus.iter().enumerate() {
            comp = comp.max(s.out.cycles);
            acct.per_gpu_comp[pi] += s.out.cycles;
            acct.per_gpu_wall_ns[pi] += s.out.wall_ns;
            acct.threads.insert(s.out.thread);
            lb_gpus += s.out.lb as u32;
            for &(gid, sum) in &s.acc {
                acc_global[gid as usize] += sum;
            }
            for (peer, &cnt) in s.peer_updates.iter().enumerate() {
                if cnt > 0 {
                    flows.push((pi as u32, peer as u32, cnt * BYTES_PER_UPDATE));
                }
            }
        }

        let mut delta = 0f32;
        for v in 0..n {
            let new_rank = base + acc_global[v];
            delta = delta.max((new_rank - ranks[v]).abs());
            ranks[v] = new_rank;
        }

        let (comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        acct.record_round(DistRoundRecord {
            round,
            active: n as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        if delta < cfg.pr_tol {
            converged = true;
            break;
        }
    }
    acct.set_converged(App::Pr, converged, cfg.max_rounds);
    Ok(acct.finish(App::Pr, ranks))
}

// ------------------------------------------------------------------- kcore

/// One simulated GPU's k-core state: compute scratch plus the persistent
/// hit list (local ids of alive successors) and per-peer flow counters.
struct GpuKcore {
    scratch: RoundScratch,
    out: RoundOut,
    /// Local ids losing one in-degree (repeats = multiple dying preds).
    hits: Vec<u32>,
    /// Per-peer count of decrements travelling to remote masters.
    peer_updates: Vec<u64>,
}

#[allow(clippy::too_many_arguments)]
fn local_kcore_round(
    part: &Partition,
    dying_local: &[u32],
    alive: &[bool],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    pool: &Pool,
    s: &mut GpuKcore,
) {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let thread = std::thread::current().id();
    s.hits.clear();
    s.peer_updates.fill(0);
    if dying_local.is_empty() {
        s.out = RoundOut {
            cycles: 0,
            edges: 0,
            lb: false,
            wall_ns: t0.elapsed().as_nanos() as u64,
            thread,
        };
        return;
    }
    let lg = &part.graph;
    let scan = cfg
        .worklist
        .scan_cost(lg.num_vertices() as u64, dying_local.len() as u64);
    // atomicSub per decrement
    engine::sim_round(
        cfg,
        sim,
        lg,
        Direction::Push,
        dying_local,
        scan,
        true,
        &s.scratch.adaptive,
        &mut s.scratch.sched,
        &mut s.scratch.sim,
        pool,
    );
    let _ = engine::observe_adaptive(&mut s.scratch.adaptive, &s.scratch.sched, &s.scratch.sim);

    for &lv in dying_local {
        let (dsts, _) = lg.out_edges(lv);
        for &lu in dsts {
            let gid = part.l2g[lu as usize];
            if alive[gid as usize] {
                s.hits.push(lu);
                // Decrements of mirror copies travel to the master.
                if (lu as usize) >= part.num_masters {
                    s.peer_updates[owner[gid as usize] as usize] += 1;
                }
            }
        }
    }
    s.out = RoundOut {
        cycles: s.scratch.sim.round.total_cycles,
        edges: s.scratch.sched.sched.total_edges(),
        lb: s.scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread,
    };
}

fn run_kcore_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    plan: &ExchangePlan,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k_parts = dg.num_parts();
    let k = cfg.kcore_k;
    let mut g2 = g.clone();
    g2.build_csc();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g2.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];

    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| (deg[v as usize]) < k).collect();
    for &v in &dying {
        alive[v as usize] = false;
    }

    let mut acct = RunAccounting::new(k_parts);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut gpus: Vec<GpuKcore> = dg
        .parts
        .iter()
        .map(|p| GpuKcore {
            scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
            out: RoundOut::idle(),
            hits: Vec::new(),
            peer_updates: vec![0; k_parts],
        })
        .collect();
    let mut dying_locals: Vec<Vec<u32>> = vec![Vec::new(); k_parts];
    let mut decr = vec![0u32; n];
    let mut flows: Vec<Flow> = Vec::new();
    let mut round = 0u32;

    while !dying.is_empty() && round < cfg.max_rounds {
        // Master-side deaths propagate to every local copy through the
        // precomputed fan-out schedules (no g2l lookups), keeping each
        // partition's local dying list in global-id order.
        plan.scatter_globals(&dying, &mut dying_locals);
        {
            let (alive_ref, owner_ref) = (&alive, &dg.owner);
            let (dying_ref, sim_ref) = (&dying_locals, &sim);
            superstep_mut(cluster.exec, pool, &mut gpus, &|pi, s: &mut GpuKcore| {
                local_kcore_round(
                    &dg.parts[pi], &dying_ref[pi], alive_ref, owner_ref, cfg,
                    sim_ref, pool, s,
                );
            });
        }

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        decr.fill(0);
        flows.clear();
        for (pi, s) in gpus.iter().enumerate() {
            comp = comp.max(s.out.cycles);
            acct.per_gpu_comp[pi] += s.out.cycles;
            acct.per_gpu_wall_ns[pi] += s.out.wall_ns;
            acct.threads.insert(s.out.thread);
            lb_gpus += s.out.lb as u32;
            let l2g = &dg.parts[pi].l2g;
            for &lu in &s.hits {
                decr[l2g[lu as usize] as usize] += 1;
            }
            for (peer, &cnt) in s.peer_updates.iter().enumerate() {
                if cnt > 0 {
                    flows.push((pi as u32, peer as u32, cnt * BYTES_PER_UPDATE));
                }
            }
        }

        let mut next = Vec::new();
        for v in 0..n {
            if alive[v] && decr[v] > 0 {
                deg[v] -= decr[v].min(deg[v]);
                if deg[v] < k {
                    alive[v] = false;
                    next.push(v as u32);
                }
            }
        }
        let (comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        acct.record_round(DistRoundRecord {
            round,
            active: dying.len() as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        dying = next;
        round += 1;
    }
    acct.set_converged(App::Kcore, dying.is_empty(), cfg.max_rounds);
    let labels = alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
    Ok(acct.finish(App::Kcore, labels))
}

// --------------------------------------------- fault tolerance (ISSUE 8)

/// Fault-tolerance configuration for [`run_distributed_faulty`].
#[derive(Debug, Clone, Default)]
pub struct FaultConfig {
    /// The deterministic fault schedule (empty = no injected faults; the
    /// driver still checkpoints on cadence and verifies every exchange).
    pub plan: FaultPlan,
    /// Snapshot cadence in logical rounds; 0 keeps only the implicit
    /// initial (epoch 0) checkpoint, so a death replays the whole run.
    pub checkpoint_every: u64,
    /// Optionally persist every epoch as an `.albk` file in this directory
    /// (recovery itself always restores from the in-memory copy).
    pub checkpoint_dir: Option<PathBuf>,
}

/// Persist a checkpoint if a directory was configured.
fn persist_checkpoint(ck: &Checkpoint, faults: &FaultConfig) -> Result<()> {
    if let Some(dir) = &faults.checkpoint_dir {
        std::fs::create_dir_all(dir)?;
        ck.save(&Checkpoint::entry_path(dir, ck.epoch))?;
    }
    Ok(())
}

/// Run `app` under a deterministic fault plan with round checkpoints and
/// replay-based recovery (DESIGN.md §14).
///
/// The headline invariant — gated by `rust/tests/chaos.rs` and CI's
/// chaos-gate — is that the recovered run's final labels are bit-identical
/// to the fault-free run's, for every supported (app, input, policy, fault
/// plan) cell, with exact-deterministic recovery metrics across
/// `sim_threads`. Legality: `pr` is rejected outright (its floating-point
/// partial-sum fold is partition-layout-dependent, mirroring §13's reorder
/// exclusions) and `cc` is rejected under `gpu-death` (replay re-activates
/// whole components on the new layout); bfs/sssp/kcore support every fault
/// kind because their reductions are idempotent-min or
/// partition-invariant-sum over a central state.
pub fn run_distributed_faulty(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
    faults: &FaultConfig,
) -> Result<DistRunResult> {
    if cfg.compute == ComputeMode::Pjrt || pjrt.is_some() {
        return Err(anyhow!(
            "fault injection requires the native engine: drop --pjrt (the \
             guarded exchange stages and replays native exchange buffers)"
        ));
    }
    match app {
        App::Pr => {
            return Err(anyhow!(
                "--faults does not support pr: its floating-point \
                 partial-sum fold is partition-layout-dependent, so a \
                 post-death re-partition cannot be bit-identical \
                 (DESIGN.md §14; valid apps: bfs, sssp, kcore, and cc \
                 without gpu-death)"
            ));
        }
        App::Cc if faults.plan.has_death() => {
            return Err(anyhow!(
                "--faults with gpu-death does not support cc: replay \
                 re-activates every component's full frontier on the new \
                 layout, which DESIGN.md §14's legality table conservatively \
                 excludes (valid gpu-death apps: bfs, sssp, kcore)"
            ));
        }
        _ => {}
    }
    if g.num_vertices() == 0 {
        let dg = partition(g, cluster.num_gpus, cluster.policy);
        return Ok(RunAccounting::new(dg.num_parts()).finish(app, Vec::new()));
    }
    let pool = Pool::new(cfg.sim_threads.max(1));
    match app {
        App::Bfs | App::Sssp | App::Cc => {
            run_push_dist_ft(app, g, source, cfg, cluster, &pool, faults)
        }
        App::Kcore => run_kcore_dist_ft(g, cfg, cluster, &pool, faults),
        App::Pr => unreachable!("rejected above"),
    }
}

/// Snapshot a push-app run at the BSP barrier: global master labels (equal
/// to every copy after broadcast) plus the sorted global frontier.
fn snapshot_push(
    epoch: u64,
    round: u64,
    n: usize,
    gpus: &[GpuPush],
    dg: &DistGraph,
) -> Checkpoint {
    let mut labels = vec![0f32; n];
    let mut frontier: Vec<u32> = Vec::new();
    for (s, p) in gpus.iter().zip(&dg.parts) {
        for (l, &gid) in p.l2g[..p.num_masters].iter().enumerate() {
            labels[gid as usize] = s.st.labels[l];
        }
        frontier.extend(s.st.active.iter().map(|&lv| p.l2g[lv as usize]));
    }
    frontier.sort_unstable();
    frontier.dedup();
    Checkpoint { epoch, round, labels, frontier, aux: CheckpointAux::Push }
}

/// Rebuild per-GPU push state on a (possibly re-partitioned) layout from a
/// checkpoint: every local copy gets its master label, and every copy of a
/// frontier vertex re-activates — a superset of the fault-free frontier,
/// safe because min-relaxation is idempotent and monotone (the fixpoint is
/// unique, so the recovered labels stay bit-identical).
fn restore_push_gpus(
    dg: &DistGraph,
    plan: &ExchangePlan,
    cfg: &EngineConfig,
    ck: &Checkpoint,
) -> Vec<GpuPush> {
    let mut gpus: Vec<GpuPush> = dg
        .parts
        .iter()
        .zip(plan.new_states())
        .map(|(p, mut st)| {
            for (l, &gid) in p.l2g.iter().enumerate() {
                st.labels[l] = ck.labels[gid as usize];
            }
            GpuPush {
                st,
                scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
                out: RoundOut::idle(),
            }
        })
        .collect();
    let mut seed: Vec<Vec<u32>> = vec![Vec::new(); dg.num_parts()];
    plan.scatter_globals(&ck.frontier, &mut seed);
    for (s, locs) in gpus.iter_mut().zip(seed) {
        s.st.active = locs;
    }
    gpus
}

/// [`run_push_dist`] under a fault session: same round shape (superstep →
/// exchange → price → record), with the exchange staged and verified
/// first, slow-link stalls priced in, and GPU deaths recovered by
/// re-partitioning survivors and replaying from the last checkpoint.
#[allow(clippy::too_many_arguments)]
fn run_push_dist_ft(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pool: &Pool,
    faults: &FaultConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let mut k_alive = cluster.num_gpus.max(1);
    let mut dg = partition(g, k_alive, cluster.policy);
    let mut plan = ExchangePlan::new(&dg);
    let init: Vec<f32> = match app {
        App::Cc => (0..n).map(|v| v as f32).collect(),
        _ => {
            let mut m = vec![INF; n];
            m[source as usize] = 0.0;
            m
        }
    };
    let mut gpus: Vec<GpuPush> = dg
        .parts
        .iter()
        .zip(plan.new_states())
        .map(|(p, mut st)| {
            for (l, &gid) in p.l2g.iter().enumerate() {
                st.labels[l] = init[gid as usize];
            }
            GpuPush {
                st,
                scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
                out: RoundOut::idle(),
            }
        })
        .collect();
    match app {
        App::Cc => {
            for (s, p) in gpus.iter_mut().zip(&dg.parts) {
                s.st.active = (0..p.graph.num_vertices() as u32).collect();
            }
        }
        _ => {
            let mut seed: Vec<Vec<u32>> = vec![Vec::new(); dg.num_parts()];
            plan.scatter_globals(&[source], &mut seed);
            for (s, locs) in gpus.iter_mut().zip(seed) {
                s.st.active = locs;
            }
        }
    }

    let mut acct = RunAccounting::new(k_alive as usize);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut flows: Vec<Flow> = Vec::new();
    let mut session = FaultSession::new(&faults.plan);

    // Epoch 0: the initial state is itself a checkpoint, so a death before
    // the first snapshot replays from round 0.
    let initial_frontier: Vec<u32> = match app {
        App::Cc => (0..n as u32).collect(),
        _ => vec![source],
    };
    let mut ck = Checkpoint {
        epoch: 0,
        round: 0,
        labels: init,
        frontier: initial_frontier,
        aux: CheckpointAux::Push,
    };
    acct.checkpoint_bytes += ck.bytes();
    persist_checkpoint(&ck, faults)?;

    let mut logical: u64 = 0;
    let mut converged = false;
    while logical < cfg.max_rounds as u64 {
        session.advance_round();
        if let Some(dead) = session.take_death(k_alive) {
            // The failing round: the dead GPU's superstep slot is masked
            // out; survivors' partial work is discarded with the round.
            let mut mask = vec![true; gpus.len()];
            mask[dead as usize] = false;
            {
                let (parts, sim_ref) = (&dg.parts, &sim);
                superstep_mut_masked(
                    cluster.exec,
                    pool,
                    &mut gpus,
                    &mask,
                    &|pi, s: &mut GpuPush| {
                        local_push_round(
                            app, &parts[pi].graph, cfg, sim_ref, None, pool, s,
                        )
                        .expect("native round cannot fail");
                    },
                );
            }
            if k_alive == 1 {
                return Err(anyhow!(
                    "gpu 0 died at wall round {} with no survivors left to \
                     re-partition onto — cannot recover",
                    session.wall_round()
                ));
            }
            eprintln!(
                "warning: gpu {dead} died at wall round {}; re-partitioning \
                 onto {} survivors and replaying from checkpoint epoch {} \
                 (logical round {})",
                session.wall_round(),
                k_alive - 1,
                ck.epoch,
                ck.round
            );
            k_alive -= 1;
            dg = repartition_survivors(g, k_alive, cluster.policy);
            plan = ExchangePlan::new(&dg);
            gpus = restore_push_gpus(&dg, &plan, cfg, &ck);
            acct.recoveries += 1;
            acct.replayed_rounds += logical - ck.round;
            logical = ck.round;
            continue;
        }

        let global_active: u64 =
            gpus.iter().map(|s| s.st.active.len() as u64).sum();
        if global_active == 0 {
            converged = true;
            break;
        }
        {
            let (parts, sim_ref) = (&dg.parts, &sim);
            superstep_mut(cluster.exec, pool, &mut gpus, &|pi, s: &mut GpuPush| {
                local_push_round(
                    app, &parts[pi].graph, cfg, sim_ref, None, pool, s,
                )
                .expect("native round cannot fail");
            });
        }
        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        for (pi, s) in gpus.iter().enumerate() {
            comp = comp.max(s.out.cycles);
            acct.per_gpu_comp[pi] += s.out.cycles;
            acct.per_gpu_wall_ns[pi] += s.out.wall_ns;
            acct.threads.insert(s.out.thread);
            lb_gpus += s.out.lb as u32;
        }

        // Guarded exchange: stage the reduce messages read-only, verify
        // under this round's injected link faults (failed attempts re-price
        // the staged bytes into `flows`), then apply through the unchanged
        // reduce/broadcast walk — fault-free label parity is automatic.
        let staged = plan.stage_reduce_messages(&mut gpus);
        flows.clear();
        session
            .exchange_guarded(k_alive, &staged, &mut flows)
            .map_err(|e| anyhow!(e))?;
        plan.reduce_min(&mut gpus, &mut flows);
        plan.broadcast_min(&mut gpus, &mut flows);

        let (mut comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        comm += session.take_stalls(&cluster.net, k_alive, &flows);
        acct.record_round(DistRoundRecord {
            round: logical as u32,
            active: global_active,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        logical += 1;

        if faults.checkpoint_every > 0 && logical % faults.checkpoint_every == 0
        {
            ck = snapshot_push(ck.epoch + 1, logical, n, &gpus, &dg);
            acct.checkpoint_bytes += ck.bytes();
            persist_checkpoint(&ck, faults)?;
        }
    }
    let converged = converged || gpus.iter().all(|s| s.st.active.is_empty());
    acct.set_converged(app, converged, cfg.max_rounds);
    acct.retry_count = session.retry_count;
    let mut labels = vec![0f32; n];
    for (s, p) in gpus.iter().zip(&dg.parts) {
        for (l, &gid) in p.l2g[..p.num_masters].iter().enumerate() {
            labels[gid as usize] = s.st.labels[l];
        }
    }
    Ok(acct.finish(app, labels))
}

/// [`run_kcore_dist`] under a fault session. The peeling state (`deg`,
/// `alive`, `dying`) is central — owned by the coordinator, not the
/// partitions — so checkpoints capture it exactly and recovery is
/// partition-layout-invariant by construction.
fn run_kcore_dist_ft(
    g: &CsrGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pool: &Pool,
    faults: &FaultConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let mut k_alive = cluster.num_gpus.max(1);
    let mut dg = partition(g, k_alive, cluster.policy);
    let mut plan = ExchangePlan::new(&dg);
    let k = cfg.kcore_k;
    let mut g2 = g.clone();
    g2.build_csc();
    let mut deg: Vec<u32> =
        (0..n as u32).map(|v| g2.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];
    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| deg[v as usize] < k).collect();
    for &v in &dying {
        alive[v as usize] = false;
    }

    let mut acct = RunAccounting::new(k_alive as usize);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let new_gpus = |dg: &DistGraph, k_alive: u32| -> Vec<GpuKcore> {
        dg.parts
            .iter()
            .map(|p| GpuKcore {
                scratch: RoundScratch::for_run(p.graph.num_vertices(), cfg),
                out: RoundOut::idle(),
                hits: Vec::new(),
                peer_updates: vec![0; k_alive as usize],
            })
            .collect()
    };
    let mut gpus = new_gpus(&dg, k_alive);
    let mut dying_locals: Vec<Vec<u32>> = vec![Vec::new(); k_alive as usize];
    let mut decr = vec![0u32; n];
    let mut flows: Vec<Flow> = Vec::new();
    let mut session = FaultSession::new(&faults.plan);

    let kcore_labels = |alive: &[bool]| -> Vec<f32> {
        alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect()
    };
    let mut ck = Checkpoint {
        epoch: 0,
        round: 0,
        labels: kcore_labels(&alive),
        frontier: Vec::new(),
        aux: CheckpointAux::Kcore {
            deg: deg.clone(),
            alive: alive.clone(),
            dying: dying.clone(),
        },
    };
    acct.checkpoint_bytes += ck.bytes();
    persist_checkpoint(&ck, faults)?;

    let mut logical: u64 = 0;
    while logical < cfg.max_rounds as u64 {
        if dying.is_empty() {
            break;
        }
        session.advance_round();
        if let Some(dead) = session.take_death(k_alive) {
            plan.scatter_globals(&dying, &mut dying_locals);
            let mut mask = vec![true; gpus.len()];
            mask[dead as usize] = false;
            {
                let (parts, sim_ref) = (&dg.parts, &sim);
                let (alive_ref, owner_ref) = (&alive, &dg.owner);
                let dying_ref = &dying_locals;
                superstep_mut_masked(
                    cluster.exec,
                    pool,
                    &mut gpus,
                    &mask,
                    &|pi, s: &mut GpuKcore| {
                        local_kcore_round(
                            &parts[pi], &dying_ref[pi], alive_ref, owner_ref,
                            cfg, sim_ref, pool, s,
                        );
                    },
                );
            }
            if k_alive == 1 {
                return Err(anyhow!(
                    "gpu 0 died at wall round {} with no survivors left to \
                     re-partition onto — cannot recover",
                    session.wall_round()
                ));
            }
            eprintln!(
                "warning: gpu {dead} died at wall round {}; re-partitioning \
                 onto {} survivors and replaying from checkpoint epoch {} \
                 (logical round {})",
                session.wall_round(),
                k_alive - 1,
                ck.epoch,
                ck.round
            );
            k_alive -= 1;
            dg = repartition_survivors(g, k_alive, cluster.policy);
            plan = ExchangePlan::new(&dg);
            gpus = new_gpus(&dg, k_alive);
            dying_locals = vec![Vec::new(); k_alive as usize];
            if let CheckpointAux::Kcore { deg: d, alive: a, dying: y } = &ck.aux
            {
                deg = d.clone();
                alive = a.clone();
                dying = y.clone();
            }
            acct.recoveries += 1;
            acct.replayed_rounds += logical - ck.round;
            logical = ck.round;
            continue;
        }

        plan.scatter_globals(&dying, &mut dying_locals);
        {
            let (parts, sim_ref) = (&dg.parts, &sim);
            let (alive_ref, owner_ref) = (&alive, &dg.owner);
            let dying_ref = &dying_locals;
            superstep_mut(cluster.exec, pool, &mut gpus, &|pi, s: &mut GpuKcore| {
                local_kcore_round(
                    &parts[pi], &dying_ref[pi], alive_ref, owner_ref, cfg,
                    sim_ref, pool, s,
                );
            });
        }

        // Stage the decrement messages (global id + unit decrement per
        // mirror hit, BYTES_PER_UPDATE each) for the guarded verification.
        let mut staged: Vec<(u32, u32, Vec<u8>)> = Vec::new();
        for (pi, s) in gpus.iter().enumerate() {
            let part = &dg.parts[pi];
            let mut per_peer: Vec<Vec<u8>> =
                vec![Vec::new(); k_alive as usize];
            for &lu in &s.hits {
                if (lu as usize) >= part.num_masters {
                    let gid = part.l2g[lu as usize];
                    let peer = dg.owner[gid as usize] as usize;
                    per_peer[peer].extend_from_slice(&gid.to_le_bytes());
                    per_peer[peer]
                        .extend_from_slice(&1f32.to_bits().to_le_bytes());
                }
            }
            for (peer, payload) in per_peer.into_iter().enumerate() {
                if peer != pi && !payload.is_empty() {
                    staged.push((pi as u32, peer as u32, payload));
                }
            }
        }
        flows.clear();
        session
            .exchange_guarded(k_alive, &staged, &mut flows)
            .map_err(|e| anyhow!(e))?;

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        decr.fill(0);
        for (pi, s) in gpus.iter().enumerate() {
            comp = comp.max(s.out.cycles);
            acct.per_gpu_comp[pi] += s.out.cycles;
            acct.per_gpu_wall_ns[pi] += s.out.wall_ns;
            acct.threads.insert(s.out.thread);
            lb_gpus += s.out.lb as u32;
            let l2g = &dg.parts[pi].l2g;
            for &lu in &s.hits {
                decr[l2g[lu as usize] as usize] += 1;
            }
            for (peer, &cnt) in s.peer_updates.iter().enumerate() {
                if cnt > 0 {
                    flows.push((pi as u32, peer as u32, cnt * BYTES_PER_UPDATE));
                }
            }
        }

        let mut next = Vec::new();
        for v in 0..n {
            if alive[v] && decr[v] > 0 {
                deg[v] -= decr[v].min(deg[v]);
                if deg[v] < k {
                    alive[v] = false;
                    next.push(v as u32);
                }
            }
        }
        let (mut comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        comm += session.take_stalls(&cluster.net, k_alive, &flows);
        acct.record_round(DistRoundRecord {
            round: logical as u32,
            active: dying.len() as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        dying = next;
        logical += 1;

        if faults.checkpoint_every > 0 && logical % faults.checkpoint_every == 0
        {
            ck = Checkpoint {
                epoch: ck.epoch + 1,
                round: logical,
                labels: kcore_labels(&alive),
                frontier: Vec::new(),
                aux: CheckpointAux::Kcore {
                    deg: deg.clone(),
                    alive: alive.clone(),
                    dying: dying.clone(),
                },
            };
            acct.checkpoint_bytes += ck.bytes();
            persist_checkpoint(&ck, faults)?;
        }
    }
    acct.set_converged(App::Kcore, dying.is_empty(), cfg.max_rounds);
    acct.retry_count = session.retry_count;
    Ok(acct.finish(App::Kcore, kcore_labels(&alive)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{bfs, cc, kcore, sssp};
    use crate::graph::gen::rmat::{self, RmatConfig};
    use crate::graph::EdgeList;

    fn test_graph(scale: u32, seed: u64) -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, seed)))
    }

    fn cfg() -> EngineConfig {
        EngineConfig { max_rounds: 100_000, ..EngineConfig::default() }
    }

    #[test]
    fn dist_bfs_matches_oracle_all_policies_and_sizes() {
        let g = test_graph(9, 21);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            for k in [1u32, 2, 4] {
                let cluster = ClusterConfig {
                    policy,
                    ..ClusterConfig::single_host(k)
                };
                let r = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None)
                    .unwrap();
                assert_eq!(r.labels, want, "{policy:?} k={k}");
            }
        }
    }

    #[test]
    fn dist_sssp_matches_oracle() {
        let g = test_graph(9, 22);
        let src = g.max_out_degree_vertex();
        let want = sssp::oracle(&g, src);
        let r = run_distributed(
            App::Sssp,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_cc_matches_oracle() {
        let g = test_graph(8, 23);
        let want = cc::oracle(&g);
        let r = run_distributed(
            App::Cc,
            &g,
            0,
            &cfg(),
            &ClusterConfig::single_host(3),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_pr_matches_oracle_within_fp_tolerance() {
        let mut g = test_graph(8, 24);
        let c = EngineConfig { max_rounds: 100, ..EngineConfig::default() };
        let r = run_distributed(
            App::Pr,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = pr::oracle(&mut g, c.pr_tol, 100);
        for (a, b) in r.labels.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn dist_kcore_matches_oracle() {
        let mut g = test_graph(8, 25);
        let c = EngineConfig {
            kcore_k: 8,
            max_rounds: 100_000,
            ..EngineConfig::default()
        };
        let r = run_distributed(
            App::Kcore,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = kcore::oracle(&mut g, 8);
        let got: Vec<bool> = r.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let g = test_graph(8, 26);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(1),
            None,
        )
        .unwrap();
        assert_eq!(r.comm_cycles, 0);
        assert_eq!(r.comm_bytes, 0);
        assert!(r.rounds.iter().all(|x| x.comm_bytes == 0));
    }

    #[test]
    fn multi_gpu_communicates() {
        let g = test_graph(9, 27);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert!(r.comm_cycles > 0);
        assert!(r.rounds.iter().any(|x| x.comm_bytes > 0));
        // Single host: all traffic is intra-host by definition.
        assert_eq!(r.comm_bytes_inter, 0);
        assert_eq!(r.comm_bytes, r.comm_bytes_intra);
        assert_eq!(
            r.comm_bytes,
            r.rounds.iter().map(|x| x.comm_bytes).sum::<u64>()
        );
    }

    #[test]
    fn cluster_splits_bytes_across_link_classes() {
        // On a 2-GPUs-per-host cluster with 4 GPUs, a power-law graph's
        // boundary traffic crosses both link classes, and the per-round
        // split sums to the total.
        let g = test_graph(9, 28);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::bridges(4), None,
        )
        .unwrap();
        assert!(r.comm_bytes_intra > 0, "expected intra-host traffic");
        assert!(r.comm_bytes_inter > 0, "expected inter-host traffic");
        assert_eq!(r.comm_bytes, r.comm_bytes_intra + r.comm_bytes_inter);
        for rec in &r.rounds {
            assert_eq!(
                rec.comm_bytes,
                rec.comm_bytes_intra + rec.comm_bytes_inter
            );
        }
    }

    #[test]
    fn cluster_comm_costs_more_than_single_host() {
        let g = test_graph(9, 28);
        let src = g.max_out_degree_vertex();
        let single = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        let cluster = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::bridges(4), None,
        )
        .unwrap();
        assert_eq!(single.labels, cluster.labels);
        assert!(cluster.comm_cycles > single.comm_cycles);
        // Identical exchanges, different pricing: total bytes agree.
        assert_eq!(single.comm_bytes, cluster.comm_bytes);
    }

    #[test]
    fn more_gpus_reduce_per_round_compute() {
        let g = test_graph(11, 29);
        let src = g.max_out_degree_vertex();
        let one = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(1), None,
        )
        .unwrap();
        let four = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert_eq!(one.labels, four.labels);
        // Compute shrinks with more GPUs (comm is extra, but this asserts
        // the partitioned work itself spreads).
        assert!(four.comp_cycles < one.comp_cycles * 2);
    }

    #[test]
    fn timing_identity_holds() {
        let g = test_graph(9, 30);
        let r = run_distributed(
            App::Bfs,
            &g,
            g.max_out_degree_vertex(),
            &cfg(),
            &ClusterConfig::single_host(2),
            None,
        )
        .unwrap();
        assert_eq!(r.total_cycles, r.comp_cycles + r.comm_cycles);
        let sum: u64 = r.rounds.iter().map(|x| x.comp_cycles + x.comm_cycles).sum();
        assert_eq!(r.total_cycles, sum);
    }

    #[test]
    fn parallel_rounds_run_on_multiple_os_threads() {
        // Acceptance gate: with an explicit multi-lane pool, >= 2 distinct
        // OS threads execute partition rounds. The coordinating thread may
        // be among them — the pool submitter participates.
        let g = test_graph(9, 31);
        let src = g.max_out_degree_vertex();
        let c = EngineConfig { sim_threads: 4, ..cfg() };
        let r = run_distributed(
            App::Bfs, &g, src, &c, &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert!(
            r.num_threads() >= 2,
            "expected >= 2 OS threads, saw {}",
            r.num_threads()
        );
    }

    #[test]
    fn sequential_mode_stays_on_one_thread() {
        let g = test_graph(8, 32);
        let src = g.max_out_degree_vertex();
        let cluster = ClusterConfig::single_host(4).with_exec(ExecMode::Sequential);
        let r = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None).unwrap();
        assert_eq!(r.num_threads(), 1);
        assert!(r.threads.contains(&std::thread::current().id()));
    }

    #[test]
    fn wall_clock_recorded_per_gpu() {
        let g = test_graph(9, 33);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert_eq!(r.per_gpu_wall_ns.len(), 4);
        assert!(r.per_gpu_wall_ns.iter().sum::<u64>() > 0);
    }

    #[test]
    fn degenerate_gpu_counts_match_oracle() {
        // ISSUE 4 hardening: k == 1, k == |V| (every partition one master),
        // and k == |V| + 3 (trailing empty partitions) all converge.
        let mut el = EdgeList::new(61);
        for v in 0..60u32 {
            el.push(v, v + 1, 1.0);
            el.push(v, (v * 7 + 3) % 61, 2.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let n = g.num_vertices() as u32;
        let want = bfs::oracle(&g, 0);
        for k in [1u32, n, n + 3] {
            for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
                let cluster = ClusterConfig {
                    policy,
                    ..ClusterConfig::single_host(k)
                };
                let r = run_distributed(App::Bfs, &g, 0, &cfg(), &cluster, None)
                    .unwrap();
                assert_eq!(r.labels, want, "{policy:?} k={k}");
            }
        }
    }

    #[test]
    fn empty_graph_runs_to_empty_result() {
        let g = CsrGraph::from_edge_list(&EdgeList::new(0));
        let r = run_distributed(
            App::Cc, &g, 0, &cfg(), &ClusterConfig::single_host(3), None,
        )
        .unwrap();
        assert!(r.labels.is_empty());
        assert!(r.rounds.is_empty());
        assert_eq!(r.total_cycles, 0);
    }

    #[test]
    fn exchange_matches_reference_on_small_graph() {
        // In-module smoke of the big parity gate in rust/tests/parity.rs:
        // labels and push-app round records must equal the preserved
        // pre-rebuild coordinator.
        let g = test_graph(9, 34);
        let src = g.max_out_degree_vertex();
        for app in [App::Bfs, App::Sssp, App::Cc] {
            let cluster = ClusterConfig::single_host(4);
            let new = run_distributed(app, &g, src, &cfg(), &cluster, None)
                .unwrap();
            let old =
                run_distributed_reference(app, &g, src, &cfg(), &cluster)
                    .unwrap();
            assert_eq!(new.labels, old.labels, "{}", app.name());
            assert_eq!(new.rounds, old.rounds, "{}", app.name());
            assert_eq!(new.total_cycles, old.total_cycles, "{}", app.name());
        }
    }

    // -------------------------------------------- fault layer (ISSUE 8)

    fn faults(spec: &str, gpus: u32, seed: u64, every: u64) -> FaultConfig {
        FaultConfig {
            plan: FaultPlan::parse(spec, gpus, seed).unwrap(),
            checkpoint_every: every,
            checkpoint_dir: None,
        }
    }

    #[test]
    fn fault_free_faulty_run_matches_run_distributed() {
        // The zero-fault path through the faulty driver is bit-identical to
        // the plain coordinator: same labels, same round records, same
        // cycles — checkpointing and exchange verification are free of
        // observable side effects.
        let g = test_graph(9, 40);
        let src = g.max_out_degree_vertex();
        let cluster = ClusterConfig::single_host(4);
        for app in [App::Bfs, App::Sssp, App::Cc, App::Kcore] {
            let base =
                run_distributed(app, &g, src, &cfg(), &cluster, None).unwrap();
            let ft = run_distributed_faulty(
                app, &g, src, &cfg(), &cluster, None,
                &faults("none", 4, 0, 2),
            )
            .unwrap();
            assert_eq!(ft.labels, base.labels, "{}", app.name());
            assert_eq!(ft.rounds, base.rounds, "{}", app.name());
            assert_eq!(ft.total_cycles, base.total_cycles, "{}", app.name());
            assert!(ft.converged && base.converged, "{}", app.name());
            assert_eq!(ft.recoveries, 0);
            assert_eq!(ft.retry_count, 0);
            assert!(ft.checkpoint_bytes > 0, "epoch 0 always counts");
        }
    }

    #[test]
    fn transient_faults_keep_labels_and_cost_retries() {
        let g = test_graph(9, 41);
        let src = g.max_out_degree_vertex();
        let cluster = ClusterConfig::single_host(4);
        let base = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None)
            .unwrap();
        let ft = run_distributed_faulty(
            App::Bfs, &g, src, &cfg(), &cluster, None,
            &faults("corrupt@2:0-1x2,drop@3:1-2x2", 4, 41, 2),
        )
        .unwrap();
        assert_eq!(ft.labels, base.labels);
        assert_eq!(ft.recoveries, 0);
        assert!(ft.retry_count >= 4, "2 corruptions + 2 drops = 4 retries");
        assert!(
            ft.comm_bytes > base.comm_bytes,
            "failed attempts re-price the staged bytes on the wire"
        );
        assert!(ft.converged);
    }

    #[test]
    fn gpu_death_recovers_bit_identical_labels() {
        let g = test_graph(9, 42);
        let src = g.max_out_degree_vertex();
        let cluster = ClusterConfig::single_host(4);
        let base = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None)
            .unwrap();
        // Death at wall round 2 with no snapshots yet: replay everything
        // from the implicit epoch-0 checkpoint on 3 survivors.
        let ft = run_distributed_faulty(
            App::Bfs, &g, src, &cfg(), &cluster, None,
            &faults("gpu-death@2:1", 4, 0, 0),
        )
        .unwrap();
        assert_eq!(ft.labels, base.labels);
        assert_eq!(ft.recoveries, 1);
        assert_eq!(ft.replayed_rounds, 1, "one logical round was redone");
        assert!(ft.converged);
    }

    #[test]
    fn kcore_death_recovers_from_central_checkpoint() {
        let mut g = test_graph(8, 25);
        let c = EngineConfig {
            kcore_k: 8,
            max_rounds: 100_000,
            ..EngineConfig::default()
        };
        let cluster = ClusterConfig::single_host(4);
        let base =
            run_distributed(App::Kcore, &g, 0, &c, &cluster, None).unwrap();
        let ft = run_distributed_faulty(
            App::Kcore, &g, 0, &c, &cluster, None,
            &faults("gpu-death@1:0", 4, 0, 1),
        )
        .unwrap();
        assert_eq!(ft.labels, base.labels);
        assert_eq!(ft.recoveries, 1);
        assert_eq!(ft.replayed_rounds, 0, "death struck before any round");
        let (want, _) = kcore::oracle(&mut g, 8);
        let got: Vec<bool> = ft.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn faulty_runs_are_deterministic_across_sim_threads() {
        // The ISSUE 8 determinism gate, in miniature: identical labels AND
        // identical recovery metrics for sim_threads in {1, 2, 4}.
        let g = test_graph(9, 43);
        let src = g.max_out_degree_vertex();
        let run = |threads: usize| {
            let c = EngineConfig { sim_threads: threads, ..cfg() };
            let r = run_distributed_faulty(
                App::Bfs, &g, src, &c, &ClusterConfig::single_host(4), None,
                &faults("chaos", 4, 43, 2),
            )
            .unwrap();
            (
                r.labels, r.rounds, r.recoveries, r.replayed_rounds,
                r.retry_count, r.checkpoint_bytes, r.total_cycles, r.converged,
            )
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
    }

    #[test]
    fn fault_legality_is_enforced_loudly() {
        let g = test_graph(8, 44);
        let cluster = ClusterConfig::single_host(4);
        let e = run_distributed_faulty(
            App::Pr, &g, 0, &cfg(), &cluster, None, &FaultConfig::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("pr"), "{e}");
        let e = run_distributed_faulty(
            App::Cc, &g, 0, &cfg(), &cluster, None,
            &faults("gpu-death", 4, 0, 2),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("cc"), "{e}");
        // Transient faults remain legal for cc.
        assert!(run_distributed_faulty(
            App::Cc, &g, 0, &cfg(), &cluster, None,
            &faults("corrupt@2:0-1x1", 4, 0, 2),
        )
        .is_ok());
        let c = EngineConfig { compute: ComputeMode::Pjrt, ..cfg() };
        let e = run_distributed_faulty(
            App::Bfs, &g, 0, &c, &cluster, None, &FaultConfig::default(),
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("native engine"), "{e}");
    }

    #[test]
    fn checkpoint_dir_persists_loadable_epochs() {
        let g = test_graph(9, 45);
        let src = g.max_out_degree_vertex();
        let dir = std::env::temp_dir().join(format!(
            "albk-coord-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let fc = FaultConfig {
            plan: FaultPlan::none(),
            checkpoint_every: 2,
            checkpoint_dir: Some(dir.clone()),
        };
        let r = run_distributed_faulty(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
            &fc,
        )
        .unwrap();
        let ck0 = Checkpoint::load(&Checkpoint::entry_path(&dir, 0)).unwrap();
        assert_eq!(ck0.epoch, 0);
        assert_eq!(ck0.round, 0);
        let ck1 = Checkpoint::load(&Checkpoint::entry_path(&dir, 1)).unwrap();
        assert_eq!(ck1.round, 2, "epoch 1 snapshots after round cadence");
        assert!(r.checkpoint_bytes >= ck0.bytes() + ck1.bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn round_exhaustion_reports_not_converged() {
        let g = test_graph(9, 46);
        let src = g.max_out_degree_vertex();
        let c = EngineConfig { max_rounds: 1, ..EngineConfig::default() };
        let r = run_distributed(
            App::Bfs, &g, src, &c, &ClusterConfig::single_host(2), None,
        )
        .unwrap();
        assert!(!r.converged, "one round cannot finish a multi-hop bfs");
    }
}
