//! The distributed multi-GPU coordinator.
//!
//! Drives the bulk-synchronous execution the paper's multi-GPU evaluation
//! (§6.2–6.3) uses: every round, each simulated GPU runs its local kernels
//! on its partition — **concurrently, as tasks on ONE shared
//! [`crate::exec::Pool`]**, through [`crate::comm::bsp::superstep`] — then
//! the superstep barrier ends the round and the Gluon-style sync
//! ([`crate::comm`]) reconciles boundary vertices. Each GPU task's own
//! kernel simulation nests onto the *same* pool (DESIGN.md §9), so a run
//! uses exactly `sim_threads` lanes however many GPUs it simulates — no
//! per-GPU thread spawning, no oversubscription.
//! Round time = slowest GPU's compute + non-overlapping communication —
//! exactly the accounting behind Figures 6/7/10/11. Intra-GPU thread-block
//! imbalance on *one* GPU therefore stalls the whole machine, which is why
//! ALB's per-GPU fix shows up at cluster scale.
//!
//! Determinism: per-GPU results are collected by partition index and every
//! reduce/broadcast folds them in that order, so a parallel run is
//! bit-identical to the [`ExecMode::Sequential`] reference (asserted by
//! `rust/tests/parity.rs`). Alongside the modeled cycles, the coordinator
//! records real per-GPU host wall-clock and the set of OS threads that
//! executed rounds (the submitting thread participates in the pool, so it
//! may appear in that set).
//!
//! Hot-path memory discipline (DESIGN.md §8): the coordinator owns one
//! [`RoundScratch`] arena per simulated GPU for the whole run; each round,
//! partition `i`'s BSP task borrows arena `i` exclusively (the tasks zip
//! `scratches.iter_mut()`), so local rounds reuse their schedule buffers,
//! simulator accounting arrays, and bitmap frontier across rounds instead
//! of reallocating them — without any cross-task sharing.

use std::collections::HashSet;
use std::thread::ThreadId;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::apps::engine::{self, ComputeMode, EngineConfig, RoundScratch};
use crate::apps::{pr, App, INF};
use crate::comm::{self, NetworkModel, BYTES_PER_UPDATE};
use crate::exec::Pool;
use crate::gpu::Simulator;
use crate::graph::CsrGraph;
use crate::lb::Direction;
use crate::partition::{partition, DistGraph, Partition, Policy};
use crate::runtime::PjrtRuntime;

pub use crate::comm::bsp::ExecMode;

/// Cluster-level configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub num_gpus: u32,
    pub policy: Policy,
    pub net: NetworkModel,
    /// How per-round GPU tasks execute (parallel threads vs the sequential
    /// reference). Output is identical either way.
    pub exec: ExecMode,
}

impl ClusterConfig {
    /// Momentum-like single host with `k` GPUs, CVC partitioning (§5).
    pub fn single_host(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::single_host(),
            exec: ExecMode::Parallel,
        }
    }

    /// Bridges-like cluster: 2 GPUs per host.
    pub fn bridges(k: u32) -> Self {
        ClusterConfig {
            num_gpus: k,
            policy: Policy::Cvc,
            net: NetworkModel::cluster(2),
            exec: ExecMode::Parallel,
        }
    }

    /// Same cluster with a different execution mode.
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }
}

/// One BSP round's record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DistRoundRecord {
    pub round: u32,
    /// Global active count entering the round.
    pub active: u64,
    /// Slowest GPU's compute cycles.
    pub comp_cycles: u64,
    /// Communication cycles (non-overlapping).
    pub comm_cycles: u64,
    pub comm_bytes: u64,
    /// GPUs whose LB kernel launched this round.
    pub lb_gpus: u32,
}

/// A completed distributed run.
#[derive(Debug, Clone)]
pub struct DistRunResult {
    pub app: App,
    /// Reconciled per-global-vertex labels (master values).
    pub labels: Vec<f32>,
    pub rounds: Vec<DistRoundRecord>,
    pub total_cycles: u64,
    pub comp_cycles: u64,
    pub comm_cycles: u64,
    /// Per-GPU total compute cycles (for balance reporting).
    pub per_gpu_comp: Vec<u64>,
    /// Per-GPU host wall-clock (ns) actually spent in local rounds —
    /// measured time alongside the modeled cycles.
    pub per_gpu_wall_ns: Vec<u64>,
    /// OS threads that executed local rounds. Under [`ExecMode::Parallel`]
    /// with a multi-lane pool this reaches >= 2 distinct ids, and may
    /// include the coordinating thread (the pool submitter participates).
    pub threads: HashSet<ThreadId>,
}

impl DistRunResult {
    pub fn ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.total_cycles)
    }

    pub fn comp_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comp_cycles)
    }

    pub fn comm_ms(&self, spec: &crate::gpu::GpuSpec) -> f64 {
        spec.cycles_to_ms(self.comm_cycles)
    }

    /// Distinct OS threads that ran local compute.
    pub fn num_threads(&self) -> usize {
        self.threads.len()
    }
}

/// Mutable accounting shared by the per-app drivers.
struct RunAccounting {
    rounds: Vec<DistRoundRecord>,
    total: u64,
    comp_total: u64,
    comm_total: u64,
    per_gpu_comp: Vec<u64>,
    per_gpu_wall_ns: Vec<u64>,
    threads: HashSet<ThreadId>,
}

impl RunAccounting {
    fn new(k: usize) -> Self {
        RunAccounting {
            rounds: Vec::new(),
            total: 0,
            comp_total: 0,
            comm_total: 0,
            per_gpu_comp: vec![0; k],
            per_gpu_wall_ns: vec![0; k],
            threads: HashSet::new(),
        }
    }

    fn record_round(&mut self, rec: DistRoundRecord) {
        self.total += rec.comp_cycles + rec.comm_cycles;
        self.comp_total += rec.comp_cycles;
        self.comm_total += rec.comm_cycles;
        self.rounds.push(rec);
    }

    fn finish(self, app: App, labels: Vec<f32>) -> DistRunResult {
        DistRunResult {
            app,
            labels,
            rounds: self.rounds,
            total_cycles: self.total,
            comp_cycles: self.comp_total,
            comm_cycles: self.comm_total,
            per_gpu_comp: self.per_gpu_comp,
            per_gpu_wall_ns: self.per_gpu_wall_ns,
            threads: self.threads,
        }
    }
}

/// Run `app` on `g` across `cluster.num_gpus` simulated GPUs.
pub fn run_distributed(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<DistRunResult> {
    if cfg.compute == ComputeMode::Pjrt && pjrt.is_none() {
        return Err(anyhow!("compute=Pjrt requires a loaded PjrtRuntime"));
    }
    let dg = partition(g, cluster.num_gpus, cluster.policy);
    // ONE pool shared by every simulated GPU for the whole run: superstep
    // dispatches the per-GPU round tasks onto it, and each task's kernel
    // simulation nests onto the same pool (DESIGN.md §9).
    let pool = Pool::new(cfg.sim_threads.max(1));
    match app {
        App::Bfs | App::Sssp | App::Cc => {
            run_push_dist(app, g, &dg, source, cfg, cluster, pjrt, &pool)
        }
        App::Pr => run_pr_dist(g, &dg, cfg, cluster, pjrt, &pool),
        App::Kcore => run_kcore_dist(g, &dg, cfg, cluster, &pool),
    }
}

// -------------------------------------------------------------------- push

/// Output of one partition's local compute round.
struct LocalRound {
    cycles: u64,
    #[allow(dead_code)] // recorded for debugging / future per-GPU reports
    edges: u64,
    lb: bool,
    /// Changed (local id, new value) pairs.
    changed: Vec<(u32, f32)>,
    /// Host wall-clock spent in this round, nanoseconds.
    wall_ns: u64,
    /// OS thread the round ran on.
    thread: ThreadId,
}

#[allow(clippy::too_many_arguments)]
fn local_push_round(
    app: App,
    part: &CsrGraph,
    active: &[u32],
    labels: &mut [f32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<LocalRound> {
    let t0 = Instant::now();
    let n = part.num_vertices();
    let scan = cfg.worklist.scan_cost(n as u64, active.len() as u64);
    cfg.balancer.schedule_into_pooled(
        active, part, Direction::Push, &cfg.spec, scan, &mut scratch.sched, pool,
    );
    sim.simulate_into_pooled(&scratch.sched.sched, true, &mut scratch.sim, pool);

    if let (ComputeMode::Pjrt, Some(rt), Some(lb)) =
        (cfg.compute, pjrt, &scratch.sched.sched.lb)
    {
        engine::relax_huge_pjrt(rt, part, &lb.vertices, app, labels, &mut scratch.next)?;
        for item in &scratch.sched.sched.twc {
            engine::relax_native(part, app, item.vertex, labels, &mut scratch.next);
        }
    } else {
        for &v in active {
            engine::relax_native(part, app, v, labels, &mut scratch.next);
        }
    }
    // Drain the bitmap frontier through the scratch's reusable buffer; the
    // (local id, value) pairs themselves cross the BSP barrier, so they are
    // owned by the result.
    scratch.next.take_sorted_into(&mut scratch.active);
    let changed = scratch
        .active
        .iter()
        .map(|&l| (l, labels[l as usize]))
        .collect();
    Ok(LocalRound {
        cycles: scratch.sim.round.total_cycles,
        edges: scratch.sched.sched.total_edges(),
        lb: scratch.sched.sched.lb.is_some(),
        changed,
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread: std::thread::current().id(),
    })
}

#[allow(clippy::too_many_arguments)]
fn run_push_dist(
    app: App,
    g: &CsrGraph,
    dg: &DistGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    // Reconciled master state.
    let mut master: Vec<f32> = match app {
        App::Cc => (0..n).map(|v| v as f32).collect(),
        _ => {
            let mut m = vec![INF; n];
            m[source as usize] = 0.0;
            m
        }
    };
    // Per-partition local labels + active sets.
    let mut labels: Vec<Vec<f32>> = dg
        .parts
        .iter()
        .map(|p| p.l2g.iter().map(|&gid| master[gid as usize]).collect())
        .collect();
    let mut active: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| match app {
            App::Cc => (0..p.graph.num_vertices() as u32).collect(),
            _ => dg.g2l[p.id as usize].get(&source).map(|&l| vec![l]).unwrap_or_default(),
        })
        .collect();

    let mut acct = RunAccounting::new(k);
    // One simulator (Sync, shared) + one scratch arena per simulated GPU,
    // living across rounds; arena i is only ever borrowed by partition i's
    // BSP task.
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();

    for round in 0..cfg.max_rounds {
        let global_active: u64 = active.iter().map(|a| a.len() as u64).sum();
        if global_active == 0 {
            break;
        }
        // --- local compute (one pool task per GPU; superstep = barrier) ---
        let results: Vec<LocalRound> = if pjrt.is_some() {
            // The PJRT client is not Sync: partitions run sequentially.
            let mut out = Vec::with_capacity(k);
            for (pi, part) in dg.parts.iter().enumerate() {
                out.push(local_push_round(
                    app, &part.graph, &active[pi], &mut labels[pi], cfg, &sim,
                    &mut scratches[pi], pjrt, pool,
                )?);
            }
            out
        } else {
            let sim_ref = &sim;
            let tasks: Vec<_> = dg
                .parts
                .iter()
                .zip(&active)
                .zip(labels.iter_mut())
                .zip(scratches.iter_mut())
                .map(|(((part, act), lab), scratch)| {
                    move || {
                        local_push_round(
                            app, &part.graph, act, lab, cfg, sim_ref, scratch,
                            None, pool,
                        )
                        .expect("native round cannot fail")
                    }
                })
                .collect();
            comm::superstep(cluster.exec, pool, tasks)
        };

        let comp = results.iter().map(|r| r.cycles).max().unwrap_or(0);
        for (pi, r) in results.iter().enumerate() {
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(r.thread);
        }
        let lb_gpus = results.iter().filter(|r| r.lb).count() as u32;

        // --- Gluon sync: reduce (min to master) ---
        let mut bytes = 0u64;
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut touched: Vec<u32> = Vec::new();
        for (pi, r) in results.iter().enumerate() {
            let part = &dg.parts[pi];
            let mut to_owner = vec![0u64; k];
            for &(l, val) in &r.changed {
                let gid = part.l2g[l as usize];
                let owner = dg.owner[gid as usize] as usize;
                if val < master[gid as usize] {
                    master[gid as usize] = val;
                }
                touched.push(gid);
                if owner != pi {
                    to_owner[owner] += BYTES_PER_UPDATE;
                }
            }
            for (o, b) in to_owner.iter().enumerate() {
                if *b > 0 {
                    flows.push((pi as u32, o as u32, *b));
                    bytes += *b;
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();

        // --- broadcast (master to every stale copy) + activation ---
        let mut bcast = vec![0u64; k * k];
        let mut next_active: Vec<Vec<u32>> = vec![Vec::new(); k];
        for &gid in &touched {
            let owner = dg.owner[gid as usize] as usize;
            let val = master[gid as usize];
            for pi in 0..k {
                if let Some(&l) = dg.g2l[pi].get(&gid) {
                    if val < labels[pi][l as usize] {
                        labels[pi][l as usize] = val;
                        if owner != pi {
                            bcast[owner * k + pi] += BYTES_PER_UPDATE;
                        }
                    }
                    // A copy whose value just changed (here or locally) is
                    // active next round if it has out-edges to relax.
                    if labels[pi][l as usize] <= val
                        && (labels[pi][l as usize] - val).abs() < f32::EPSILON
                        && dg.parts[pi].graph.out_degree(l) > 0
                    {
                        next_active[pi].push(l);
                    }
                }
            }
        }
        for o in 0..k {
            for pi in 0..k {
                let b = bcast[o * k + pi];
                if b > 0 {
                    flows.push((o as u32, pi as u32, b));
                    bytes += b;
                }
            }
        }
        for a in next_active.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        active = next_active;

        let comm = cluster.net.round_cycles(&flows);
        acct.record_round(DistRoundRecord {
            round,
            active: global_active,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
    }
    Ok(acct.finish(app, master))
}

// ---------------------------------------------------------------------- pr

/// One partition's pagerank round output.
struct PrLocal {
    cycles: u64,
    lb: bool,
    wall_ns: u64,
    thread: ThreadId,
    /// (global id, partial rank mass pulled into it), in local-vertex order.
    acc: Vec<(u32, f32)>,
    /// Bytes of partial sums travelling to remote masters.
    remote_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn local_pr_round(
    pi: usize,
    part: &Partition,
    lg: &CsrGraph,
    all: &[u32],
    ranks: &[f32],
    out_deg: &[u32],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<PrLocal> {
    let t0 = Instant::now();
    let nl = lg.num_vertices();
    let scan = cfg.worklist.scan_cost(nl as u64, nl as u64);
    cfg.balancer.schedule_into_pooled(
        all, lg, Direction::Pull, &cfg.spec, scan, &mut scratch.sched, pool,
    );
    sim.simulate_into_pooled(&scratch.sched.sched, false, &mut scratch.sim, pool);

    // Contributions of local src copies (kernel in Pjrt mode).
    let src_ranks: Vec<f32> = part.l2g.iter().map(|&gid| ranks[gid as usize]).collect();
    let src_degs: Vec<u32> = part.l2g.iter().map(|&gid| out_deg[gid as usize]).collect();
    let contrib: Vec<f32> = match (cfg.compute, pjrt) {
        (ComputeMode::Pjrt, Some(rt)) => {
            let mut c = Vec::with_capacity(nl);
            let tile = 16_384.min(nl.max(1));
            for start in (0..nl).step_by(tile) {
                let end = (start + tile).min(nl);
                c.extend(rt.pr_pull(
                    &src_ranks[start..end],
                    &src_degs[start..end],
                    pr::DAMPING,
                )?);
            }
            c
        }
        _ => src_ranks
            .iter()
            .zip(&src_degs)
            .map(|(&r, &d)| pr::DAMPING * r / d.max(1) as f32)
            .collect(),
    };
    // Pull along local in-edges; emit per-dst partial sums in local order so
    // the coordinator's merge (partition order, then local order) reproduces
    // the sequential reference bit-for-bit.
    let mut acc = Vec::new();
    let mut remote_bytes = 0u64;
    for lv in 0..nl as u32 {
        let (srcs, _) = lg.in_edges(lv);
        if srcs.is_empty() {
            continue;
        }
        let mut sum = 0f32;
        for &lu in srcs {
            sum += contrib[lu as usize];
        }
        let gid = part.l2g[lv as usize];
        acc.push((gid, sum));
        // Partial sums on non-owner partitions travel to the master.
        if owner[gid as usize] as usize != pi {
            remote_bytes += BYTES_PER_UPDATE;
        }
    }
    Ok(PrLocal {
        cycles: scratch.sim.round.total_cycles,
        lb: scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread: std::thread::current().id(),
        acc,
        remote_bytes,
    })
}

fn run_pr_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    let out_deg: Vec<u32> = (0..n as u32).map(|v| g.out_degree(v) as u32).collect();
    let mut ranks = pr::init_ranks(n);
    // Local CSC views for the pull traversal.
    let mut parts: Vec<CsrGraph> = dg.parts.iter().map(|p| p.graph.clone()).collect();
    for p in parts.iter_mut() {
        p.build_csc();
    }
    let base = (1.0 - pr::DAMPING) / n as f32;

    let mut acct = RunAccounting::new(k);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();
    // Topology-driven: every local vertex is active every round.
    let alls: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| (0..p.graph.num_vertices() as u32).collect())
        .collect();

    for round in 0..cfg.max_rounds {
        // Broadcast: every mirror refreshes its rank copy (topology-driven:
        // all ranks move every round).
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut bytes = 0u64;
        for (pi, p) in dg.parts.iter().enumerate() {
            let b = p.num_mirrors() as u64 * BYTES_PER_UPDATE;
            if b > 0 {
                // All owners collectively feed this partition; attribute to
                // the heaviest link pattern by splitting evenly.
                flows.push((((pi + 1) % k) as u32, pi as u32, b));
                bytes += b;
            }
        }

        // Local compute: per-partition contribution gather, one GPU per
        // thread; the superstep join barriers before the reduce below.
        let locals: Vec<PrLocal> = if pjrt.is_some() {
            let mut out = Vec::with_capacity(k);
            for (pi, p) in dg.parts.iter().enumerate() {
                out.push(local_pr_round(
                    pi, p, &parts[pi], &alls[pi], &ranks, &out_deg, &dg.owner,
                    cfg, &sim, &mut scratches[pi], pjrt, pool,
                )?);
            }
            out
        } else {
            let (ranks_ref, out_deg_ref) = (&ranks, &out_deg);
            let (owner_ref, parts_ref) = (&dg.owner, &parts);
            let (alls_ref, sim_ref) = (&alls, &sim);
            let tasks: Vec<_> = dg
                .parts
                .iter()
                .enumerate()
                .zip(scratches.iter_mut())
                .map(|((pi, p), scratch)| {
                    move || {
                        local_pr_round(
                            pi, p, &parts_ref[pi], &alls_ref[pi], ranks_ref,
                            out_deg_ref, owner_ref, cfg, sim_ref, scratch, None,
                            pool,
                        )
                        .expect("native pr round cannot fail")
                    }
                })
                .collect();
            comm::superstep(cluster.exec, pool, tasks)
        };

        // Reduce: fold partial sums in partition order (deterministic).
        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut acc_global = vec![0f32; n];
        for (pi, r) in locals.iter().enumerate() {
            comp = comp.max(r.cycles);
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(r.thread);
            lb_gpus += r.lb as u32;
            for &(gid, sum) in &r.acc {
                acc_global[gid as usize] += sum;
            }
            bytes += r.remote_bytes;
        }
        // The reduce traffic: approximate per-partition aggregate flow.
        if k > 1 {
            flows.push((1, 0, bytes / k as u64));
        }

        let mut delta = 0f32;
        for v in 0..n {
            let new_rank = base + acc_global[v];
            delta = delta.max((new_rank - ranks[v]).abs());
            ranks[v] = new_rank;
        }

        let comm = cluster.net.round_cycles(&flows);
        acct.record_round(DistRoundRecord {
            round,
            active: n as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
        if delta < cfg.pr_tol {
            break;
        }
    }
    Ok(acct.finish(App::Pr, ranks))
}

// ------------------------------------------------------------------- kcore

/// One partition's k-core round output.
struct KcoreLocal {
    cycles: u64,
    lb: bool,
    wall_ns: u64,
    thread: ThreadId,
    /// Global ids losing one in-degree (repeats = multiple dying preds).
    hits: Vec<u32>,
    remote_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn local_kcore_round(
    pi: usize,
    part: &Partition,
    dying: &[u32],
    g2l: &std::collections::HashMap<u32, u32>,
    alive: &[bool],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
    pool: &Pool,
) -> KcoreLocal {
    let t0 = Instant::now();
    let thread = std::thread::current().id();
    let lg = &part.graph;
    // Reuse the scratch's frontier buffer for the local dying list.
    scratch.active.clear();
    scratch
        .active
        .extend(dying.iter().filter_map(|&gv| g2l.get(&gv).copied()));
    if scratch.active.is_empty() {
        return KcoreLocal {
            cycles: 0,
            lb: false,
            wall_ns: t0.elapsed().as_nanos() as u64,
            thread,
            hits: Vec::new(),
            remote_bytes: 0,
        };
    }
    let scan = cfg
        .worklist
        .scan_cost(lg.num_vertices() as u64, scratch.active.len() as u64);
    cfg.balancer.schedule_into_pooled(
        &scratch.active, lg, Direction::Push, &cfg.spec, scan, &mut scratch.sched,
        pool,
    );
    sim.simulate_into_pooled(&scratch.sched.sched, true, &mut scratch.sim, pool);

    let mut hits = Vec::new();
    let mut remote_bytes = 0u64;
    for &lv in &scratch.active {
        let (dsts, _) = lg.out_edges(lv);
        for &lu in dsts {
            let gid = part.l2g[lu as usize];
            if alive[gid as usize] {
                hits.push(gid);
                if owner[gid as usize] as usize != pi {
                    remote_bytes += BYTES_PER_UPDATE;
                }
            }
        }
    }
    KcoreLocal {
        cycles: scratch.sim.round.total_cycles,
        lb: scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        thread,
        hits,
        remote_bytes,
    }
}

fn run_kcore_dist(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
    pool: &Pool,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k_parts = dg.num_parts();
    let k = cfg.kcore_k;
    let mut g2 = g.clone();
    g2.build_csc();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g2.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];

    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| (deg[v as usize]) < k).collect();
    for &v in &dying {
        alive[v as usize] = false;
    }

    let mut acct = RunAccounting::new(k_parts);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();
    let mut round = 0u32;

    while !dying.is_empty() && round < cfg.max_rounds {
        // Per-partition: local copies of dying vertices drive out-edge
        // decrement scans — one GPU per thread, barrier at the join.
        let locals: Vec<KcoreLocal> = {
            let (dying_ref, alive_ref, owner_ref) = (&dying, &alive, &dg.owner);
            let sim_ref = &sim;
            let tasks: Vec<_> = dg
                .parts
                .iter()
                .enumerate()
                .zip(scratches.iter_mut())
                .map(|((pi, p), scratch)| {
                    let g2l = &dg.g2l[pi];
                    move || {
                        local_kcore_round(
                            pi, p, dying_ref, g2l, alive_ref, owner_ref, cfg,
                            sim_ref, scratch, pool,
                        )
                    }
                })
                .collect();
            comm::superstep(cluster.exec, pool, tasks)
        };

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut decr = vec![0u32; n];
        let mut bytes = 0u64;
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        for (pi, r) in locals.iter().enumerate() {
            comp = comp.max(r.cycles);
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(r.thread);
            lb_gpus += r.lb as u32;
            for &gid in &r.hits {
                decr[gid as usize] += 1;
            }
            if r.remote_bytes > 0 {
                flows.push((pi as u32, ((pi + 1) % k_parts) as u32, r.remote_bytes));
                bytes += r.remote_bytes;
            }
        }

        let mut next = Vec::new();
        for v in 0..n {
            if alive[v] && decr[v] > 0 {
                deg[v] -= decr[v].min(deg[v]);
                if deg[v] < k {
                    alive[v] = false;
                    next.push(v as u32);
                }
            }
        }
        let comm = cluster.net.round_cycles(&flows);
        acct.record_round(DistRoundRecord {
            round,
            active: dying.len() as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            lb_gpus,
        });
        dying = next;
        round += 1;
    }
    let labels = alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
    Ok(acct.finish(App::Kcore, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{bfs, cc, kcore, sssp};
    use crate::graph::gen::rmat::{self, RmatConfig};

    fn test_graph(scale: u32, seed: u64) -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, seed)))
    }

    fn cfg() -> EngineConfig {
        EngineConfig { max_rounds: 100_000, ..EngineConfig::default() }
    }

    #[test]
    fn dist_bfs_matches_oracle_all_policies_and_sizes() {
        let g = test_graph(9, 21);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            for k in [1u32, 2, 4] {
                let cluster = ClusterConfig {
                    policy,
                    ..ClusterConfig::single_host(k)
                };
                let r = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None)
                    .unwrap();
                assert_eq!(r.labels, want, "{policy:?} k={k}");
            }
        }
    }

    #[test]
    fn dist_sssp_matches_oracle() {
        let g = test_graph(9, 22);
        let src = g.max_out_degree_vertex();
        let want = sssp::oracle(&g, src);
        let r = run_distributed(
            App::Sssp,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_cc_matches_oracle() {
        let g = test_graph(8, 23);
        let want = cc::oracle(&g);
        let r = run_distributed(
            App::Cc,
            &g,
            0,
            &cfg(),
            &ClusterConfig::single_host(3),
            None,
        )
        .unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn dist_pr_matches_oracle_within_fp_tolerance() {
        let mut g = test_graph(8, 24);
        let c = EngineConfig { max_rounds: 100, ..EngineConfig::default() };
        let r = run_distributed(
            App::Pr,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = pr::oracle(&mut g, c.pr_tol, 100);
        for (a, b) in r.labels.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn dist_kcore_matches_oracle() {
        let mut g = test_graph(8, 25);
        let c = EngineConfig { kcore_k: 8, max_rounds: 100_000, ..EngineConfig::default() };
        let r = run_distributed(
            App::Kcore,
            &g,
            0,
            &c,
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        let (want, _) = kcore::oracle(&mut g, 8);
        let got: Vec<bool> = r.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_gpu_has_no_comm() {
        let g = test_graph(8, 26);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(1),
            None,
        )
        .unwrap();
        assert_eq!(r.comm_cycles, 0);
        assert!(r.rounds.iter().all(|x| x.comm_bytes == 0));
    }

    #[test]
    fn multi_gpu_communicates() {
        let g = test_graph(9, 27);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs,
            &g,
            src,
            &cfg(),
            &ClusterConfig::single_host(4),
            None,
        )
        .unwrap();
        assert!(r.comm_cycles > 0);
        assert!(r.rounds.iter().any(|x| x.comm_bytes > 0));
    }

    #[test]
    fn cluster_comm_costs_more_than_single_host() {
        let g = test_graph(9, 28);
        let src = g.max_out_degree_vertex();
        let single = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        let cluster = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::bridges(4), None,
        )
        .unwrap();
        assert_eq!(single.labels, cluster.labels);
        assert!(cluster.comm_cycles > single.comm_cycles);
    }

    #[test]
    fn more_gpus_reduce_per_round_compute() {
        let g = test_graph(11, 29);
        let src = g.max_out_degree_vertex();
        let one = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(1), None,
        )
        .unwrap();
        let four = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert_eq!(one.labels, four.labels);
        // Compute shrinks with more GPUs (comm is extra, but this asserts
        // the partitioned work itself spreads).
        assert!(four.comp_cycles < one.comp_cycles * 2);
    }

    #[test]
    fn timing_identity_holds() {
        let g = test_graph(9, 30);
        let r = run_distributed(
            App::Bfs,
            &g,
            g.max_out_degree_vertex(),
            &cfg(),
            &ClusterConfig::single_host(2),
            None,
        )
        .unwrap();
        assert_eq!(r.total_cycles, r.comp_cycles + r.comm_cycles);
        let sum: u64 = r.rounds.iter().map(|x| x.comp_cycles + x.comm_cycles).sum();
        assert_eq!(r.total_cycles, sum);
    }

    #[test]
    fn parallel_rounds_run_on_multiple_os_threads() {
        // Acceptance gate: with an explicit multi-lane pool, >= 2 distinct
        // OS threads execute partition rounds. The coordinating thread may
        // be among them — the pool submitter participates.
        let g = test_graph(9, 31);
        let src = g.max_out_degree_vertex();
        let c = EngineConfig { sim_threads: 4, ..cfg() };
        let r = run_distributed(
            App::Bfs, &g, src, &c, &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert!(
            r.num_threads() >= 2,
            "expected >= 2 OS threads, saw {}",
            r.num_threads()
        );
    }

    #[test]
    fn sequential_mode_stays_on_one_thread() {
        let g = test_graph(8, 32);
        let src = g.max_out_degree_vertex();
        let cluster = ClusterConfig::single_host(4).with_exec(ExecMode::Sequential);
        let r = run_distributed(App::Bfs, &g, src, &cfg(), &cluster, None).unwrap();
        assert_eq!(r.num_threads(), 1);
        assert!(r.threads.contains(&std::thread::current().id()));
    }

    #[test]
    fn wall_clock_recorded_per_gpu() {
        let g = test_graph(9, 33);
        let src = g.max_out_degree_vertex();
        let r = run_distributed(
            App::Bfs, &g, src, &cfg(), &ClusterConfig::single_host(4), None,
        )
        .unwrap();
        assert_eq!(r.per_gpu_wall_ns.len(), 4);
        assert!(r.per_gpu_wall_ns.iter().sum::<u64>() > 0);
    }
}
