//! The preserved **pre-rebuild** coordinator: central `master: Vec<f32>`
//! reconciliation plus per-round `g2l` HashMap lookups, exactly as the
//! coordinator synchronized before the `comm::exchange` schedules (ISSUE 4).
//!
//! This is not a hot path — it exists as the golden reference the rebuilt
//! exchange is asserted against (`rust/tests/parity.rs`): identical labels
//! for every app, and for the push apps identical per-round records
//! (compute cycles, comm cycles, and byte counts — the schedules ship
//! exactly the updates the full reconciliation shipped). It runs
//! sequentially on the calling thread and allocates freely per round, in
//! the same spirit as [`crate::apps::engine::run_push_reference`].

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::apps::engine::{self, EngineConfig, RoundScratch};
use crate::apps::{pr, App, INF};
use crate::comm::BYTES_PER_UPDATE;
use crate::gpu::Simulator;
use crate::graph::CsrGraph;
use crate::lb::Direction;
use crate::partition::{partition, DistGraph, Partition};

use super::{
    price, ClusterConfig, DistRoundRecord, DistRunResult, RunAccounting,
};

/// Run `app` with the pre-rebuild reconciliation (sequential, native-only).
#[doc(hidden)]
pub fn run_distributed_reference(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
) -> Result<DistRunResult> {
    let dg = partition(g, cluster.num_gpus, cluster.policy);
    if g.num_vertices() == 0 {
        return Ok(RunAccounting::new(dg.num_parts()).finish(app, Vec::new()));
    }
    match app {
        App::Bfs | App::Sssp | App::Cc => {
            ref_push(app, g, &dg, source, cfg, cluster)
        }
        App::Pr => ref_pr(g, &dg, cfg, cluster),
        App::Kcore => ref_kcore(g, &dg, cfg, cluster),
    }
}

struct LocalRound {
    cycles: u64,
    lb: bool,
    /// Changed (local id, new value) pairs — the freshly-allocated payload
    /// the exchange rebuild replaced.
    changed: Vec<(u32, f32)>,
    wall_ns: u64,
}

fn local_push_round(
    app: App,
    part: &CsrGraph,
    active: &[u32],
    labels: &mut [f32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
) -> LocalRound {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let n = part.num_vertices();
    let scan = cfg.worklist.scan_cost(n as u64, active.len() as u64);
    cfg.balancer.schedule_into(
        active, part, Direction::Push, &cfg.spec, scan, &mut scratch.sched,
    );
    sim.simulate_into(&scratch.sched.sched, true, &mut scratch.sim);
    for &v in active {
        engine::relax_native(part, app, v, labels, &mut scratch.next);
    }
    scratch.next.take_sorted_into(&mut scratch.active);
    let changed = scratch
        .active
        .iter()
        .map(|&l| (l, labels[l as usize]))
        .collect();
    LocalRound {
        cycles: scratch.sim.round.total_cycles,
        lb: scratch.sched.sched.lb.is_some(),
        changed,
        wall_ns: t0.elapsed().as_nanos() as u64,
    }
}

fn ref_push(
    app: App,
    g: &CsrGraph,
    dg: &DistGraph,
    source: u32,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    // Reconciled master state.
    let mut master: Vec<f32> = match app {
        App::Cc => (0..n).map(|v| v as f32).collect(),
        _ => {
            let mut m = vec![INF; n];
            m[source as usize] = 0.0;
            m
        }
    };
    let mut labels: Vec<Vec<f32>> = dg
        .parts
        .iter()
        .map(|p| p.l2g.iter().map(|&gid| master[gid as usize]).collect())
        .collect();
    let mut active: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| match app {
            App::Cc => (0..p.graph.num_vertices() as u32).collect(),
            _ => dg.g2l[p.id as usize]
                .get(&source)
                .map(|&l| vec![l])
                .unwrap_or_default(),
        })
        .collect();

    let mut acct = RunAccounting::new(k);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();
    let me = std::thread::current().id();

    let mut converged = false;
    for round in 0..cfg.max_rounds {
        let global_active: u64 = active.iter().map(|a| a.len() as u64).sum();
        if global_active == 0 {
            converged = true;
            break;
        }
        let mut results = Vec::with_capacity(k);
        for (pi, part) in dg.parts.iter().enumerate() {
            results.push(local_push_round(
                app, &part.graph, &active[pi], &mut labels[pi], cfg, &sim,
                &mut scratches[pi],
            ));
        }
        let comp = results.iter().map(|r| r.cycles).max().unwrap_or(0);
        for (pi, r) in results.iter().enumerate() {
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(me);
        }
        let lb_gpus = results.iter().filter(|r| r.lb).count() as u32;

        // --- Gluon sync: reduce (min to master), every update through the
        // central master array ---
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut touched: Vec<u32> = Vec::new();
        for (pi, r) in results.iter().enumerate() {
            let part = &dg.parts[pi];
            let mut to_owner = vec![0u64; k];
            for &(l, val) in &r.changed {
                let gid = part.l2g[l as usize];
                let owner = dg.owner[gid as usize] as usize;
                if val < master[gid as usize] {
                    master[gid as usize] = val;
                }
                touched.push(gid);
                if owner != pi {
                    to_owner[owner] += BYTES_PER_UPDATE;
                }
            }
            for (o, b) in to_owner.iter().enumerate() {
                if *b > 0 {
                    flows.push((pi as u32, o as u32, *b));
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();

        // --- broadcast (master to every stale copy) + activation, through
        // the per-partition g2l HashMaps ---
        let mut bcast = vec![0u64; k * k];
        let mut next_active: Vec<Vec<u32>> = vec![Vec::new(); k];
        for &gid in &touched {
            let owner = dg.owner[gid as usize] as usize;
            let val = master[gid as usize];
            for pi in 0..k {
                if let Some(&l) = dg.g2l[pi].get(&gid) {
                    if val < labels[pi][l as usize] {
                        labels[pi][l as usize] = val;
                        if owner != pi {
                            bcast[owner * k + pi] += BYTES_PER_UPDATE;
                        }
                    }
                    // A copy whose value just changed (here or locally) is
                    // active next round if it has out-edges to relax.
                    if labels[pi][l as usize] <= val
                        && (labels[pi][l as usize] - val).abs() < f32::EPSILON
                        && dg.parts[pi].graph.out_degree(l) > 0
                    {
                        next_active[pi].push(l);
                    }
                }
            }
        }
        for o in 0..k {
            for pi in 0..k {
                let b = bcast[o * k + pi];
                if b > 0 {
                    flows.push((o as u32, pi as u32, b));
                }
            }
        }
        for a in next_active.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        active = next_active;

        let (comm, bytes_intra, bytes_inter) = price(&cluster.net, &flows);
        acct.record_round(DistRoundRecord {
            round,
            active: global_active,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes_intra + bytes_inter,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
    }
    let converged = converged || active.iter().all(|a| a.is_empty());
    acct.set_converged(app, converged, cfg.max_rounds);
    Ok(acct.finish(app, master))
}

struct PrLocal {
    cycles: u64,
    lb: bool,
    wall_ns: u64,
    /// (global id, partial rank mass), in local-vertex order.
    acc: Vec<(u32, f32)>,
    remote_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn local_pr_round(
    pi: usize,
    part: &Partition,
    lg: &CsrGraph,
    all: &[u32],
    ranks: &[f32],
    out_deg: &[u32],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
) -> PrLocal {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let nl = lg.num_vertices();
    let scan = cfg.worklist.scan_cost(nl as u64, nl as u64);
    cfg.balancer.schedule_into(
        all, lg, Direction::Pull, &cfg.spec, scan, &mut scratch.sched,
    );
    sim.simulate_into(&scratch.sched.sched, false, &mut scratch.sim);

    let src_ranks: Vec<f32> =
        part.l2g.iter().map(|&gid| ranks[gid as usize]).collect();
    let src_degs: Vec<u32> =
        part.l2g.iter().map(|&gid| out_deg[gid as usize]).collect();
    let contrib: Vec<f32> = src_ranks
        .iter()
        .zip(&src_degs)
        .map(|(&r, &d)| pr::DAMPING * r / d.max(1) as f32)
        .collect();
    let mut acc = Vec::new();
    let mut remote_bytes = 0u64;
    for lv in 0..nl as u32 {
        let (srcs, _) = lg.in_edges(lv);
        if srcs.is_empty() {
            continue;
        }
        let mut sum = 0f32;
        for &lu in srcs {
            sum += contrib[lu as usize];
        }
        let gid = part.l2g[lv as usize];
        acc.push((gid, sum));
        if owner[gid as usize] as usize != pi {
            remote_bytes += BYTES_PER_UPDATE;
        }
    }
    PrLocal {
        cycles: scratch.sim.round.total_cycles,
        lb: scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        acc,
        remote_bytes,
    }
}

fn ref_pr(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k = dg.num_parts();
    let out_deg: Vec<u32> = (0..n as u32).map(|v| g.out_degree(v) as u32).collect();
    let mut ranks = pr::init_ranks(n);
    let mut parts: Vec<CsrGraph> = dg.parts.iter().map(|p| p.graph.clone()).collect();
    for p in parts.iter_mut() {
        p.build_csc();
    }
    let base = (1.0 - pr::DAMPING) / n as f32;

    let mut acct = RunAccounting::new(k);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();
    let alls: Vec<Vec<u32>> = dg
        .parts
        .iter()
        .map(|p| (0..p.graph.num_vertices() as u32).collect())
        .collect();
    let me = std::thread::current().id();
    let mut converged = false;

    for round in 0..cfg.max_rounds {
        // Mirror-refresh broadcast with the historical coarse attribution.
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        let mut bytes = 0u64;
        for (pi, p) in dg.parts.iter().enumerate() {
            let b = p.num_mirrors() as u64 * BYTES_PER_UPDATE;
            if b > 0 {
                flows.push((((pi + 1) % k) as u32, pi as u32, b));
                bytes += b;
            }
        }

        let mut locals = Vec::with_capacity(k);
        for (pi, p) in dg.parts.iter().enumerate() {
            locals.push(local_pr_round(
                pi, p, &parts[pi], &alls[pi], &ranks, &out_deg, &dg.owner, cfg,
                &sim, &mut scratches[pi],
            ));
        }

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut acc_global = vec![0f32; n];
        for (pi, r) in locals.iter().enumerate() {
            comp = comp.max(r.cycles);
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(me);
            lb_gpus += r.lb as u32;
            for &(gid, sum) in &r.acc {
                acc_global[gid as usize] += sum;
            }
            bytes += r.remote_bytes;
        }
        // The reduce traffic: historical approximate aggregate flow.
        if k > 1 {
            flows.push((1, 0, bytes / k as u64));
        }

        let mut delta = 0f32;
        for v in 0..n {
            let new_rank = base + acc_global[v];
            delta = delta.max((new_rank - ranks[v]).abs());
            ranks[v] = new_rank;
        }

        let comm = cluster.net.round_cycles(&flows);
        let (bytes_intra, bytes_inter) = cluster.net.split_bytes(&flows);
        acct.record_round(DistRoundRecord {
            round,
            active: n as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            // The historical record kept the true byte total even though
            // the flow attribution was approximate.
            comm_bytes: bytes,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        if delta < cfg.pr_tol {
            converged = true;
            break;
        }
    }
    acct.set_converged(App::Pr, converged, cfg.max_rounds);
    Ok(acct.finish(App::Pr, ranks))
}

struct KcoreLocal {
    cycles: u64,
    lb: bool,
    wall_ns: u64,
    /// Global ids losing one in-degree (repeats = multiple dying preds).
    hits: Vec<u32>,
    remote_bytes: u64,
}

#[allow(clippy::too_many_arguments)]
fn local_kcore_round(
    pi: usize,
    part: &Partition,
    dying: &[u32],
    g2l: &HashMap<u32, u32>,
    alive: &[bool],
    owner: &[u32],
    cfg: &EngineConfig,
    sim: &Simulator,
    scratch: &mut RoundScratch,
) -> KcoreLocal {
    // Allowlisted D001 host-timing site: advisory wall-clock only.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let lg = &part.graph;
    scratch.active.clear();
    scratch
        .active
        .extend(dying.iter().filter_map(|&gv| g2l.get(&gv).copied()));
    if scratch.active.is_empty() {
        return KcoreLocal {
            cycles: 0,
            lb: false,
            wall_ns: t0.elapsed().as_nanos() as u64,
            hits: Vec::new(),
            remote_bytes: 0,
        };
    }
    let scan = cfg
        .worklist
        .scan_cost(lg.num_vertices() as u64, scratch.active.len() as u64);
    cfg.balancer.schedule_into(
        &scratch.active, lg, Direction::Push, &cfg.spec, scan,
        &mut scratch.sched,
    );
    sim.simulate_into(&scratch.sched.sched, true, &mut scratch.sim);

    let mut hits = Vec::new();
    let mut remote_bytes = 0u64;
    for &lv in &scratch.active {
        let (dsts, _) = lg.out_edges(lv);
        for &lu in dsts {
            let gid = part.l2g[lu as usize];
            if alive[gid as usize] {
                hits.push(gid);
                if owner[gid as usize] as usize != pi {
                    remote_bytes += BYTES_PER_UPDATE;
                }
            }
        }
    }
    KcoreLocal {
        cycles: scratch.sim.round.total_cycles,
        lb: scratch.sched.sched.lb.is_some(),
        wall_ns: t0.elapsed().as_nanos() as u64,
        hits,
        remote_bytes,
    }
}

fn ref_kcore(
    g: &CsrGraph,
    dg: &DistGraph,
    cfg: &EngineConfig,
    cluster: &ClusterConfig,
) -> Result<DistRunResult> {
    let n = g.num_vertices();
    let k_parts = dg.num_parts();
    let k = cfg.kcore_k;
    let mut g2 = g.clone();
    g2.build_csc();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g2.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];

    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| (deg[v as usize]) < k).collect();
    for &v in &dying {
        alive[v as usize] = false;
    }

    let mut acct = RunAccounting::new(k_parts);
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut scratches: Vec<RoundScratch> = dg
        .parts
        .iter()
        .map(|p| RoundScratch::for_vertices(p.graph.num_vertices()))
        .collect();
    let me = std::thread::current().id();
    let mut round = 0u32;

    while !dying.is_empty() && round < cfg.max_rounds {
        let mut locals = Vec::with_capacity(k_parts);
        for (pi, p) in dg.parts.iter().enumerate() {
            locals.push(local_kcore_round(
                pi, p, &dying, &dg.g2l[pi], &alive, &dg.owner, cfg, &sim,
                &mut scratches[pi],
            ));
        }

        let mut comp = 0u64;
        let mut lb_gpus = 0u32;
        let mut decr = vec![0u32; n];
        let mut bytes = 0u64;
        let mut flows: Vec<(u32, u32, u64)> = Vec::new();
        for (pi, r) in locals.iter().enumerate() {
            comp = comp.max(r.cycles);
            acct.per_gpu_comp[pi] += r.cycles;
            acct.per_gpu_wall_ns[pi] += r.wall_ns;
            acct.threads.insert(me);
            lb_gpus += r.lb as u32;
            for &gid in &r.hits {
                decr[gid as usize] += 1;
            }
            if r.remote_bytes > 0 {
                flows.push((
                    pi as u32,
                    ((pi + 1) % k_parts) as u32,
                    r.remote_bytes,
                ));
                bytes += r.remote_bytes;
            }
        }

        let mut next = Vec::new();
        for v in 0..n {
            if alive[v] && decr[v] > 0 {
                deg[v] -= decr[v].min(deg[v]);
                if deg[v] < k {
                    alive[v] = false;
                    next.push(v as u32);
                }
            }
        }
        let comm = cluster.net.round_cycles(&flows);
        let (bytes_intra, bytes_inter) = cluster.net.split_bytes(&flows);
        acct.record_round(DistRoundRecord {
            round,
            active: dying.len() as u64,
            comp_cycles: comp,
            comm_cycles: comm,
            comm_bytes: bytes,
            comm_bytes_intra: bytes_intra,
            comm_bytes_inter: bytes_inter,
            lb_gpus,
        });
        dying = next;
        round += 1;
    }
    acct.set_converged(App::Kcore, dying.is_empty(), cfg.max_rounds);
    let labels = alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
    Ok(acct.finish(App::Kcore, labels))
}
