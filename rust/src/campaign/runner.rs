//! Campaign execution: run matrix cells through the engine/coordinator
//! entry points and capture per-cell metrics.
//!
//! Cells are executed in the spec's canonical order (input-major, so each
//! input graph is generated once and reused); cells whose id already
//! appears in the `prior` map — loaded from an existing `CAMPAIGN.json` —
//! are skipped and their recorded result carried over verbatim, which is
//! what makes a sweep resumable (DESIGN.md §11 resume rules). After every
//! executed cell the whole artifact is rewritten to the checkpoint path,
//! so an interrupted sweep loses at most one cell.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::apps::engine::EngineConfig;
use crate::comm::fault::FaultPlan;
use crate::coordinator::FaultConfig;
use crate::graph::inputs;
use crate::session::{ClusterRequest, Session};

use super::artifact;
use super::spec::{CampaignSpec, Cell};

/// One executed (or resumed) cell's record — exactly the fields the
/// `CAMPAIGN.json` artifact stores. All dimension fields are plain strings
/// so resumed results roundtrip bit-for-bit through the artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    /// `app/input/balancer/policy/gpus` (see [`Cell::id`]).
    pub id: String,
    pub app: String,
    pub input: String,
    pub balancer: String,
    /// Partition policy name, `-` for single-GPU cells.
    pub policy: String,
    pub gpus: u32,
    /// FNV-1a over the final labels' f32 bit patterns, 16 hex digits —
    /// machine-independent (labels are bit-deterministic).
    pub labels_hash: String,
    pub rounds: u64,
    pub total_cycles: u64,
    /// Single-GPU cells: peak per-kernel thread-block imbalance (the
    /// paper's Figure 1/5 quantity). Multi-GPU cells: max/mean of per-GPU
    /// compute cycles.
    pub imbalance_factor: f64,
    /// Total / intra-host / inter-host exchanged bytes (0 for single-GPU).
    pub comm_bytes: u64,
    pub comm_bytes_intra: u64,
    pub comm_bytes_inter: u64,
    pub simulated_ms: f64,
    /// Host wall-clock for the cell — the one machine-dependent field
    /// (excluded from golden comparison; carried verbatim on resume).
    pub host_ms: f64,
    /// Inspector threshold after the last round (adaptive/auto single-GPU
    /// cells; 0 for static balancers and for multi-GPU cells, whose per-GPU
    /// controllers have no single final value).
    pub adaptive_threshold_final: u64,
    /// Rounds whose LB kernel launched (multi-GPU: on at least one GPU).
    pub lb_rounds: u64,
    /// Did the run reach its fixpoint, or stop on the round cap?
    pub converged: bool,
    /// Fault-plan preset for this cell (`"none"` for the fault-free matrix).
    pub fault: String,
    /// Recovery metrics (all 0 for fault-free cells; DESIGN.md §14).
    pub recoveries: u32,
    pub replayed_rounds: u64,
    pub retry_count: u64,
}

impl Default for CellResult {
    fn default() -> CellResult {
        CellResult {
            id: String::new(),
            app: String::new(),
            input: String::new(),
            balancer: String::new(),
            policy: String::new(),
            gpus: 0,
            labels_hash: String::new(),
            rounds: 0,
            total_cycles: 0,
            imbalance_factor: 0.0,
            comm_bytes: 0,
            comm_bytes_intra: 0,
            comm_bytes_inter: 0,
            simulated_ms: 0.0,
            host_ms: 0.0,
            adaptive_threshold_final: 0,
            lb_rounds: 0,
            // Pre-fault-axis artifacts carry neither key: such cells all
            // converged (the campaign round cap is effectively unbounded)
            // and are fault-free, so the defaults say so rather than "".
            converged: true,
            fault: "none".to_string(),
            recoveries: 0,
            replayed_rounds: 0,
            retry_count: 0,
        }
    }
}

/// The outcome of one sweep invocation.
#[derive(Debug)]
pub struct SweepOutcome {
    /// All cells in canonical order (executed and resumed alike).
    pub results: Vec<CellResult>,
    pub executed: usize,
    pub skipped: usize,
}

/// The campaign-wide base [`EngineConfig`] a per-input [`Session`] is built
/// with; per-cell variation rides in the [`crate::session::RunRequest`].
/// The round cap is effectively unbounded so every cell converges on every
/// input scale (PageRank cells override it to [`super::spec::PR_MAX_ROUNDS`]
/// via [`super::spec::AppVariant::to_request`]).
pub fn base_config(spec: &CampaignSpec) -> EngineConfig {
    EngineConfig::default()
        .with_sim_threads(spec.sim_threads)
        .with_max_rounds(1_000_000)
}

/// Execute one cell against `session` (the already-prepared input graph).
/// The session's input name must be the cell's input: default-source
/// selection and `auto`-balancer resolution both key on it.
pub fn run_cell(cell: &Cell, spec: &CampaignSpec, session: &Session) -> Result<CellResult> {
    // Allowlisted D001 host-timing site: feeds only `host_ms`, which the
    // artifact writer and golden checks treat as machine-dependent.
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    debug_assert_eq!(session.input(), cell.input);

    let mut req = cell
        .app
        .to_request(spec.sssp_delta)
        // `auto` is forwarded unresolved: the session resolves it against
        // (app, input) exactly as the CLI does; the cell id and recorded
        // balancer keep the name "auto".
        .with_balancer(cell.balancer.clone());
    // Per-block kernel stats feed the single-GPU imbalance factor.
    req.record_blocks = cell.gpus <= 1;
    if cell.gpus > 1 {
        let policy = cell
            .policy
            .ok_or_else(|| anyhow!("multi-GPU cell {} without a policy", cell.id()))?;
        req.cluster = Some(ClusterRequest {
            gpus: cell.gpus,
            policy,
            gpus_per_host: None,
            exec: spec.exec,
        });
        if cell.fault != "none" {
            // Fault cells replay the plan the CLI preset of the same name
            // would build from the sweep's seed, checkpointing every other
            // round in memory so a GPU death replays at most one round.
            let plan =
                FaultPlan::parse(cell.fault, cell.gpus, spec.seed).map_err(|e| anyhow!(e))?;
            req.fault =
                Some(FaultConfig { plan, checkpoint_every: 2, checkpoint_dir: None });
        }
    }

    let reply = session.run(&req, None)?;
    let mut r = CellResult {
        id: cell.id(),
        app: cell.app.name().to_string(),
        input: cell.input.to_string(),
        balancer: cell.balancer.name().to_string(),
        policy: cell.policy.map(|p| p.name()).unwrap_or("-").to_string(),
        gpus: cell.gpus,
        fault: cell.fault.to_string(),
        labels_hash: reply.labels_hash.clone(),
        rounds: reply.rounds,
        total_cycles: reply.total_cycles,
        simulated_ms: reply.simulated_ms,
        imbalance_factor: reply.imbalance_factor,
        lb_rounds: reply.lb_rounds,
        converged: reply.converged,
        adaptive_threshold_final: reply.adaptive_threshold_final,
        ..CellResult::default()
    };
    if let Some(d) = &reply.dist {
        r.comm_bytes = d.comm_bytes;
        r.comm_bytes_intra = d.comm_bytes_intra;
        r.comm_bytes_inter = d.comm_bytes_inter;
        r.recoveries = d.recoveries;
        r.replayed_rounds = d.replayed_rounds;
        r.retry_count = d.retry_count;
    }
    r.host_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(r)
}

/// Run the whole sweep. `prior` maps cell id → previously recorded result
/// (resume); `checkpoint` is rewritten after every executed cell and once
/// at the end; `each(result, executed)` is called per cell in order
/// (`executed = false` for resumed cells).
///
/// Prior cells *outside* this spec's enumeration (e.g. a full-matrix
/// artifact resumed with a narrower `--apps` filter) are never dropped:
/// every checkpoint rewrite re-appends them after the enumerated cells,
/// sorted by id, so a filtered continuation cannot destroy recorded
/// results. The returned [`SweepOutcome::results`] holds the enumerated
/// cells only.
pub fn run_sweep(
    spec: &CampaignSpec,
    prior: &HashMap<String, CellResult>,
    checkpoint: Option<&Path>,
    each: impl FnMut(&CellResult, bool),
) -> Result<SweepOutcome> {
    run_sweep_cached(spec, prior, checkpoint, None, each)
}

/// [`run_sweep`] with an optional on-disk CSR cache (`--graph-cache DIR`):
/// input graphs load from `graph_cache` when a valid entry exists and are
/// generated-and-saved otherwise. The cache key is the exact generator
/// inputs `(input, scale_delta, seed)`, so a hit is definitionally the
/// graph [`inputs::build`] would produce — results, and therefore the
/// artifact, are byte-identical with or without a cache directory (the
/// cache never enters [`artifact`] state or resume matching).
pub fn run_sweep_cached(
    spec: &CampaignSpec,
    prior: &HashMap<String, CellResult>,
    checkpoint: Option<&Path>,
    graph_cache: Option<&Path>,
    mut each: impl FnMut(&CellResult, bool),
) -> Result<SweepOutcome> {
    let cells = spec.cells();
    // Recorded results that this (possibly filtered) enumeration does not
    // cover — preserved verbatim in every artifact rewrite.
    let extras: Vec<CellResult> = {
        let ids: std::collections::HashSet<String> =
            cells.iter().map(|c| c.id()).collect();
        let mut keep: Vec<CellResult> = prior
            .values()
            .filter(|c| !ids.contains(&c.id))
            .cloned()
            .collect();
        keep.sort_by(|a, b| a.id.cmp(&b.id));
        keep
    };
    let write_checkpoint = |results: &[CellResult]| -> Result<()> {
        let Some(path) = checkpoint else { return Ok(()) };
        if extras.is_empty() {
            artifact::write(path, spec, results)?;
        } else {
            let mut all = Vec::with_capacity(results.len() + extras.len());
            all.extend_from_slice(results);
            all.extend_from_slice(&extras);
            artifact::write(path, spec, &all)?;
        }
        Ok(())
    };

    let mut results: Vec<CellResult> = Vec::with_capacity(cells.len());
    let (mut executed, mut skipped) = (0usize, 0usize);
    // One prepared session at a time; cells are input-major so this is at
    // most one graph generation (and one CSC build + pool spin-up) per
    // input.
    let mut cache: Option<(&'static str, Session)> = None;

    for cell in &cells {
        let id = cell.id();
        if let Some(prev) = prior.get(&id) {
            skipped += 1;
            results.push(prev.clone());
            each(results.last().unwrap(), false);
            continue;
        }
        let needs_build = !matches!(&cache, Some((name, _)) if *name == cell.input);
        if needs_build {
            let g = match graph_cache {
                Some(dir) => {
                    let (g, _hit) = crate::graph::disk::GraphCache::new(dir)?
                        .load_or_build(cell.input, spec.scale_delta, spec.seed)?;
                    g
                }
                None => inputs::build(cell.input, spec.scale_delta, spec.seed)
                    .ok_or_else(|| {
                        anyhow!(
                            "unknown input preset {}; valid presets: {}",
                            cell.input,
                            inputs::preset_names()
                        )
                    })?,
            };
            cache = Some((cell.input, Session::new(g, cell.input, base_config(spec))));
        }
        let (_, session) = cache.as_ref().unwrap();
        let r = run_cell(cell, spec, session)?;
        executed += 1;
        results.push(r);
        each(results.last().unwrap(), true);
        write_checkpoint(&results)?;
    }
    write_checkpoint(&results)?;
    Ok(SweepOutcome { results, executed, skipped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::spec::AppVariant;
    use crate::lb::Balancer;
    use crate::partition::Policy;

    fn tiny_spec() -> CampaignSpec {
        let mut s = CampaignSpec::smoke();
        s.scale_delta = -5;
        s.sim_threads = 2;
        s
    }

    /// Build the per-input session exactly as `run_sweep_cached` does.
    fn session_for(spec: &CampaignSpec, input: &'static str) -> Session {
        let g = inputs::build(input, spec.scale_delta, spec.seed).unwrap();
        Session::new(g, input, base_config(spec))
    }

    #[test]
    fn single_and_distributed_cells_capture_metrics() {
        let spec = tiny_spec();
        let sess = session_for(&spec, "rmat18");
        let single = Cell {
            app: AppVariant::Bfs,
            input: "rmat18",
            balancer: Balancer::Twc,
            policy: None,
            gpus: 1,
            fault: "none",
        };
        let r = run_cell(&single, &spec, &sess).unwrap();
        assert_eq!(r.id, "bfs/rmat18/twc/-/1");
        assert_eq!(r.labels_hash.len(), 16);
        assert!(r.rounds > 0 && r.total_cycles > 0);
        assert!(r.imbalance_factor >= 1.0);
        assert_eq!(r.comm_bytes, 0);

        let dist = Cell { policy: Some(Policy::Cvc), gpus: 4, ..single.clone() };
        let d = run_cell(&dist, &spec, &sess).unwrap();
        assert_eq!(d.id, "bfs/rmat18/twc/cvc/4");
        assert!(d.comm_bytes > 0, "4-GPU bfs must exchange bytes");
        assert_eq!(d.comm_bytes, d.comm_bytes_intra + d.comm_bytes_inter);
        assert_eq!(d.comm_bytes_inter, 0, "single-host cluster is all intra");
        // Labels agree between single and distributed bfs (same fixpoint).
        assert_eq!(r.labels_hash, d.labels_hash);
    }

    #[test]
    fn adaptive_cell_records_controller_columns() {
        let spec = tiny_spec();
        let sess = session_for(&spec, "rmat18");
        let cell = Cell {
            app: AppVariant::Bfs,
            input: "rmat18",
            balancer: Balancer::Adaptive {
                distribution: crate::lb::Distribution::Cyclic,
                threshold: None,
            },
            policy: None,
            gpus: 1,
            fault: "none",
        };
        let ada = run_cell(&cell, &spec, &sess).unwrap();
        assert_eq!(ada.id, "bfs/rmat18/adaptive/-/1");
        assert!(ada.adaptive_threshold_final > 0, "adaptive cells record the final threshold");

        let twc = run_cell(
            &Cell { balancer: Balancer::Twc, ..cell.clone() },
            &spec,
            &sess,
        )
        .unwrap();
        assert_eq!(twc.adaptive_threshold_final, 0, "static cells record 0");
        assert_eq!(twc.lb_rounds, 0, "TWC never launches the LB kernel");

        // `auto` keeps its id/name but resolves to a concrete strategy —
        // the labels must match any other balancer's fixpoint.
        let auto = run_cell(
            &Cell { balancer: Balancer::Auto, ..cell },
            &spec,
            &sess,
        )
        .unwrap();
        assert_eq!(auto.id, "bfs/rmat18/auto/-/1");
        assert_eq!(auto.balancer, "auto");
        assert_eq!(auto.labels_hash, twc.labels_hash);
    }

    #[test]
    fn resume_skips_prior_cells() {
        let mut spec = tiny_spec();
        spec.filter_inputs("road-s").unwrap();
        spec.filter_apps("kcore").unwrap();
        let full = run_sweep(&spec, &HashMap::new(), None, |_, _| {}).unwrap();
        assert_eq!(full.executed, spec.cells().len());
        assert_eq!(full.skipped, 0);

        let prior: HashMap<String, CellResult> = full
            .results
            .iter()
            .map(|r| (r.id.clone(), r.clone()))
            .collect();
        let mut seen_exec = 0;
        let again = run_sweep(&spec, &prior, None, |_, executed| {
            if executed {
                seen_exec += 1;
            }
        })
        .unwrap();
        assert_eq!(again.executed, 0);
        assert_eq!(seen_exec, 0);
        assert_eq!(again.skipped, full.results.len());
        assert_eq!(again.results, full.results, "resume carries results verbatim");
    }

    #[test]
    fn narrowed_resume_preserves_out_of_filter_cells() {
        // Regression: resuming a recorded artifact with a NARROWER filter
        // must not rewrite away the cells outside the filter.
        let mut spec = tiny_spec();
        spec.filter_inputs("road-s").unwrap();
        spec.filter_apps("kcore").unwrap();
        let path = std::env::temp_dir()
            .join(format!("alb-runner-narrow-{}.json", std::process::id()));
        let full = run_sweep(&spec, &HashMap::new(), Some(&path), |_, _| {}).unwrap();
        let n_all = full.results.len();

        let prior: HashMap<String, CellResult> = full
            .results
            .iter()
            .map(|r| (r.id.clone(), r.clone()))
            .collect();
        let mut narrow = spec.clone();
        narrow.filter_balancers("twc").unwrap();
        let n_narrow = narrow.cells().len();
        assert!(n_narrow < n_all);
        let out = run_sweep(&narrow, &prior, Some(&path), |_, _| {}).unwrap();
        assert_eq!(out.results.len(), n_narrow);

        let reread = artifact::read(&path).unwrap();
        assert_eq!(reread.cells.len(), n_all, "out-of-filter cells were dropped");
        let mut want: Vec<String> = full.results.iter().map(|r| r.id.clone()).collect();
        let mut got: Vec<String> = reread.cells.iter().map(|c| c.id.clone()).collect();
        want.sort();
        got.sort();
        assert_eq!(got, want);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fault_cells_recover_to_the_fault_free_labels() {
        let spec = tiny_spec();
        let sess = session_for(&spec, "road-s");
        let clean = Cell {
            app: AppVariant::Bfs,
            input: "road-s",
            balancer: Balancer::Twc,
            policy: Some(Policy::Cvc),
            gpus: 4,
            fault: "none",
        };
        let base = run_cell(&clean, &spec, &sess).unwrap();
        assert!(base.converged);
        assert_eq!((base.fault.as_str(), base.recoveries, base.retry_count), ("none", 0, 0));

        for fault in ["gpu-death", "chaos"] {
            let faulty = run_cell(&Cell { fault, ..clean.clone() }, &spec, &sess).unwrap();
            assert_eq!(faulty.id, format!("{}/{fault}", base.id));
            assert_eq!(faulty.fault, fault);
            assert!(faulty.converged);
            assert!(faulty.recoveries >= 1, "{fault} must kill a GPU");
            assert_eq!(
                faulty.labels_hash, base.labels_hash,
                "{fault}: recovered labels must be bit-identical to fault-free"
            );
        }
    }

    #[test]
    fn cached_sweep_matches_uncached_bit_for_bit() {
        // Cold (build + save) and warm (load) cache passes must both match
        // the cache-less sweep on every deterministic field — the CI
        // sweep-smoke byte-diff in miniature.
        let mut spec = tiny_spec();
        spec.filter_inputs("road-s").unwrap();
        spec.filter_apps("bfs").unwrap();
        spec.filter_gpus("1").unwrap();
        let dir = std::env::temp_dir()
            .join(format!("alb-runner-gcache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let plain = run_sweep(&spec, &HashMap::new(), None, |_, _| {}).unwrap();
        let cold =
            run_sweep_cached(&spec, &HashMap::new(), None, Some(&dir), |_, _| {})
                .unwrap();
        let warm =
            run_sweep_cached(&spec, &HashMap::new(), None, Some(&dir), |_, _| {})
                .unwrap();
        let strip = |rs: &[CellResult]| -> Vec<CellResult> {
            rs.iter()
                .map(|r| CellResult { host_ms: 0.0, ..r.clone() })
                .collect()
        };
        assert_eq!(strip(&plain.results), strip(&cold.results));
        assert_eq!(strip(&plain.results), strip(&warm.results));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
