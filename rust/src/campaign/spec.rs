//! Declarative campaign specification and deterministic cell enumeration.
//!
//! A [`CampaignSpec`] names the values each matrix dimension takes; its
//! [`cells`](CampaignSpec::cells) enumeration is the single source of truth
//! for cell ordering — the runner, the artifact, the committed golden, and
//! the resume logic all follow it. Matrix semantics (DESIGN.md §11):
//!
//! * single-GPU cells carry no partition-policy dimension (`policy: None`,
//!   rendered `-` in ids), so the 1-GPU column is not multiplied by the
//!   policy list;
//! * the direction-optimizing bfs and delta-stepping sssp variants are
//!   single-GPU engines (the coordinator's push driver implements the plain
//!   chaotic relaxation), so their multi-GPU cells are skipped rather than
//!   silently running a different algorithm.

use crate::coordinator::ExecMode;
use crate::exec;
use crate::graph::inputs;
use crate::lb::{Balancer, Distribution};
use crate::partition::Policy;

const APPS_HELP: &str = "bfs, bfs-dopt, sssp-delta, pr, kcore";
/// Keep in sync with [`crate::lb::BALANCER_NAMES`] (pinned by a test).
const BALANCERS_HELP: &str =
    "vertex, twc, edge-lb, alb, enterprise, adaptive, auto";
const POLICIES_HELP: &str = "oec, iec, cvc";
const FAULTS_HELP: &str = "none, gpu-death, corrupt, drop, slow, chaos";

/// The fault-plan presets the campaign matrix can enumerate (DESIGN.md
/// §14). Explicit `gpu-death@R:G`-style specs stay a CLI-only affair —
/// axis values must be preset names so cell ids are stable across runs.
pub const FAULT_PRESETS: [&str; 6] =
    ["none", "gpu-death", "corrupt", "drop", "slow", "chaos"];

/// One application *variant*: an [`crate::apps::App`] plus the engine
/// options that change its algorithm (direction-optimizing bfs,
/// delta-stepping sssp). These are the five columns of the campaign
/// matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppVariant {
    Bfs,
    BfsDopt,
    SsspDelta,
    Pr,
    Kcore,
}

/// All variants, in matrix order.
pub const ALL_VARIANTS: [AppVariant; 5] = [
    AppVariant::Bfs,
    AppVariant::BfsDopt,
    AppVariant::SsspDelta,
    AppVariant::Pr,
    AppVariant::Kcore,
];

/// PageRank cells cap their round count like the repro harness does (the
/// tolerance stop usually fires much earlier).
pub const PR_MAX_ROUNDS: u32 = 100;

impl AppVariant {
    pub fn name(&self) -> &'static str {
        match self {
            AppVariant::Bfs => "bfs",
            AppVariant::BfsDopt => "bfs-dopt",
            AppVariant::SsspDelta => "sssp-delta",
            AppVariant::Pr => "pr",
            AppVariant::Kcore => "kcore",
        }
    }

    pub fn parse(s: &str) -> Option<AppVariant> {
        match s {
            "bfs" => Some(AppVariant::Bfs),
            "bfs-dopt" => Some(AppVariant::BfsDopt),
            "sssp-delta" => Some(AppVariant::SsspDelta),
            "pr" => Some(AppVariant::Pr),
            "kcore" => Some(AppVariant::Kcore),
            _ => None,
        }
    }

    /// The underlying application.
    pub fn app(&self) -> crate::apps::App {
        match self {
            AppVariant::Bfs | AppVariant::BfsDopt => crate::apps::App::Bfs,
            AppVariant::SsspDelta => crate::apps::App::Sssp,
            AppVariant::Pr => crate::apps::App::Pr,
            AppVariant::Kcore => crate::apps::App::Kcore,
        }
    }

    /// Whether the multi-GPU coordinator implements this variant; the
    /// matrix skips multi-GPU cells for the single-GPU-only variants.
    pub fn distributed(&self) -> bool {
        matches!(self, AppVariant::Bfs | AppVariant::Pr | AppVariant::Kcore)
    }

    /// Whether the fault-tolerant driver accepts this variant. PageRank is
    /// excluded: its floating-point partial-sum fold is partition-layout-
    /// dependent, so a post-recovery replay is not bit-comparable
    /// (DESIGN.md §14).
    pub fn fault_injectable(&self) -> bool {
        matches!(self, AppVariant::Bfs | AppVariant::Kcore)
    }

    /// The variant's engine options as a typed [`crate::session::RunRequest`]
    /// — the runner layers balancer / cluster / fault fields on top and
    /// executes it through a [`crate::session::Session`], so a campaign
    /// cell and an `alb run` of the same variant resolve their configs
    /// through the identical seam.
    pub fn to_request(&self, sssp_delta: f32) -> crate::session::RunRequest {
        let mut req = crate::session::RunRequest::new(self.app());
        match self {
            AppVariant::Bfs | AppVariant::Kcore => {}
            AppVariant::BfsDopt => req.direction_opt = Some(true),
            AppVariant::SsspDelta => req.sssp_delta = Some(sssp_delta),
            AppVariant::Pr => req.max_rounds = Some(PR_MAX_ROUNDS),
        }
        req
    }
}

/// One cell of the campaign matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub app: AppVariant,
    pub input: &'static str,
    pub balancer: Balancer,
    /// `None` for single-GPU cells (no partitioning dimension).
    pub policy: Option<Policy>,
    pub gpus: u32,
    /// Fault-plan preset ([`FAULT_PRESETS`]); `"none"` for the fault-free
    /// matrix, which keeps legacy ids unchanged.
    pub fault: &'static str,
}

impl Cell {
    /// The cell's stable identifier: `app/input/balancer/policy/gpus`
    /// (policy is `-` for single-GPU cells), with `/fault` appended for
    /// fault-injected cells. Ids key the artifact's resume logic and the
    /// golden comparison; fault-free cells keep their pre-fault-axis ids.
    pub fn id(&self) -> String {
        let base = format!(
            "{}/{}/{}/{}/{}",
            self.app.name(),
            self.input,
            self.balancer.name(),
            self.policy.map(|p| p.name()).unwrap_or("-"),
            self.gpus
        );
        if self.fault == "none" {
            base
        } else {
            format!("{base}/{}", self.fault)
        }
    }

    /// Id of this cell's fault-free twin — the cell the fault gate compares
    /// labels against. Identity for fault-free cells.
    pub fn fault_free_id(&self) -> String {
        Cell { fault: "none", ..self.clone() }.id()
    }
}

/// Declarative sweep specification: dimension values + run parameters.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub apps: Vec<AppVariant>,
    pub inputs: Vec<&'static str>,
    pub balancers: Vec<Balancer>,
    /// Partition policies for multi-GPU cells.
    pub policies: Vec<Policy>,
    pub gpu_counts: Vec<u32>,
    pub scale_delta: i32,
    pub seed: u64,
    /// Delta-stepping bucket width for the `sssp-delta` variant.
    pub sssp_delta: f32,
    pub sim_threads: usize,
    pub exec: ExecMode,
    /// Whether this is the smoke subset (recorded in the artifact; resume
    /// refuses to mix smoke and full artifacts).
    pub smoke: bool,
    /// Fault-plan presets ([`FAULT_PRESETS`]). Defaults to `["none"]`, so
    /// the matrix shape is unchanged unless `--faults` opts in; non-"none"
    /// presets expand only the multi-GPU cells of fault-injectable
    /// variants.
    pub faults: Vec<&'static str>,
}

/// Largest accepted simulated-GPU count (matrix filters reject more).
pub const MAX_GPUS: u32 = 64;

impl CampaignSpec {
    /// The paper's full evaluation matrix (PAPER.md §6): every variant ×
    /// every Table 1 input × every balancer × {oec, iec, cvc} × {1, 4, 8,
    /// 16} GPUs.
    pub fn full() -> CampaignSpec {
        CampaignSpec {
            apps: ALL_VARIANTS.to_vec(),
            inputs: inputs::ALL_INPUTS.to_vec(),
            balancers: all_balancers(),
            policies: vec![Policy::Oec, Policy::Iec, Policy::Cvc],
            gpu_counts: vec![1, 4, 8, 16],
            scale_delta: 0,
            seed: 42,
            sssp_delta: 25.0,
            sim_threads: exec::default_threads(),
            exec: ExecMode::Parallel,
            smoke: false,
            faults: vec!["none"],
        }
    }

    /// The CI smoke subset: one power-law and one road input, the paper's
    /// headline strategies (TWC vs ALB), CVC at 4 GPUs. Small enough for a
    /// release-mode CI job, diverse enough to pin every engine driver and
    /// the coordinator. The committed `CAMPAIGN.golden.json` mirrors this
    /// enumeration exactly.
    pub fn smoke() -> CampaignSpec {
        let alb = Balancer::Alb { distribution: Distribution::Cyclic, threshold: None };
        CampaignSpec {
            apps: ALL_VARIANTS.to_vec(),
            inputs: vec!["rmat18", "road-s"],
            balancers: vec![Balancer::Twc, alb],
            policies: vec![Policy::Cvc],
            gpu_counts: vec![1, 4],
            smoke: true,
            ..CampaignSpec::full()
        }
    }

    /// Enumerate the matrix in the canonical deterministic order:
    /// input-major (so the runner builds each graph once), then app,
    /// balancer, GPU count, and policy innermost.
    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::new();
        for &input in &self.inputs {
            for &app in &self.apps {
                for b in &self.balancers {
                    for &gpus in &self.gpu_counts {
                        self.push_cells(&mut out, app, input, b, gpus);
                    }
                }
            }
        }
        out
    }

    /// One (app, input, balancer, gpus) point expanded into cells
    /// (single-GPU: no policy dimension; multi-GPU: one per policy,
    /// skipped entirely for single-GPU-only variants).
    fn push_cells(
        &self,
        out: &mut Vec<Cell>,
        app: AppVariant,
        input: &'static str,
        b: &Balancer,
        gpus: u32,
    ) {
        if gpus <= 1 {
            let balancer = b.clone();
            out.push(Cell { app, input, balancer, policy: None, gpus: 1, fault: "none" });
            return;
        }
        if !app.distributed() {
            return;
        }
        for &p in &self.policies {
            for &fault in &self.faults {
                // The fault axis only multiplies cells the fault-tolerant
                // driver accepts; other (app, fault) points are skipped, not
                // errors, so `--faults none,chaos` still covers pr fault-free.
                if fault != "none" && !app.fault_injectable() {
                    continue;
                }
                let (balancer, policy) = (b.clone(), Some(p));
                out.push(Cell { app, input, balancer, policy, gpus, fault });
            }
        }
    }

    /// Restrict the app dimension to a comma-separated list of variant
    /// names. Unknown names are a CLI-grade error listing the valid set.
    pub fn filter_apps(&mut self, csv: &str) -> Result<(), String> {
        let mut keep = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let v = AppVariant::parse(name).ok_or_else(|| {
                format!("unknown app {name:?} in --apps; valid values: {APPS_HELP}")
            })?;
            if !keep.contains(&v) {
                keep.push(v);
            }
        }
        if keep.is_empty() {
            return Err(format!("--apps selected nothing; valid values: {APPS_HELP}"));
        }
        self.apps = keep;
        Ok(())
    }

    /// Restrict the input dimension (Table 1 presets, plus the opt-in
    /// oversize [`inputs::EXTRA_INPUTS`] — accepted here so `--inputs
    /// rmat24` works, but never part of the default matrix).
    pub fn filter_inputs(&mut self, csv: &str) -> Result<(), String> {
        let valid = || {
            inputs::ALL_INPUTS
                .iter()
                .chain(inputs::EXTRA_INPUTS.iter())
                .copied()
                .collect::<Vec<_>>()
                .join(", ")
        };
        let mut keep: Vec<&'static str> = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let preset = inputs::ALL_INPUTS
                .iter()
                .chain(inputs::EXTRA_INPUTS.iter())
                .find(|&&p| p == name)
                .copied()
                .ok_or_else(|| {
                    format!(
                        "unknown input {name:?} in --inputs; valid values: {}",
                        valid()
                    )
                })?;
            if !keep.contains(&preset) {
                keep.push(preset);
            }
        }
        if keep.is_empty() {
            return Err(format!(
                "--inputs selected nothing; valid values: {}",
                valid()
            ));
        }
        self.inputs = keep;
        Ok(())
    }

    /// Restrict the balancer dimension by strategy name.
    pub fn filter_balancers(&mut self, csv: &str) -> Result<(), String> {
        let mut keep: Vec<Balancer> = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let b = Balancer::parse(name).ok_or_else(|| {
                format!(
                    "unknown balancer {name:?} in --balancers; valid values: \
                     {BALANCERS_HELP}"
                )
            })?;
            if !keep.contains(&b) {
                keep.push(b);
            }
        }
        if keep.is_empty() {
            return Err(format!("--balancers selected nothing; valid values: {BALANCERS_HELP}"));
        }
        self.balancers = keep;
        Ok(())
    }

    /// Restrict the partition-policy dimension (multi-GPU cells only).
    pub fn filter_policies(&mut self, csv: &str) -> Result<(), String> {
        let mut keep: Vec<Policy> = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let p = Policy::parse(name).ok_or_else(|| {
                format!("unknown policy {name:?} in --policies; valid values: {POLICIES_HELP}")
            })?;
            if !keep.contains(&p) {
                keep.push(p);
            }
        }
        if keep.is_empty() {
            return Err(format!("--policies selected nothing; valid values: {POLICIES_HELP}"));
        }
        self.policies = keep;
        Ok(())
    }

    /// Restrict the GPU-count dimension. Values must be in `1..=`
    /// [`MAX_GPUS`].
    pub fn filter_gpus(&mut self, csv: &str) -> Result<(), String> {
        let mut keep: Vec<u32> = Vec::new();
        for tok in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let k: u32 = tok.parse().map_err(|_| {
                format!("invalid GPU count {tok:?} in --gpus; valid range: 1..={MAX_GPUS}")
            })?;
            if k == 0 || k > MAX_GPUS {
                return Err(format!("invalid GPU count {k} in --gpus; range: 1..={MAX_GPUS}"));
            }
            if !keep.contains(&k) {
                keep.push(k);
            }
        }
        if keep.is_empty() {
            return Err(format!("--gpus selected nothing; valid range: 1..={MAX_GPUS}"));
        }
        self.gpu_counts = keep;
        Ok(())
    }

    /// Restrict (or expand) the fault-plan axis to a comma-separated list
    /// of [`FAULT_PRESETS`] names.
    pub fn filter_faults(&mut self, csv: &str) -> Result<(), String> {
        let mut keep: Vec<&'static str> = Vec::new();
        for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let preset = FAULT_PRESETS
                .iter()
                .find(|&&p| p == name)
                .copied()
                .ok_or_else(|| {
                    format!("unknown fault {name:?} in --faults; valid values: {FAULTS_HELP}")
                })?;
            if !keep.contains(&preset) {
                keep.push(preset);
            }
        }
        if keep.is_empty() {
            return Err(format!("--faults selected nothing; valid values: {FAULTS_HELP}"));
        }
        self.faults = keep;
        Ok(())
    }
}

/// Every campaign-enumerable `Balancer`, cyclic defaults, in CLI order.
/// `auto` is deliberately absent: it is a meta-strategy that *resolves to*
/// one of these per (app, input) — putting it in the matrix would duplicate
/// whichever cell it resolves to under a second id.
pub fn all_balancers() -> Vec<Balancer> {
    vec![
        Balancer::Vertex,
        Balancer::Twc,
        Balancer::EdgeLb { distribution: Distribution::Cyclic },
        Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
        Balancer::Enterprise,
        Balancer::Adaptive { distribution: Distribution::Cyclic, threshold: None },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn smoke_matrix_shape() {
        let cells = CampaignSpec::smoke().cells();
        // Per input: bfs/pr/kcore get 2 balancers x (1 single + 1x cvc@4)
        // = 4 cells each; bfs-dopt and sssp-delta are single-GPU only
        // = 2 cells each. 3*4 + 2*2 = 16 per input, two inputs.
        assert_eq!(cells.len(), 32);
        let ids: HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), cells.len(), "cell ids must be unique");
        assert!(ids.contains("bfs/rmat18/alb/cvc/4"));
        assert!(ids.contains("bfs/rmat18/alb/-/1"));
        assert!(!ids.contains("bfs-dopt/rmat18/alb/cvc/4"), "dopt is single-GPU only");
    }

    #[test]
    fn full_matrix_shape() {
        let cells = CampaignSpec::full().cells();
        // Per input: distributed-capable variants (bfs, pr, kcore) get
        // 6 balancers x (1 + 3 gpu counts x 3 policies) = 60; the two
        // single-GPU variants get 6 each. (3*60 + 2*6) * 8 inputs.
        assert_eq!(cells.len(), (3 * 60 + 2 * 6) * 8);
    }

    #[test]
    fn balancers_help_matches_parseable_names() {
        // The CLI error text must list exactly what Balancer::parse accepts.
        assert_eq!(BALANCERS_HELP, crate::lb::BALANCER_NAMES.join(", "));
    }

    #[test]
    fn auto_is_filterable_but_not_enumerated() {
        // `auto` parses (so --balancers auto works) but never appears in
        // the default matrix axes — it resolves to a concrete strategy.
        let mut s = CampaignSpec::smoke();
        s.filter_balancers("auto").unwrap();
        assert_eq!(s.balancers, vec![Balancer::Auto]);
        assert!(!all_balancers().contains(&Balancer::Auto));
        assert!(all_balancers().contains(&Balancer::Adaptive {
            distribution: Distribution::Cyclic,
            threshold: None,
        }));
    }

    #[test]
    fn enumeration_is_deterministic() {
        let a = CampaignSpec::smoke().cells();
        let b = CampaignSpec::smoke().cells();
        assert_eq!(a, b);
        // Input-major ordering: all rmat18 cells precede all road-s cells.
        let last_rmat = a.iter().rposition(|c| c.input == "rmat18").unwrap();
        let first_road = a.iter().position(|c| c.input == "road-s").unwrap();
        assert!(last_rmat < first_road);
    }

    #[test]
    fn filters_narrow_and_reject() {
        let mut s = CampaignSpec::smoke();
        s.filter_apps("bfs, kcore").unwrap();
        assert_eq!(s.apps, vec![AppVariant::Bfs, AppVariant::Kcore]);
        s.filter_inputs("road-s").unwrap();
        assert_eq!(s.inputs, vec!["road-s"]);
        s.filter_balancers("alb").unwrap();
        assert_eq!(s.balancers.len(), 1);
        s.filter_policies("oec,cvc").unwrap();
        assert_eq!(s.policies.len(), 2);
        s.filter_gpus("1,4,4").unwrap();
        assert_eq!(s.gpu_counts, vec![1, 4]);

        assert!(s.filter_apps("bogus").unwrap_err().contains("bfs-dopt"));
        assert!(s.filter_inputs("nope").unwrap_err().contains("rmat18"));
        assert!(
            s.filter_inputs("nope").unwrap_err().contains("rmat24"),
            "error must list the opt-in extras too"
        );
        s.filter_inputs("rmat24").unwrap();
        assert_eq!(s.inputs, vec!["rmat24"]);
        s.filter_inputs("road-s").unwrap();
        assert!(s.filter_balancers("nope").unwrap_err().contains("enterprise"));
        assert!(s.filter_balancers("nope").unwrap_err().contains("adaptive"));
        assert!(s.filter_balancers("nope").unwrap_err().contains("auto"));
        assert!(s.filter_policies("nope").unwrap_err().contains("cvc"));
        assert!(s.filter_gpus("0").unwrap_err().contains("1..="));
        assert!(s.filter_gpus("abc").unwrap_err().contains("1..="));
        assert!(s.filter_gpus("65").unwrap_err().contains("1..="));
    }

    #[test]
    fn fault_axis_expands_only_injectable_multi_gpu_cells() {
        let mut s = CampaignSpec::smoke();
        let base = s.cells().len();
        s.filter_faults("none,chaos").unwrap();
        let cells = s.cells();
        // Per input: chaos twins exist only for bfs and kcore at cvc@4 with
        // each of the 2 balancers = 4 extra cells per input.
        assert_eq!(cells.len(), base + 2 * 4);
        let ids: HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert!(ids.contains("bfs/rmat18/alb/cvc/4/chaos"));
        assert!(ids.contains("kcore/road-s/twc/cvc/4/chaos"));
        assert!(!ids.contains("pr/rmat18/alb/cvc/4/chaos"), "pr is fault-excluded");
        assert!(!ids.contains("bfs/rmat18/alb/-/1/chaos"), "single-GPU cells stay fault-free");
        // Fault-free ids are unchanged, and each faulty cell knows its twin.
        assert!(ids.contains("bfs/rmat18/alb/cvc/4"));
        let chaos = cells.iter().find(|c| c.fault == "chaos").unwrap();
        assert_eq!(chaos.fault_free_id(), chaos.id().trim_end_matches("/chaos"));
    }

    #[test]
    fn fault_filter_rejects_unknown_and_presets_all_parse() {
        let mut s = CampaignSpec::smoke();
        assert!(s.filter_faults("bogus").unwrap_err().contains("gpu-death"));
        assert!(s.filter_faults("").unwrap_err().contains("selected nothing"));
        s.filter_faults("gpu-death, gpu-death,drop").unwrap();
        assert_eq!(s.faults, vec!["gpu-death", "drop"]);
        // Every enumerable preset must be accepted by the CLI-level parser
        // the runner hands it to.
        for p in FAULT_PRESETS {
            crate::comm::fault::FaultPlan::parse(p, 4, 42)
                .unwrap_or_else(|e| panic!("preset {p} must parse: {e}"));
        }
    }

    #[test]
    fn variant_wiring() {
        assert_eq!(AppVariant::parse("bfs-dopt"), Some(AppVariant::BfsDopt));
        assert_eq!(AppVariant::parse("cc"), None);
        assert!(AppVariant::Bfs.distributed());
        assert!(!AppVariant::SsspDelta.distributed());
        let req = AppVariant::SsspDelta.to_request(25.0);
        assert_eq!(req.sssp_delta, Some(25.0));
        assert_eq!(req.app, crate::apps::App::Sssp);
        let req = AppVariant::BfsDopt.to_request(25.0);
        assert_eq!(req.direction_opt, Some(true));
        let req = AppVariant::Pr.to_request(25.0);
        assert_eq!(req.max_rounds, Some(PR_MAX_ROUNDS));
        assert_eq!(AppVariant::Kcore.to_request(25.0).max_rounds, None);
    }
}
