//! The `CAMPAIGN.json` artifact: deterministic writer, resumable reader,
//! and the golden comparison behind CI's `sweep-smoke` gate.
//!
//! Schema (version 1) — all keys sorted (the [`Json`] writer uses a
//! BTreeMap), so output is byte-deterministic given the same results:
//!
//! ```json
//! {
//!   "campaign": "sweep",
//!   "cells": [
//!     {
//!       "adaptive_threshold_final": 0, "app": "bfs", "balancer": "alb",
//!       "comm_bytes": 0, "comm_bytes_inter": 0, "comm_bytes_intra": 0,
//!       "converged": true, "fault": "none", "gpus": 1, "host_ms": 12.5,
//!       "id": "bfs/rmat18/alb/-/1", "imbalance_factor": 3.5,
//!       "input": "rmat18", "labels_hash": "0123456789abcdef",
//!       "lb_rounds": 2, "policy": "-", "recoveries": 0,
//!       "replayed_rounds": 0, "retry_count": 0, "rounds": 17,
//!       "simulated_ms": 1.25, "total_cycles": 123456
//!     }
//!   ],
//!   "scale_delta": 0, "schema_version": 1, "seed": 42, "smoke": true
//! }
//! ```
//!
//! The reader is a line scanner matched to our own writer (same approach
//! as [`crate::metrics::bench::read_json`]): within a cell object the
//! sorted keys end at `total_cycles`, which closes the record. Top-level
//! and cell key sets are disjoint, so no nesting state is needed.
//!
//! Every numeric field except `host_ms` is a simulation output and
//! bit-deterministic; `labels_hash` is the golden-comparison key. Cycle
//! counts are stored through f64 (exact below 2^53 — far above any
//! simulated run).

use std::io;
use std::path::Path;

use crate::metrics::Json;

use super::runner::CellResult;
use super::spec::CampaignSpec;

pub const SCHEMA_VERSION: u64 = 1;

/// A parsed `CAMPAIGN.json` (artifact or golden).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CampaignFile {
    pub schema_version: u64,
    pub seed: u64,
    pub scale_delta: i64,
    pub smoke: bool,
    pub cells: Vec<CellResult>,
}

impl CampaignFile {
    /// Resume compatibility: an artifact written under a different seed,
    /// scale, or smoke flag must not silently seed a resume.
    pub fn matches_spec(&self, spec: &CampaignSpec) -> bool {
        self.schema_version == SCHEMA_VERSION
            && self.seed == spec.seed
            && self.scale_delta == spec.scale_delta as i64
            && self.smoke == spec.smoke
    }
}

fn cell_json(c: &CellResult) -> Json {
    Json::obj()
        .set("adaptive_threshold_final", c.adaptive_threshold_final)
        .set("app", c.app.as_str())
        .set("balancer", c.balancer.as_str())
        .set("comm_bytes", c.comm_bytes)
        .set("comm_bytes_inter", c.comm_bytes_inter)
        .set("comm_bytes_intra", c.comm_bytes_intra)
        .set("converged", c.converged)
        .set("fault", c.fault.as_str())
        .set("gpus", c.gpus)
        .set("host_ms", c.host_ms)
        .set("id", c.id.as_str())
        .set("imbalance_factor", c.imbalance_factor)
        .set("input", c.input.as_str())
        .set("labels_hash", c.labels_hash.as_str())
        .set("lb_rounds", c.lb_rounds)
        .set("policy", c.policy.as_str())
        .set("recoveries", c.recoveries)
        .set("replayed_rounds", c.replayed_rounds)
        .set("retry_count", c.retry_count)
        .set("rounds", c.rounds)
        .set("simulated_ms", c.simulated_ms)
        .set("total_cycles", c.total_cycles)
}

/// Build the artifact document.
pub fn to_json(spec: &CampaignSpec, cells: &[CellResult]) -> Json {
    Json::obj()
        .set("campaign", "sweep")
        .set("cells", Json::Arr(cells.iter().map(cell_json).collect()))
        .set("scale_delta", spec.scale_delta as i64)
        .set("schema_version", SCHEMA_VERSION)
        .set("seed", spec.seed)
        .set("smoke", spec.smoke)
}

/// Write the artifact (pretty-printed, trailing newline).
pub fn write(path: &Path, spec: &CampaignSpec, cells: &[CellResult]) -> io::Result<()> {
    let mut s = to_json(spec, cells).to_string_pretty();
    s.push('\n');
    std::fs::write(path, s)
}

/// Read an artifact back. Unknown keys are ignored; a malformed file
/// yields a `CampaignFile` that fails [`CampaignFile::matches_spec`].
pub fn read(path: &Path) -> io::Result<CampaignFile> {
    Ok(parse(&std::fs::read_to_string(path)?))
}

/// Parse the writer's output (line scanner; see module docs).
pub fn parse(text: &str) -> CampaignFile {
    let mut file = CampaignFile::default();
    let mut cur = CellResult::default();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some((key, value)) = line.split_once(':') else { continue };
        let key = key.trim().trim_matches('"');
        let value = value.trim();
        let unquoted = || value.trim_matches('"').to_string();
        match key {
            // top level
            "schema_version" => file.schema_version = value.parse().unwrap_or(0),
            "seed" => file.seed = value.parse().unwrap_or(0),
            "scale_delta" => file.scale_delta = value.parse().unwrap_or(0),
            "smoke" => file.smoke = value == "true",
            // cell fields (sorted; total_cycles closes the record)
            "adaptive_threshold_final" => {
                cur.adaptive_threshold_final = value.parse().unwrap_or(0)
            }
            "app" => cur.app = unquoted(),
            "balancer" => cur.balancer = unquoted(),
            "comm_bytes" => cur.comm_bytes = value.parse().unwrap_or(0),
            "comm_bytes_inter" => cur.comm_bytes_inter = value.parse().unwrap_or(0),
            "comm_bytes_intra" => cur.comm_bytes_intra = value.parse().unwrap_or(0),
            "converged" => cur.converged = value == "true",
            "fault" => cur.fault = unquoted(),
            "gpus" => cur.gpus = value.parse().unwrap_or(0),
            "host_ms" => cur.host_ms = value.parse().unwrap_or(0.0),
            "id" => cur.id = unquoted(),
            "imbalance_factor" => cur.imbalance_factor = value.parse().unwrap_or(0.0),
            "input" => cur.input = unquoted(),
            "labels_hash" => cur.labels_hash = unquoted(),
            "lb_rounds" => cur.lb_rounds = value.parse().unwrap_or(0),
            "policy" => cur.policy = unquoted(),
            "recoveries" => cur.recoveries = value.parse().unwrap_or(0),
            "replayed_rounds" => cur.replayed_rounds = value.parse().unwrap_or(0),
            "retry_count" => cur.retry_count = value.parse().unwrap_or(0),
            "rounds" => cur.rounds = value.parse().unwrap_or(0),
            "simulated_ms" => cur.simulated_ms = value.parse().unwrap_or(0.0),
            "total_cycles" => {
                cur.total_cycles = value.parse().unwrap_or(0);
                file.cells.push(std::mem::take(&mut cur));
            }
            _ => {}
        }
    }
    file
}

/// What a golden comparison found.
#[derive(Debug, Clone, PartialEq)]
pub struct GoldenReport {
    /// Cells whose non-empty golden hash was compared (and matched).
    pub seeded: usize,
    /// Golden cells whose `labels_hash` is still empty.
    pub unseeded: usize,
}

/// Compare sweep results against a golden artifact.
///
/// * the ordered cell-id lists must match exactly (the golden pins the
///   matrix enumeration itself);
/// * every golden cell with a non-empty `labels_hash` must match the
///   produced hash;
/// * a golden with *zero* seeded hashes is a LOUD error (the gate must
///   never silently pass unarmed) — the message carries the seeding
///   recipe, mirroring the bench gate's empty-baseline policy.
pub fn check_golden(
    results: &[CellResult],
    golden: &CampaignFile,
    golden_path: &str,
) -> Result<GoldenReport, String> {
    let got: Vec<&str> = results.iter().map(|c| c.id.as_str()).collect();
    let want: Vec<&str> = golden.cells.iter().map(|c| c.id.as_str()).collect();
    if got != want {
        let diverge = got
            .iter()
            .zip(&want)
            .position(|(a, b)| a != b)
            .unwrap_or(got.len().min(want.len()));
        return Err(format!(
            "GOLDEN MATRIX MISMATCH: produced {} cells, {golden_path} lists {} \
             (first divergence at index {diverge}: produced {:?}, golden {:?}). \
             The golden pins the smoke enumeration — regenerate it from a fresh \
             `alb sweep --smoke` artifact if the matrix changed intentionally.",
            got.len(),
            want.len(),
            got.get(diverge).copied().unwrap_or("<none>"),
            want.get(diverge).copied().unwrap_or("<none>"),
        ));
    }
    let mut report = GoldenReport { seeded: 0, unseeded: 0 };
    let mut mismatches = Vec::new();
    for (r, g) in results.iter().zip(&golden.cells) {
        if g.labels_hash.is_empty() {
            report.unseeded += 1;
        } else if g.labels_hash == r.labels_hash {
            report.seeded += 1;
        } else {
            mismatches.push(format!(
                "  {}: produced {} vs golden {}",
                r.id, r.labels_hash, g.labels_hash
            ));
        }
    }
    if !mismatches.is_empty() {
        return Err(format!(
            "GOLDEN HASH MISMATCH ({} cells):\n{}",
            mismatches.len(),
            mismatches.join("\n")
        ));
    }
    if report.seeded == 0 {
        return Err(format!(
            "UNSEEDED GOLDEN: {golden_path} lists the matrix but no \
             labels-hashes, so the value gate cannot run. To seed it, commit \
             exactly one artifact:\n\
             1. open any CI run's `sweep-smoke` job and download the \
             `CAMPAIGN` artifact (it contains `CAMPAIGN.ci.json`);\n\
             2. `cp CAMPAIGN.ci.json {golden_path}`\n\
             3. `git add {golden_path}` and commit.\n\
             (Equivalently, run `alb sweep --smoke --resume false --out \
             {golden_path}` anywhere — hashes are machine-independent.)"
        ));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_cells() -> Vec<CellResult> {
        vec![
            CellResult {
                id: "bfs/rmat18/twc/-/1".into(),
                app: "bfs".into(),
                input: "rmat18".into(),
                balancer: "twc".into(),
                policy: "-".into(),
                gpus: 1,
                labels_hash: "00112233aabbccdd".into(),
                rounds: 9,
                total_cycles: 123_456,
                imbalance_factor: 2.5,
                comm_bytes: 0,
                comm_bytes_intra: 0,
                comm_bytes_inter: 0,
                simulated_ms: 0.75,
                host_ms: 10.25,
                adaptive_threshold_final: 3072,
                lb_rounds: 2,
                ..CellResult::default()
            },
            // A fault-injected cell: every recovery field non-default so
            // the roundtrip test covers the fault columns.
            CellResult {
                id: "bfs/rmat18/twc/cvc/4/chaos".into(),
                app: "bfs".into(),
                input: "rmat18".into(),
                balancer: "twc".into(),
                policy: "cvc".into(),
                gpus: 4,
                labels_hash: "00112233aabbccdd".into(),
                rounds: 11,
                total_cycles: 98_765,
                imbalance_factor: 1.25,
                comm_bytes: 4096,
                comm_bytes_intra: 4096,
                comm_bytes_inter: 0,
                simulated_ms: 0.5,
                host_ms: 20.5,
                adaptive_threshold_final: 0,
                lb_rounds: 0,
                converged: false,
                fault: "chaos".into(),
                recoveries: 1,
                replayed_rounds: 2,
                retry_count: 3,
            },
        ]
    }

    #[test]
    fn roundtrip_is_lossless() {
        let spec = CampaignSpec::smoke();
        let cells = sample_cells();
        let text = to_json(&spec, &cells).to_string_pretty();
        let parsed = parse(&text);
        assert_eq!(parsed.schema_version, SCHEMA_VERSION);
        assert_eq!(parsed.seed, spec.seed);
        assert_eq!(parsed.scale_delta, spec.scale_delta as i64);
        assert!(parsed.smoke);
        assert_eq!(parsed.cells, cells);
        assert!(parsed.matches_spec(&spec));
        // Reserialization is byte-identical (determinism backbone).
        assert_eq!(to_json(&spec, &parsed.cells).to_string_pretty(), text);
    }

    #[test]
    fn spec_fingerprint_guards_resume() {
        let spec = CampaignSpec::smoke();
        let parsed = parse(&to_json(&spec, &[]).to_string_pretty());
        let mut other = spec.clone();
        other.seed = 7;
        assert!(!parsed.matches_spec(&other));
        let mut other = spec.clone();
        other.scale_delta = -2;
        assert!(!parsed.matches_spec(&other));
        let mut other = spec.clone();
        other.smoke = false;
        assert!(!parsed.matches_spec(&other));
    }

    #[test]
    fn golden_check_modes() {
        let cells = sample_cells();
        let mut golden = CampaignFile {
            schema_version: SCHEMA_VERSION,
            seed: 42,
            scale_delta: 0,
            smoke: true,
            cells: cells.clone(),
        };
        // Fully seeded: both compared, no unseeded.
        let rep = check_golden(&cells, &golden, "G").unwrap();
        assert_eq!(rep, GoldenReport { seeded: 2, unseeded: 0 });
        // Partially seeded still passes.
        golden.cells[1].labels_hash = String::new();
        let rep = check_golden(&cells, &golden, "G").unwrap();
        assert_eq!(rep, GoldenReport { seeded: 1, unseeded: 1 });
        // Entirely unseeded is a loud error with the seeding recipe.
        golden.cells[0].labels_hash = String::new();
        let err = check_golden(&cells, &golden, "G").unwrap_err();
        assert!(err.contains("UNSEEDED GOLDEN"), "{err}");
        assert!(err.contains("CAMPAIGN.ci.json"), "{err}");
        // Hash mismatch names the cell.
        golden.cells[0].labels_hash = "ffffffffffffffff".into();
        let err = check_golden(&cells, &golden, "G").unwrap_err();
        assert!(err.contains("GOLDEN HASH MISMATCH"), "{err}");
        assert!(err.contains("bfs/rmat18/twc/-/1"), "{err}");
        // Matrix drift names the first divergence.
        golden.cells.pop();
        let err = check_golden(&cells, &golden, "G").unwrap_err();
        assert!(err.contains("GOLDEN MATRIX MISMATCH"), "{err}");
    }
}
