//! The scenario-matrix campaign runner (`alb sweep`, DESIGN.md §11).
//!
//! The paper's contribution is validated by an evaluation *matrix* — five
//! application variants × the Table 1 inputs × every load-balancing
//! strategy × partition policy × GPU count (§6) — and this module turns
//! that matrix into a first-class enumerable surface instead of a pile of
//! ad-hoc `alb run` invocations:
//!
//! * [`spec`] — the declarative [`CampaignSpec`]: which values each
//!   dimension takes, CLI-grade filters, the `--smoke` subset, and the
//!   deterministic [`Cell`] enumeration order;
//! * [`runner`] — executes cells on the shared [`crate::exec::Pool`]
//!   machinery (single-GPU cells through [`crate::apps::engine::run`],
//!   multi-GPU cells through [`crate::coordinator::run_distributed`]) and
//!   captures each cell's labels-hash, total cycles, imbalance factor and
//!   communication volume into a [`CellResult`];
//! * [`artifact`] — the machine-readable `CAMPAIGN.json` schema
//!   (deterministic sorted-key output, resumable line-scanner reader, and
//!   the golden-comparison used by CI's `sweep-smoke` gate).
//!
//! Every recorded quantity except `host_ms` is a simulation output —
//! bit-deterministic for any pool width and exec mode — so campaign
//! artifacts are comparable across machines, and the committed
//! `CAMPAIGN.golden.json` plus [`crate::repro::check_campaign_invariants`]
//! give every future PR a whole-matrix regression oracle.

pub mod artifact;
pub mod runner;
pub mod spec;

pub use artifact::{check_golden, CampaignFile, GoldenReport};
pub use runner::{run_sweep, run_sweep_cached, CellResult, SweepOutcome};
pub use spec::{AppVariant, CampaignSpec, Cell, ALL_VARIANTS};
