//! Single-source shortest path (push-style): the paper's running example
//! (Fig. 2). Data-driven Bellman-Ford / chaotic relaxation over the min-plus
//! semiring with the graph's edge weights.

use crate::graph::CsrGraph;

use super::INF;

/// Per-edge relax weight: the edge's own weight.
#[inline]
pub fn relax_weight(edge_weight: f32) -> f32 {
    edge_weight
}

/// Initial labels: `src = 0`, everything else unreached.
pub fn init_labels(n: usize, src: u32) -> Vec<f32> {
    let mut l = vec![INF; n];
    l[src as usize] = 0.0;
    l
}

/// Serial reference Dijkstra (oracle for engine tests). Weights must be
/// non-negative, which all generators guarantee.
pub fn oracle(g: &CsrGraph, src: u32) -> Vec<f32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut dist = vec![INF; g.num_vertices()];
    let mut heap: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    dist[src as usize] = 0.0;
    heap.push(Reverse((0, src)));
    while let Some(Reverse((d_bits, v))) = heap.pop() {
        let d = d_bits as f32;
        if d > dist[v as usize] {
            continue;
        }
        let (dsts, ws) = g.out_edges(v);
        for (&u, &w) in dsts.iter().zip(ws) {
            let cand = d + w;
            if cand < dist[u as usize] {
                dist[u as usize] = cand;
                // Integer weights => exact f32 -> u64 keying.
                heap.push(Reverse((cand as u64, u)));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn oracle_prefers_cheaper_path() {
        // 0 -> 1 (10), 0 -> 2 (1), 2 -> 1 (2): best 0->1 is 3.
        let mut el = EdgeList::new(3);
        el.push(0, 1, 10.0);
        el.push(0, 2, 1.0);
        el.push(2, 1, 2.0);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(oracle(&g, 0), vec![0.0, 3.0, 1.0]);
    }

    #[test]
    fn weight_passthrough() {
        assert_eq!(relax_weight(7.5), 7.5);
    }

    #[test]
    fn disconnected_is_inf() {
        let el = EdgeList::new(2);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(oracle(&g, 0)[1], INF);
    }

    #[test]
    fn oracle_matches_bfs_on_unit_weights() {
        use crate::graph::gen::rmat::{self, RmatConfig};
        let mut cfg = RmatConfig::paper(8, 3);
        cfg.max_weight = 1;
        let el = rmat::generate(&cfg);
        let g = CsrGraph::from_edge_list(&el);
        let s = oracle(&g, 0);
        let b = crate::apps::bfs::oracle(&g, 0);
        assert_eq!(s, b);
    }
}
