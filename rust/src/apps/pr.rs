//! PageRank, pull-style (paper §5: tolerance 1e-6, run to convergence).
//!
//! Each round every vertex gathers damped contributions `d * rank(u) /
//! out_degree(u)` from its in-neighbors — the operator reads *incoming*
//! edges, which is why pr never trips ALB's huge bin on the rmat inputs
//! (in-degree skew is mild; §6.1).

use crate::graph::CsrGraph;

pub const DAMPING: f32 = 0.85;
pub const DEFAULT_TOL: f32 = 1e-6;

/// Initial rank: uniform.
pub fn init_ranks(n: usize) -> Vec<f32> {
    vec![1.0 / n as f32; n]
}

/// One pull round from `ranks` (contributions precomputed by caller or
/// kernel): returns (new_ranks, max |delta|).
pub fn pull_round(g: &CsrGraph, ranks: &[f32], contrib: &[f32]) -> (Vec<f32>, f32) {
    let n = g.num_vertices();
    let base = (1.0 - DAMPING) / n as f32;
    let mut new_ranks = vec![0f32; n];
    let mut max_delta = 0f32;
    for v in 0..n as u32 {
        let (srcs, _) = g.in_edges(v);
        let mut acc = 0f32;
        for &u in srcs {
            acc += contrib[u as usize];
        }
        let r = base + acc;
        max_delta = max_delta.max((r - ranks[v as usize]).abs());
        new_ranks[v as usize] = r;
    }
    (new_ranks, max_delta)
}

/// Per-vertex contribution (native twin of the `pr_pull` Pallas kernel).
pub fn contributions(g: &CsrGraph, ranks: &[f32]) -> Vec<f32> {
    ranks
        .iter()
        .enumerate()
        .map(|(v, &r)| DAMPING * r / (g.out_degree(v as u32).max(1) as f32))
        .collect()
}

/// Serial reference PageRank to tolerance (oracle for engine tests).
pub fn oracle(g: &mut CsrGraph, tol: f32, max_rounds: u32) -> (Vec<f32>, u32) {
    g.build_csc();
    let mut ranks = init_ranks(g.num_vertices());
    for round in 0..max_rounds {
        let contrib = contributions(g, &ranks);
        let (new_ranks, delta) = pull_round(g, &ranks, &contrib);
        ranks = new_ranks;
        if delta < tol {
            return (ranks, round + 1);
        }
    }
    (ranks, max_rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn cycle(n: u32) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for v in 0..n {
            el.push(v, (v + 1) % n, 1.0);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn uniform_on_symmetric_cycle() {
        let mut g = cycle(8);
        let (r, rounds) = oracle(&mut g, 1e-7, 100);
        assert!(rounds < 100);
        for &x in &r {
            assert!((x - 0.125).abs() < 1e-5, "rank {x}");
        }
    }

    #[test]
    fn ranks_sum_to_one_ish() {
        use crate::graph::gen::rmat::{self, RmatConfig};
        let el = rmat::generate(&RmatConfig::paper(8, 1));
        let mut g = CsrGraph::from_edge_list(&el);
        let (r, _) = oracle(&mut g, 1e-6, 100);
        let sum: f32 = r.iter().sum();
        // Dangling mass leaks (no redistribution, like the paper's simple
        // pr), so the sum is <= 1 but must stay positive and substantial.
        assert!(sum > 0.1 && sum <= 1.01, "sum {sum}");
    }

    #[test]
    fn hub_outranks_leaves() {
        // star pointing INTO vertex 0: 0 gathers everyone's contribution.
        let mut el = EdgeList::new(10);
        for v in 1..10 {
            el.push(v, 0, 1.0);
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let (r, _) = oracle(&mut g, 1e-7, 100);
        assert!(r[0] > 5.0 * r[1]);
    }

    #[test]
    fn contributions_guard_zero_degree() {
        let el = EdgeList::new(3);
        let g = CsrGraph::from_edge_list(&el);
        let c = contributions(&g, &[0.3, 0.3, 0.3]);
        assert!((c[0] - 0.85 * 0.3).abs() < 1e-7);
    }
}
