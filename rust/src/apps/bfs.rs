//! Breadth-first search (push-style): hop counts from a source.
//! An instance of the min-plus relaxation with unit edge weights.

use crate::graph::CsrGraph;

use super::INF;

/// Per-edge relax weight: every hop costs 1 regardless of edge weight.
#[inline]
pub fn relax_weight(_edge_weight: f32) -> f32 {
    1.0
}

/// Initial labels: `src = 0`, everything else unreached.
pub fn init_labels(n: usize, src: u32) -> Vec<f32> {
    let mut l = vec![INF; n];
    l[src as usize] = 0.0;
    l
}

/// Serial reference BFS (oracle for engine tests).
pub fn oracle(g: &CsrGraph, src: u32) -> Vec<f32> {
    let mut dist = vec![INF; g.num_vertices()];
    let mut q = std::collections::VecDeque::new();
    dist[src as usize] = 0.0;
    q.push_back(src);
    while let Some(v) = q.pop_front() {
        let d = dist[v as usize];
        let (dsts, _) = g.out_edges(v);
        for &u in dsts {
            if dist[u as usize] >= INF {
                dist[u as usize] = d + 1.0;
                q.push_back(u);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn oracle_on_diamond() {
        let mut el = EdgeList::new(4);
        el.push(0, 1, 9.0);
        el.push(0, 2, 9.0);
        el.push(1, 3, 9.0);
        el.push(2, 3, 9.0);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(oracle(&g, 0), vec![0.0, 1.0, 1.0, 2.0]);
    }

    #[test]
    fn unreachable_stays_inf() {
        let mut el = EdgeList::new(3);
        el.push(0, 1, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        let d = oracle(&g, 0);
        assert_eq!(d[2], INF);
    }

    #[test]
    fn weight_is_ignored() {
        assert_eq!(relax_weight(123.0), 1.0);
    }

    #[test]
    fn init_labels_shape() {
        let l = init_labels(5, 2);
        assert_eq!(l[2], 0.0);
        assert!(l.iter().enumerate().all(|(i, &x)| i == 2 || x == INF));
    }
}
