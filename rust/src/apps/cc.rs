//! Connected components via min-label propagation (push-style).
//!
//! Every vertex starts with its own id as label; active vertices push their
//! label to out-neighbors, keeping the minimum — zero-weight min-plus
//! relaxation. On directed inputs this computes the forward label-propagation
//! fixpoint (the standard GPU formulation; symmetric inputs like orkut-s and
//! road-s yield true connected components).

use crate::graph::CsrGraph;

/// Per-edge relax weight: label propagation is weight-free.
#[inline]
pub fn relax_weight(_edge_weight: f32) -> f32 {
    0.0
}

/// Initial labels: own vertex id.
pub fn init_labels(n: usize) -> Vec<f32> {
    (0..n).map(|v| v as f32).collect()
}

/// Serial reference: iterate min-label propagation to fixpoint.
pub fn oracle(g: &CsrGraph) -> Vec<f32> {
    let n = g.num_vertices();
    let mut label = init_labels(n);
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n as u32 {
            let lv = label[v as usize];
            let (dsts, _) = g.out_edges(v);
            for &u in dsts {
                if lv < label[u as usize] {
                    label[u as usize] = lv;
                    changed = true;
                }
            }
        }
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn two_components() {
        let mut el = EdgeList::new(6);
        el.push(0, 1, 1.0);
        el.push(1, 0, 1.0);
        el.push(1, 2, 1.0);
        el.push(2, 1, 1.0);
        el.push(4, 5, 1.0);
        el.push(5, 4, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        let l = oracle(&g);
        assert_eq!(l, vec![0.0, 0.0, 0.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn directed_chain_propagates_forward() {
        let mut el = EdgeList::new(4);
        el.push(3, 2, 1.0);
        el.push(2, 1, 1.0);
        el.push(1, 0, 1.0);
        let g = CsrGraph::from_edge_list(&el);
        // min label flows 3->2->1->0 but 0's own label (0) is already least.
        assert_eq!(oracle(&g), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn symmetric_star_collapses() {
        let mut el = EdgeList::new(5);
        for i in 1..5 {
            el.push(0, i, 1.0);
            el.push(i, 0, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        assert!(oracle(&g).iter().all(|&l| l == 0.0));
    }

    #[test]
    fn zero_weight() {
        assert_eq!(relax_weight(42.0), 0.0);
    }
}
