//! k-core decomposition (paper §5 uses k = 100).
//!
//! Iterative peeling on *in*-degrees: a vertex stays while its current
//! in-degree (edges from still-alive predecessors) is >= k. When a vertex
//! dies, the decrement flows along its **out-edges** to every successor —
//! so the per-round work is the dying vertices' out-edge lists, and on the
//! rmat inputs the hub's death floods a single CTA exactly like the push
//! apps do (which is why the paper's Table 2 shows kcore speeding up ~3x
//! under ALB while pr does not).

use crate::graph::CsrGraph;

pub const DEFAULT_K: u32 = 100;

/// Serial reference peel: returns (alive flags, rounds).
pub fn oracle(g: &mut CsrGraph, k: u32) -> (Vec<bool>, u32) {
    g.build_csc();
    let n = g.num_vertices();
    let mut deg: Vec<u64> = (0..n as u32).map(|v| g.in_degree(v)).collect();
    let mut alive = vec![true; n];
    let mut dying: Vec<u32> =
        (0..n as u32).filter(|&v| deg[v as usize] < k as u64).collect();
    for v in &dying {
        alive[*v as usize] = false;
    }
    let mut rounds = 0;
    while !dying.is_empty() {
        rounds += 1;
        let mut next = Vec::new();
        for &v in &dying {
            let (dsts, _) = g.out_edges(v);
            for &u in dsts {
                if alive[u as usize] {
                    deg[u as usize] -= 1;
                    if deg[u as usize] < k as u64 {
                        alive[u as usize] = false;
                        next.push(u);
                    }
                }
            }
        }
        dying = next;
    }
    (alive, rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn clique_survives_its_degree() {
        // K5: every vertex has in-degree 4.
        let mut el = EdgeList::new(5);
        for a in 0..5u32 {
            for b in 0..5u32 {
                if a != b {
                    el.push(a, b, 1.0);
                }
            }
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let (alive, _) = oracle(&mut g, 4);
        assert!(alive.iter().all(|&a| a));
        let (alive, _) = oracle(&mut g, 5);
        assert!(alive.iter().all(|&a| !a));
    }

    #[test]
    fn cascade_peeling() {
        // chain 0->1->2->3 with k=1: 0 (in-deg 0) dies, then 1, 2, 3.
        let mut el = EdgeList::new(4);
        el.push(0, 1, 1.0);
        el.push(1, 2, 1.0);
        el.push(2, 3, 1.0);
        let mut g = CsrGraph::from_edge_list(&el);
        let (alive, rounds) = oracle(&mut g, 1);
        assert!(alive.iter().all(|&a| !a));
        assert!(rounds >= 3, "cascade must take multiple rounds: {rounds}");
    }

    #[test]
    fn k_zero_keeps_everyone() {
        let mut el = EdgeList::new(3);
        el.push(0, 1, 1.0);
        let mut g = CsrGraph::from_edge_list(&el);
        let (alive, rounds) = oracle(&mut g, 0);
        assert!(alive.iter().all(|&a| a));
        assert_eq!(rounds, 0);
    }

    #[test]
    fn decrement_flows_along_out_edges() {
        // 0 -> 1, 2 -> 1: vertex 1 has in-degree 2; k=2. Vertex 0 and 2
        // have in-degree 0, die immediately, and their deaths strip 1.
        let mut el = EdgeList::new(3);
        el.push(0, 1, 1.0);
        el.push(2, 1, 1.0);
        let mut g = CsrGraph::from_edge_list(&el);
        let (alive, _) = oracle(&mut g, 1);
        assert_eq!(alive, vec![false, false, false]);
    }
}
