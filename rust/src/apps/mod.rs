//! The five evaluated applications (paper §5) and the round engine.
//!
//! Push-style (operator reads the active vertex, updates out-neighbors):
//! [`bfs`], [`sssp`], [`cc`] — all instances of the min-plus relaxation the
//! LB kernel accelerates. Pull-style (operator reads in-neighbors, updates
//! the active vertex): [`pr`], [`kcore`].
//!
//! [`engine`] drives rounds: strategy -> schedule -> simulated kernels ->
//! operator application (native Rust or the AOT-compiled PJRT kernels).

pub mod bfs;
pub mod cc;
pub mod engine;
pub mod kcore;
pub mod pr;
pub mod sssp;
pub mod worklist;

use crate::lb::Direction;

/// Label value standing in for "unreached" (2^30, f32-exact; shared with the
/// Pallas kernels' `ref.INF`).
pub const INF: f32 = 1_073_741_824.0;

/// One of the paper's five applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum App {
    Bfs,
    Sssp,
    Cc,
    Pr,
    Kcore,
}

/// All apps, in the paper's table order.
pub const ALL_APPS: [App; 5] = [App::Bfs, App::Cc, App::Kcore, App::Pr, App::Sssp];

/// Every spelling [`App::parse`] accepts, for error messages that name the
/// valid set (the C001 lint rule).
pub const APP_NAMES: &str = "bfs, sssp, cc, pr|pagerank, kcore|k-core";

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::Bfs => "bfs",
            App::Sssp => "sssp",
            App::Cc => "cc",
            App::Pr => "pr",
            App::Kcore => "kcore",
        }
    }

    pub fn parse(s: &str) -> Option<App> {
        match s {
            "bfs" => Some(App::Bfs),
            "sssp" => Some(App::Sssp),
            "cc" => Some(App::Cc),
            "pr" | "pagerank" => Some(App::Pr),
            "kcore" | "k-core" => Some(App::Kcore),
            _ => None,
        }
    }

    /// §5: push for bfs/cc/sssp, pull for pr/kcore.
    pub fn direction(&self) -> Direction {
        match self {
            App::Bfs | App::Sssp | App::Cc => Direction::Push,
            App::Pr | App::Kcore => Direction::Pull,
        }
    }

    pub fn is_push(&self) -> bool {
        self.direction() == Direction::Push
    }

    /// Does this app need a source vertex?
    pub fn needs_source(&self) -> bool {
        matches!(self, App::Bfs | App::Sssp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for app in ALL_APPS {
            assert_eq!(App::parse(app.name()), Some(app));
        }
        assert_eq!(App::parse("pagerank"), Some(App::Pr));
        assert_eq!(App::parse("nope"), None);
    }

    #[test]
    fn directions_match_paper() {
        assert!(App::Bfs.is_push());
        assert!(App::Sssp.is_push());
        assert!(App::Cc.is_push());
        assert!(!App::Pr.is_push());
        assert!(!App::Kcore.is_push());
    }

    #[test]
    fn sources() {
        assert!(App::Bfs.needs_source());
        assert!(App::Sssp.needs_source());
        assert!(!App::Pr.needs_source());
    }
}
