//! The single-GPU round engine.
//!
//! Drives the paper's execution loop (Fig. 3 lines 26–34) on the simulated
//! GPU: each round the configured [`Balancer`] turns the active set into a
//! [`crate::lb::Schedule`], the [`Simulator`] prices the kernel launches
//! (this is where the strategies differ), and the operator is applied to
//! produce next round's active set (this part is strategy-independent, so
//! every balancer converges to identical labels — asserted by tests).
//!
//! Operator application runs either natively or through the AOT-compiled
//! JAX/Pallas kernels via [`PjrtRuntime`] (`compute = Pjrt`): the LB kernel's
//! huge-vertex relaxation, pr's contribution kernel, and kcore's filter
//! kernel all execute as compiled HLO — python never runs here.
//!
//! Hot-path memory discipline (DESIGN.md §8): every driver owns one
//! [`RoundScratch`] for the whole run and threads it through
//! `Balancer::schedule_into` → `Simulator::simulate_into` → the bitmap
//! frontier drain, so steady-state rounds perform zero heap allocations
//! (asserted by `rust/tests/alloc.rs`). [`run_push_reference`] preserves
//! the fresh-allocation implementation as the golden reference
//! (`rust/tests/parity.rs`) and the pre-optimization baseline
//! (`benches/hotpath.rs`).
//!
//! Intra-GPU parallel simulation (DESIGN.md §9): each run owns one
//! [`exec::Pool`] of [`EngineConfig::sim_threads`] lanes and drives the
//! kernel simulation and the ALB inspector's probe pass through the pooled
//! entry points (`simulate_into_pooled` / `schedule_into_pooled`) — output
//! is bit-identical to `sim_threads = 1` for any pool width
//! (`rust/tests/parity.rs`).

use anyhow::{anyhow, Result};

use crate::apps::worklist::{NextWorklist, WorklistKind};
use crate::apps::{bfs, cc, kcore, pr, sssp, App, INF};
use crate::exec::{self, Pool};
use crate::gpu::{CostModel, GpuSpec, KernelStats, SimScratch, Simulator};
use crate::graph::CsrGraph;
use crate::lb::adaptive::{AdaptiveController, AdaptiveRound, RoundSignal};
use crate::lb::{Balancer, Direction, Distribution, ScheduleScratch};
use crate::runtime::PjrtRuntime;

/// How operators are computed. The schedule/simulation is identical either
/// way; `Pjrt` routes the numeric hot paths through the compiled artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    Native,
    Pjrt,
}

/// Per-run engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub balancer: Balancer,
    pub worklist: WorklistKind,
    pub spec: GpuSpec,
    pub cost: CostModel,
    pub compute: ComputeMode,
    pub pr_tol: f32,
    pub kcore_k: u32,
    pub max_rounds: u32,
    /// Direction-optimizing bfs (Beamer-style push/pull switching) — the
    /// variant Gunrock reports in Table 2's parentheses. Off by default
    /// (the paper's D-IrGL does not support it).
    pub bfs_direction_opt: bool,
    /// Delta-stepping sssp bucket width (§2.1 names delta-stepping as the
    /// canonical data-driven sssp); `None` = chaotic relaxation.
    pub sssp_delta: Option<f32>,
    /// Retain per-block kernel stats per round (needed by Figures 1 & 5;
    /// off by default to keep sweeps lean).
    pub record_blocks: bool,
    /// Worker-pool lanes for the intra-GPU parallel simulation
    /// (DESIGN.md §9): `1` = the historical sequential block walk on the
    /// calling thread. Defaults to [`exec::default_threads`] (the
    /// `ALB_SIM_THREADS` env override, else available parallelism).
    /// Output is bit-identical for any value. The multi-GPU coordinator
    /// sizes its single shared pool from this too.
    pub sim_threads: usize,
}

impl EngineConfig {
    /// Builder-style balancer swap — the thin entry the campaign runner
    /// and CLI use to derive a cell's config from the defaults without
    /// re-spelling the whole struct.
    pub fn with_balancer(mut self, balancer: Balancer) -> Self {
        self.balancer = balancer;
        self
    }

    /// Builder-style pool-width override (see
    /// [`sim_threads`](Self::sim_threads)).
    pub fn with_sim_threads(mut self, sim_threads: usize) -> Self {
        self.sim_threads = sim_threads;
        self
    }

    /// Builder-style direction-optimizing-bfs toggle (see
    /// [`bfs_direction_opt`](Self::bfs_direction_opt)).
    pub fn with_direction_opt(mut self, on: bool) -> Self {
        self.bfs_direction_opt = on;
        self
    }

    /// Builder-style delta-stepping bucket width (see
    /// [`sssp_delta`](Self::sssp_delta)); `None` = chaotic relaxation.
    pub fn with_sssp_delta(mut self, delta: Option<f32>) -> Self {
        self.sssp_delta = delta;
        self
    }

    /// Builder-style PageRank convergence tolerance.
    pub fn with_pr_tol(mut self, tol: f32) -> Self {
        self.pr_tol = tol;
        self
    }

    /// Builder-style k-core threshold.
    pub fn with_kcore_k(mut self, k: u32) -> Self {
        self.kcore_k = k;
        self
    }

    /// Builder-style round budget.
    pub fn with_max_rounds(mut self, max_rounds: u32) -> Self {
        self.max_rounds = max_rounds;
        self
    }

    /// Builder-style per-block kernel-stat retention toggle (see
    /// [`record_blocks`](Self::record_blocks)).
    pub fn with_record_blocks(mut self, on: bool) -> Self {
        self.record_blocks = on;
        self
    }

    /// Builder-style compute-mode switch (native vs PJRT artifacts).
    pub fn with_compute(mut self, compute: ComputeMode) -> Self {
        self.compute = compute;
        self
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            balancer: Balancer::Alb {
                distribution: Distribution::Cyclic,
                threshold: None,
            },
            worklist: WorklistKind::Dense,
            spec: GpuSpec::default_sim(),
            cost: CostModel::default(),
            compute: ComputeMode::Native,
            pr_tol: pr::DEFAULT_TOL,
            kcore_k: kcore::DEFAULT_K,
            max_rounds: 10_000,
            bfs_direction_opt: false,
            sssp_delta: None,
            record_blocks: false,
            sim_threads: exec::default_threads(),
        }
    }
}

/// One round's record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: u32,
    pub active: u64,
    pub edges: u64,
    pub cycles: u64,
    /// Whether the LB kernel launched this round (ALB adaptivity signal).
    pub lb_triggered: bool,
    /// Per-block stats, when `record_blocks` is set.
    pub kernels: Option<Vec<KernelStats>>,
    /// Feedback-controller trace ([`Balancer::Adaptive`]/[`Balancer::Auto`]
    /// runs only): the threshold and sampling budget this round ran with,
    /// and the imbalance it measured. `None` under static balancers, so
    /// record-equality checks between static strategies are unaffected.
    pub adaptive: Option<AdaptiveRound>,
}

/// A completed run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub app: App,
    pub labels: Vec<f32>,
    pub rounds: Vec<RoundRecord>,
    pub total_cycles: u64,
    /// Did the run reach its fixpoint, or did it exhaust `max_rounds`?
    /// Surfaced in the CLI JSON and per campaign cell (ISSUE 8).
    pub converged: bool,
}

/// Record the loop-exit condition, warning loudly on round exhaustion — a
/// run that silently stops at `max_rounds` reads as a converged answer when
/// it is not one.
fn warn_exhausted(app: App, converged: bool, max_rounds: u32) -> bool {
    if !converged {
        eprintln!(
            "warning: {} exhausted --max-rounds ({max_rounds}) before \
             converging; labels are a partial fixpoint",
            app.name()
        );
    }
    converged
}

impl RunResult {
    /// Simulated execution time in milliseconds on `spec`.
    pub fn ms(&self, spec: &GpuSpec) -> f64 {
        spec.cycles_to_ms(self.total_cycles)
    }

    pub fn total_edges(&self) -> u64 {
        self.rounds.iter().map(|r| r.edges).sum()
    }

    pub fn rounds_with_lb(&self) -> usize {
        self.rounds.iter().filter(|r| r.lb_triggered).count()
    }
}

/// The reusable per-round buffer arena (DESIGN.md §8): schedule buffers,
/// simulator accounting arrays, and the bitmap frontier, all owned for the
/// duration of one run (the multi-GPU coordinator owns one per simulated
/// GPU, used only by that GPU's BSP thread). Ownership contract: callees
/// never retain references into the scratch across rounds — each round
/// overwrites `sched.sched`/`sim.round` in place, and `active` is refilled
/// by draining `next`.
#[derive(Debug, Default)]
pub struct RoundScratch {
    pub sched: ScheduleScratch,
    pub sim: SimScratch,
    pub next: NextWorklist,
    /// Current frontier, refilled from `next`'s drain each round.
    pub active: Vec<u32>,
    /// The per-run feedback controller, armed by [`arm_adaptive`]
    /// (Self::arm_adaptive) when the balancer is adaptive; `None` keeps
    /// every static strategy on the exact pre-controller code path.
    pub adaptive: Option<AdaptiveController>,
}

impl RoundScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A scratch whose frontier bitmap covers `n` vertices.
    pub fn for_vertices(n: usize) -> Self {
        let mut s = Self::default();
        s.next.resize_for(n);
        s
    }

    /// Arm the feedback controller when `cfg.balancer` is adaptive
    /// ([`AdaptiveController::for_balancer`]); static balancers leave it
    /// `None` and are untouched by the controller plumbing.
    pub fn arm_adaptive(&mut self, cfg: &EngineConfig) {
        self.adaptive =
            AdaptiveController::for_balancer(&cfg.balancer, &cfg.spec, &cfg.cost);
    }

    /// [`for_vertices`](Self::for_vertices) + [`arm_adaptive`]
    /// (Self::arm_adaptive): the per-GPU constructor the multi-GPU
    /// coordinator uses — each simulated GPU gets its *own* controller,
    /// steering from its own partition's measured imbalance.
    pub fn for_run(n: usize, cfg: &EngineConfig) -> Self {
        let mut s = Self::for_vertices(n);
        s.arm_adaptive(cfg);
        s
    }

    /// Re-arm a (possibly used) scratch for a fresh run on an `n`-vertex
    /// graph under `cfg`: grow the frontier bitmap, drop any leftover
    /// frontier, and rebuild the feedback controller. This is what lets
    /// [`crate::session::Session`] keep one checkout pool of arenas and
    /// reuse them across queries instead of allocating per run;
    /// [`run_prepared`] calls it unconditionally, so a fresh scratch pays
    /// only the (empty) clears.
    pub fn reset_for(&mut self, n: usize, cfg: &EngineConfig) {
        self.next.resize_for(n);
        self.next.clear();
        self.active.clear();
        self.arm_adaptive(cfg);
    }
}

/// One schedule + simulate step under the (optionally adaptive) balancer:
/// the controller's current threshold and sampled-warp budget when armed,
/// the configured balancer and cost-model default otherwise. Shared by
/// every driver loop and the multi-GPU coordinator's per-GPU rounds.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sim_round(
    cfg: &EngineConfig,
    sim: &Simulator,
    g: &CsrGraph,
    dir: Direction,
    active: &[u32],
    scan_vertices: u64,
    atomics: bool,
    adaptive: &Option<AdaptiveController>,
    sched: &mut ScheduleScratch,
    sim_scratch: &mut SimScratch,
    pool: &Pool,
) {
    // `Balancer` clones are heap-free (enum of Copy payloads), so the
    // dispatch below costs nothing on the zero-allocation hot path (§8).
    let balancer = match adaptive {
        Some(ctl) => ctl.balancer(),
        None => cfg.balancer.clone(),
    };
    balancer.schedule_into_pooled(active, g, dir, &cfg.spec, scan_vertices, sched, pool);
    sim.simulate_into_pooled_capped(
        &sched.sched,
        atomics,
        sim_scratch,
        pool,
        adaptive.as_ref().map(|c| c.sample_cap()),
    );
}

/// Feed the round's measured kernel signal to the controller (when armed)
/// and return the trace for this round's [`RoundRecord`]. Must run *before*
/// [`record_kernels`] moves the kernel stats out of the scratch.
pub(crate) fn observe_adaptive(
    adaptive: &mut Option<AdaptiveController>,
    sched: &ScheduleScratch,
    sim_scratch: &SimScratch,
) -> Option<AdaptiveRound> {
    let ctl = adaptive.as_mut()?;
    let mut imbalance = 1.0f64;
    let mut twc_cycles = 0u64;
    let mut lb_cycles = 0u64;
    for k in &sim_scratch.round.kernels {
        imbalance = imbalance.max(k.imbalance_factor());
        match k.label {
            "twc" => twc_cycles = k.kernel_cycles,
            "lb" => lb_cycles = k.kernel_cycles,
            _ => {}
        }
    }
    let trace = AdaptiveRound {
        threshold: ctl.threshold(),
        sample_cap: ctl.sample_cap(),
        imbalance,
    };
    ctl.observe(&RoundSignal {
        imbalance,
        twc_cycles,
        lb_cycles,
        lb_triggered: sched.sched.lb.is_some(),
    });
    Some(trace)
}

/// Does running `app` under `cfg` read in-edges (and therefore need
/// [`CsrGraph::build_csc`] to have run)?
pub fn needs_csc(app: App, cfg: &EngineConfig) -> bool {
    matches!(app, App::Pr | App::Kcore) || (app == App::Bfs && cfg.bfs_direction_opt)
}

/// Run `app` on `g` under `cfg`. `source` is used by bfs/sssp; `pjrt` must
/// be `Some` when `cfg.compute == Pjrt`.
///
/// This is the one-shot entry: it builds the CSC view when the driver pulls
/// in-edges, allocates a fresh [`Pool`] and [`RoundScratch`], and delegates
/// to [`run_prepared`]. Long-lived callers (the serve daemon's
/// [`crate::session::Session`]) prepare the graph once and call
/// [`run_prepared`] directly so concurrent queries share `&CsrGraph`, one
/// pool, and recycled arenas.
pub fn run(
    app: App,
    g: &mut CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
) -> Result<RunResult> {
    if needs_csc(app, cfg) {
        g.build_csc();
    }
    // One worker pool per run (DESIGN.md §9); `sim_threads = 1` spawns
    // nothing and every pooled entry point takes the sequential path.
    let pool = Pool::new(cfg.sim_threads.max(1));
    let mut scratch = RoundScratch::for_run(g.num_vertices(), cfg);
    run_prepared(app, g, source, cfg, pjrt, &pool, &mut scratch)
}

/// Run `app` on an immutable, already-prepared graph with caller-owned
/// execution resources — the [`crate::session::Session`] hot path
/// (DESIGN.md §16). `scratch` is [`RoundScratch::reset_for`]-armed here, so
/// any (possibly used) arena is accepted. Results are bit-identical to
/// [`run`] for the same `(app, g, source, cfg)`: the two differ only in who
/// owns the pool and scratch.
///
/// Preconditions: `g.csc` must be built when [`needs_csc`] holds (a loud
/// error, not a panic, otherwise), and `pjrt` must be `Some` under
/// `ComputeMode::Pjrt`.
pub fn run_prepared(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    if cfg.compute == ComputeMode::Pjrt && pjrt.is_none() {
        return Err(anyhow!("compute=Pjrt requires a loaded PjrtRuntime"));
    }
    if needs_csc(app, cfg) && g.csc.is_none() {
        return Err(anyhow!(
            "{} pulls in-edges: call CsrGraph::build_csc() before \
             run_prepared (engine::run and session::Session do this for you)",
            app.name()
        ));
    }
    scratch.reset_for(g.num_vertices(), cfg);
    match app {
        App::Bfs if cfg.bfs_direction_opt => run_bfs_dopt(g, source, cfg, pool, scratch),
        App::Sssp if cfg.sssp_delta.is_some() => {
            run_sssp_delta(g, source, cfg, cfg.sssp_delta.unwrap(), pool, scratch)
        }
        App::Bfs | App::Sssp | App::Cc => {
            run_push(app, g, source, cfg, pjrt, pool, scratch)
        }
        App::Pr => run_pr(g, cfg, pjrt, pool, scratch),
        App::Kcore => run_kcore(g, cfg, pjrt, pool, scratch),
    }
}

/// Relax weight for one push app.
#[inline]
pub(crate) fn relax_weight(app: App, w: f32) -> f32 {
    match app {
        App::Bfs => bfs::relax_weight(w),
        App::Sssp => sssp::relax_weight(w),
        App::Cc => cc::relax_weight(w),
        _ => unreachable!("not a push app"),
    }
}

// ------------------------------------------------------------------- push

fn run_push(
    app: App,
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    let n = g.num_vertices();
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut labels = match app {
        App::Bfs => bfs::init_labels(n, source),
        App::Sssp => sssp::init_labels(n, source),
        App::Cc => cc::init_labels(n),
        _ => unreachable!(),
    };
    scratch.active = match app {
        App::Bfs | App::Sssp => vec![source],
        App::Cc => (0..n as u32).collect(),
        _ => unreachable!(),
    };
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;

    for round in 0..cfg.max_rounds {
        if scratch.active.is_empty() {
            break;
        }
        let scan = cfg.worklist.scan_cost(n as u64, scratch.active.len() as u64);
        sim_round(
            cfg, &sim, g, Direction::Push, &scratch.active, scan, true,
            &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
        );
        let cycles = scratch.sim.round.total_cycles;
        total_cycles += cycles;
        let adaptive =
            observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
        rounds.push(RoundRecord {
            round,
            active: scratch.active.len() as u64,
            edges: scratch.sched.sched.total_edges(),
            cycles,
            lb_triggered: scratch.sched.sched.lb.is_some(),
            kernels: record_kernels(cfg, &mut scratch.sim),
            adaptive,
        });

        // --- operator application ---
        if let (ComputeMode::Pjrt, Some(rt), Some(lb)) =
            (cfg.compute, pjrt, &scratch.sched.sched.lb)
        {
            // Huge bin through the compiled LB kernel...
            relax_huge_pjrt(rt, g, &lb.vertices, app, &mut labels, &mut scratch.next)?;
            // ...the rest natively (TWC items are exactly active \ huge).
            for item in &scratch.sched.sched.twc {
                relax_native(g, app, item.vertex, &mut labels, &mut scratch.next);
            }
        } else {
            for &v in &scratch.active {
                relax_native(g, app, v, &mut labels, &mut scratch.next);
            }
        }
        scratch.next.take_sorted_into(&mut scratch.active);
    }
    let converged =
        warn_exhausted(app, scratch.active.is_empty(), cfg.max_rounds);
    Ok(RunResult { app, labels, rounds, total_cycles, converged })
}

/// Take the round's kernel stats out of the scratch when `record_blocks` is
/// set — a move, not a clone: the stats leave the simulator's recycling
/// pool and live in the [`RoundRecord`] (stat-retaining runs re-allocate
/// next round by design; lean runs allocate nothing here).
#[inline]
fn record_kernels(cfg: &EngineConfig, sim: &mut SimScratch) -> Option<Vec<KernelStats>> {
    cfg.record_blocks.then(|| std::mem::take(&mut sim.round.kernels))
}

#[inline]
pub(crate) fn relax_native(
    g: &CsrGraph,
    app: App,
    v: u32,
    labels: &mut [f32],
    next: &mut NextWorklist,
) {
    let dv = labels[v as usize];
    if dv >= INF {
        return;
    }
    let (dsts, ws) = g.out_edges(v);
    for (&dst, &w) in dsts.iter().zip(ws) {
        let cand = dv + relax_weight(app, w);
        if cand < labels[dst as usize] {
            labels[dst as usize] = cand;
            next.push(dst);
        }
    }
}

/// Relax all edges of `huge` through the AOT LB kernel, in groups bounded by
/// the largest compiled huge-table variant.
pub(crate) fn relax_huge_pjrt(
    rt: &PjrtRuntime,
    g: &CsrGraph,
    huge: &[u32],
    app: App,
    labels: &mut [f32],
    next: &mut NextWorklist,
) -> Result<()> {
    let max_h = rt.max_relax_h().max(1);
    for group in huge.chunks(max_h) {
        // Prefix + source labels for this group (kernel inputs).
        let mut prefix = Vec::with_capacity(group.len());
        let mut src_dist = Vec::with_capacity(group.len());
        let mut total = 0u64;
        for &v in group {
            total += g.out_degree(v);
            prefix.push(u32::try_from(total).map_err(|_| {
                anyhow!("huge group exceeds u32 edge space")
            })?);
            src_dist.push(labels[v as usize]);
        }
        // Flattened edge ids + relax weights + destinations (host knows the
        // eid -> (dst, w) map from CSR; the kernel recovers eid -> src).
        let mut eids = Vec::with_capacity(total as usize);
        let mut weights = Vec::with_capacity(total as usize);
        let mut dsts = Vec::with_capacity(total as usize);
        let mut e = 0u32;
        for &v in group {
            let (d, w) = g.out_edges(v);
            for (&dst, &wt) in d.iter().zip(w) {
                eids.push(e);
                weights.push(relax_weight(app, wt));
                dsts.push(dst);
                e += 1;
            }
        }
        let (_src, cand) = rt.edge_relax(&prefix, &src_dist, &eids, &weights)?;
        for (i, &c) in cand.iter().enumerate() {
            // Skip relaxations from unreached sources (INF + w).
            if c >= INF {
                continue;
            }
            let dst = dsts[i] as usize;
            if c < labels[dst] {
                labels[dst] = c;
                next.push(dsts[i]);
            }
        }
    }
    Ok(())
}

// --------------------------------------------------- reference (golden)

/// The golden fresh-allocation reference for the push apps: identical
/// labels, per-round records, and total cycles to [`run`]'s scratch-reuse
/// hot path (asserted by `rust/tests/parity.rs`), implemented with the
/// legacy allocating APIs — [`Balancer::schedule`],
/// [`Simulator::simulate_reference`], and a per-round `Vec` +
/// `sort_unstable` + `dedup` frontier. Doubles as the pre-optimization
/// baseline in `benches/hotpath.rs`; not a hot path.
#[doc(hidden)]
pub fn run_push_reference(
    app: App,
    g: &mut CsrGraph,
    source: u32,
    cfg: &EngineConfig,
) -> Result<RunResult> {
    let n = g.num_vertices();
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut labels = match app {
        App::Bfs => bfs::init_labels(n, source),
        App::Sssp => sssp::init_labels(n, source),
        App::Cc => cc::init_labels(n),
        _ => return Err(anyhow!("reference engine covers push apps only")),
    };
    let mut active: Vec<u32> = match app {
        App::Bfs | App::Sssp => vec![source],
        App::Cc => (0..n as u32).collect(),
        _ => unreachable!(),
    };
    // The historical flag-array next-worklist: per-run flags, a freshly
    // grown item list every round, and a per-round sort.
    let mut flags = vec![false; n];
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;

    for round in 0..cfg.max_rounds {
        if active.is_empty() {
            break;
        }
        let scan = cfg.worklist.scan_cost(n as u64, active.len() as u64);
        let sched =
            cfg.balancer
                .schedule(&active, g, Direction::Push, &cfg.spec, scan);
        let simr = sim.simulate_reference(&sched, true);
        total_cycles += simr.total_cycles;
        rounds.push(RoundRecord {
            round,
            active: active.len() as u64,
            edges: sched.total_edges(),
            cycles: simr.total_cycles,
            lb_triggered: sched.lb.is_some(),
            kernels: cfg.record_blocks.then(|| simr.kernels.clone()),
            adaptive: None,
        });

        // Operator application with push-time flag dedup (the bitmap drain
        // produces the same sorted unique set).
        let mut next: Vec<u32> = Vec::new();
        for &v in &active {
            let dv = labels[v as usize];
            if dv >= INF {
                continue;
            }
            let (dsts, ws) = g.out_edges(v);
            for (&dst, &w) in dsts.iter().zip(ws) {
                let cand = dv + relax_weight(app, w);
                if cand < labels[dst as usize] {
                    labels[dst as usize] = cand;
                    if !flags[dst as usize] {
                        flags[dst as usize] = true;
                        next.push(dst);
                    }
                }
            }
        }
        for &v in &next {
            flags[v as usize] = false;
        }
        next.sort_unstable();
        active = next;
    }
    let converged = warn_exhausted(app, active.is_empty(), cfg.max_rounds);
    Ok(RunResult { app, labels, rounds, total_cycles, converged })
}


// --------------------------------------------------- direction-opt bfs

/// Direction-optimizing bfs (Beamer-style): push from the frontier while it
/// is small; switch to pull (each unvisited vertex scans in-edges for a
/// visited parent, early-exit) when the frontier's out-edge volume exceeds
/// a fraction of the unexplored edges. This is Gunrock's bfs variant that
/// the paper quotes in Table 2's parentheses.
fn run_bfs_dopt(
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    const ALPHA: u64 = 14; // Beamer's push->pull switch factor
    const BETA: u64 = 24; //  pull->push switch factor

    let n = g.num_vertices();
    let m = g.num_edges() as u64;
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut labels = bfs::init_labels(n, source);
    scratch.active = vec![source];
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;
    let mut explored = 0u64;
    let mut pulling = false;

    for round in 0..cfg.max_rounds {
        if scratch.active.is_empty() {
            break;
        }
        let mf: u64 = scratch.active.iter().map(|&v| g.out_degree(v)).sum();
        let mu = m.saturating_sub(explored);
        if !pulling && mf * ALPHA > mu {
            pulling = true;
        } else if pulling && (scratch.active.len() as u64) * BETA < n as u64 {
            // Frontier shrank again -> switch back to push.
            pulling = false;
        }

        if pulling {
            // Pull round: every unvisited vertex scans its in-edges for a
            // parent on the current frontier, early-exiting on the first
            // hit. Work items carry the edges actually scanned, so the
            // simulated cost reflects the early exit.
            let cur_level: f32 = labels[scratch.active[0] as usize];
            scratch.sched.reset();
            let mut scanned_total = 0u64;
            for v in 0..n as u32 {
                if labels[v as usize] < INF {
                    continue;
                }
                let (srcs, _) = g.in_edges(v);
                let mut scanned = 0u64;
                for &u in srcs {
                    scanned += 1;
                    if labels[u as usize] == cur_level {
                        labels[v as usize] = cur_level + 1.0;
                        scratch.next.push(v);
                        break;
                    }
                }
                scanned_total += scanned;
                scratch.sched.sched.twc.push(crate::lb::VertexItem {
                    vertex: v,
                    degree: scanned,
                    unit: crate::lb::twc::bin(scanned, &cfg.spec),
                });
            }
            let items = scratch.sched.sched.twc.len() as u64;
            scratch.sched.sched.scan_vertices =
                cfg.worklist.scan_cost(n as u64, items);
            sim.simulate_into_pooled(&scratch.sched.sched, false, &mut scratch.sim, pool);
            explored += scanned_total;
        } else {
            let scan =
                cfg.worklist.scan_cost(n as u64, scratch.active.len() as u64);
            sim_round(
                cfg, &sim, g, Direction::Push, &scratch.active, scan, true,
                &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
            );
            for &v in &scratch.active {
                relax_native(g, App::Bfs, v, &mut labels, &mut scratch.next);
            }
            explored += mf;
        }
        let cycles = scratch.sim.round.total_cycles;
        total_cycles += cycles;
        // Pull rounds feed the controller too: the schedule is built by the
        // direction-optimizer rather than the balancer, but the measured
        // imbalance is real and the recovery rule needs idle-LB rounds.
        let adaptive =
            observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
        rounds.push(RoundRecord {
            round,
            active: scratch.active.len() as u64,
            edges: scratch.sched.sched.total_edges(),
            cycles,
            lb_triggered: scratch.sched.sched.lb.is_some(),
            kernels: record_kernels(cfg, &mut scratch.sim),
            adaptive,
        });
        scratch.next.take_sorted_into(&mut scratch.active);
    }
    let converged =
        warn_exhausted(App::Bfs, scratch.active.is_empty(), cfg.max_rounds);
    Ok(RunResult { app: App::Bfs, labels, rounds, total_cycles, converged })
}

// --------------------------------------------------- delta-stepping sssp

/// Delta-stepping sssp (Meyer & Sanders; §2.1's canonical data-driven
/// algorithm): settle distance buckets of width `delta` in order — light
/// edges (w <= delta) relax iteratively within the bucket, heavy edges once
/// when it settles. Each inner iteration is one simulated round.
fn run_sssp_delta(
    g: &CsrGraph,
    source: u32,
    cfg: &EngineConfig,
    delta: f32,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    assert!(
        delta > 0.0 && delta.is_finite(),
        "sssp_delta must be positive and finite"
    );
    // Bucket-index clamp (ISSUE 4 bugfix): `(d / delta) as usize` saturates
    // for unreached (>= INF) labels and for huge distance/delta ratios, and
    // the saturated index used to drive `buckets.resize(usize::MAX + 1)` —
    // a capacity-overflow panic (or an OOM for merely-huge finite ratios).
    // Distances past TAIL_BUCKET * delta share one clamped tail bucket;
    // unreached labels map to a sentinel that never matches a real bucket.
    const TAIL_BUCKET: usize = 1 << 16;
    let n = g.num_vertices();
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut labels = sssp::init_labels(n, source);
    let bucket_of = |d: f32| -> usize {
        if d >= INF {
            return usize::MAX; // unreached: member of no bucket
        }
        ((d / delta) as u64).min(TAIL_BUCKET as u64) as usize
    };
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new()];
    buckets[0].push(source);
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;
    let mut round = 0u32;
    let mut k = 0usize;

    let requeue = |buckets: &mut Vec<Vec<u32>>, v: u32, d: f32| {
        let b = bucket_of(d);
        if b > TAIL_BUCKET {
            return; // unreached sentinel (defensive): nothing to schedule
        }
        if b >= buckets.len() {
            buckets.resize(b + 1, Vec::new());
        }
        buckets[b].push(v);
    };

    while k < buckets.len() && round < cfg.max_rounds {
        let mut settled: Vec<u32> = Vec::new();
        // Light phase: iterate until bucket k stops refilling.
        loop {
            let mut active: Vec<u32> = std::mem::take(&mut buckets[k]);
            active.sort_unstable();
            active.dedup();
            active.retain(|&v| bucket_of(labels[v as usize]) == k);
            if active.is_empty() || round >= cfg.max_rounds {
                break;
            }
            let scan = cfg.worklist.scan_cost(n as u64, active.len() as u64);
            sim_round(
                cfg, &sim, g, Direction::Push, &active, scan, true,
                &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
            );
            let cycles = scratch.sim.round.total_cycles;
            total_cycles += cycles;
            let adaptive =
                observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
            rounds.push(RoundRecord {
                round,
                active: active.len() as u64,
                edges: scratch.sched.sched.total_edges(),
                cycles,
                lb_triggered: scratch.sched.sched.lb.is_some(),
                kernels: record_kernels(cfg, &mut scratch.sim),
                adaptive,
            });
            round += 1;
            for &v in &active {
                let dv = labels[v as usize];
                if dv >= INF {
                    continue;
                }
                let (dsts, ws) = g.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    if w <= delta {
                        let cand = dv + w;
                        if cand < labels[dst as usize] {
                            labels[dst as usize] = cand;
                            requeue(&mut buckets, dst, cand);
                        }
                    }
                }
            }
            settled.extend_from_slice(&active);
        }
        // Heavy phase: one pass over the settled vertices' heavy edges.
        settled.sort_unstable();
        settled.dedup();
        if !settled.is_empty() && round < cfg.max_rounds {
            let scan = cfg.worklist.scan_cost(n as u64, settled.len() as u64);
            sim_round(
                cfg, &sim, g, Direction::Push, &settled, scan, true,
                &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
            );
            let cycles = scratch.sim.round.total_cycles;
            total_cycles += cycles;
            let adaptive =
                observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
            rounds.push(RoundRecord {
                round,
                active: settled.len() as u64,
                edges: scratch.sched.sched.total_edges(),
                cycles,
                lb_triggered: scratch.sched.sched.lb.is_some(),
                kernels: record_kernels(cfg, &mut scratch.sim),
                adaptive,
            });
            round += 1;
            for &v in &settled {
                let dv = labels[v as usize];
                if dv >= INF {
                    continue;
                }
                let (dsts, ws) = g.out_edges(v);
                for (&dst, &w) in dsts.iter().zip(ws) {
                    if w > delta {
                        let cand = dv + w;
                        if cand < labels[dst as usize] {
                            labels[dst as usize] = cand;
                            requeue(&mut buckets, dst, cand);
                        }
                    }
                }
            }
        }
        if round >= cfg.max_rounds {
            break;
        }
        if !buckets[k].is_empty() {
            // A heavy relaxation normally lands in a bucket > k (w > delta
            // implies cand crosses the next boundary), so this re-entry
            // only fires when the clamped tail bucket refilled itself —
            // re-settle it instead of advancing past pending work.
            continue;
        }
        k += 1;
    }
    // Converged = every distance bucket drained (the loop's natural exit);
    // breaking on `max_rounds` leaves buckets unsettled.
    let converged =
        warn_exhausted(App::Sssp, k >= buckets.len(), cfg.max_rounds);
    Ok(RunResult { app: App::Sssp, labels, rounds, total_cycles, converged })
}

// --------------------------------------------------------------------- pr

fn run_pr(
    g: &CsrGraph,
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    let n = g.num_vertices();
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let all: Vec<u32> = (0..n as u32).collect();
    let out_deg: Vec<u32> =
        (0..n as u32).map(|v| g.out_degree(v) as u32).collect();
    let mut ranks = pr::init_ranks(n);
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;
    let mut converged = false;

    for round in 0..cfg.max_rounds {
        // Topology-driven: all vertices active, pull direction.
        let scan = cfg.worklist.scan_cost(n as u64, n as u64);
        sim_round(
            cfg, &sim, g, Direction::Pull, &all, scan, false,
            &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
        );
        let cycles = scratch.sim.round.total_cycles;
        total_cycles += cycles;
        let adaptive =
            observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
        rounds.push(RoundRecord {
            round,
            active: n as u64,
            edges: scratch.sched.sched.total_edges(),
            cycles,
            lb_triggered: scratch.sched.sched.lb.is_some(),
            kernels: record_kernels(cfg, &mut scratch.sim),
            adaptive,
        });

        let contrib = match (cfg.compute, pjrt) {
            (ComputeMode::Pjrt, Some(rt)) => {
                // Tile through the compiled elementwise kernel.
                let mut c = Vec::with_capacity(n);
                let tile = 16_384.min(n.max(1));
                for start in (0..n).step_by(tile) {
                    let end = (start + tile).min(n);
                    c.extend(rt.pr_pull(
                        &ranks[start..end],
                        &out_deg[start..end],
                        pr::DAMPING,
                    )?);
                }
                c
            }
            _ => pr::contributions(g, &ranks),
        };
        let (new_ranks, delta) = pr::pull_round(g, &ranks, &contrib);
        ranks = new_ranks;
        if delta < cfg.pr_tol {
            converged = true;
            break;
        }
    }
    let converged = warn_exhausted(App::Pr, converged, cfg.max_rounds);
    Ok(RunResult { app: App::Pr, labels: ranks, rounds, total_cycles, converged })
}

// ------------------------------------------------------------------ kcore

fn run_kcore(
    g: &CsrGraph,
    cfg: &EngineConfig,
    pjrt: Option<&PjrtRuntime>,
    pool: &Pool,
    scratch: &mut RoundScratch,
) -> Result<RunResult> {
    let n = g.num_vertices();
    let k = cfg.kcore_k;
    let sim = Simulator::new(cfg.spec.clone(), cfg.cost.clone());
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g.in_degree(v) as u32).collect();
    let mut alive = vec![true; n];
    let mut rounds = Vec::new();
    let mut total_cycles = 0u64;

    // Round 0: the initial filter over all vertices (scan only, no edges).
    let mut dying: Vec<u32> = {
        let flags = survival(pjrt, cfg, &deg, k)?;
        (0..n as u32).filter(|&v| !flags[v as usize]).collect()
    };
    for &v in &dying {
        alive[v as usize] = false;
    }
    scratch.sched.reset();
    scratch.sched.sched.scan_vertices =
        cfg.worklist.scan_cost(n as u64, n as u64);
    sim.simulate_into_pooled(&scratch.sched.sched, false, &mut scratch.sim, pool);
    let cycles0 = scratch.sim.round.total_cycles;
    total_cycles += cycles0;
    let adaptive0 =
        observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
    rounds.push(RoundRecord {
        round: 0,
        active: n as u64,
        edges: 0,
        cycles: cycles0,
        lb_triggered: false,
        kernels: record_kernels(cfg, &mut scratch.sim),
        adaptive: adaptive0,
    });

    let mut round = 1;
    while !dying.is_empty() && round < cfg.max_rounds {
        // Work this round: the dying vertices' out-edges (decrement push).
        let scan = cfg.worklist.scan_cost(n as u64, dying.len() as u64);
        // atomicSub per decrement
        sim_round(
            cfg, &sim, g, Direction::Push, &dying, scan, true,
            &scratch.adaptive, &mut scratch.sched, &mut scratch.sim, pool,
        );
        let cycles = scratch.sim.round.total_cycles;
        total_cycles += cycles;
        let adaptive =
            observe_adaptive(&mut scratch.adaptive, &scratch.sched, &scratch.sim);
        rounds.push(RoundRecord {
            round,
            active: dying.len() as u64,
            edges: scratch.sched.sched.total_edges(),
            cycles,
            lb_triggered: scratch.sched.sched.lb.is_some(),
            kernels: record_kernels(cfg, &mut scratch.sim),
            adaptive,
        });

        // Decrement successors; collect candidates whose degree dropped.
        let mut touched = Vec::new();
        for &v in &dying {
            let (dsts, _) = g.out_edges(v);
            for &u in dsts {
                if alive[u as usize] {
                    deg[u as usize] -= 1;
                    touched.push(u);
                }
            }
        }
        touched.sort_unstable();
        touched.dedup();
        // Threshold test (compiled kernel in Pjrt mode).
        let tdeg: Vec<u32> = touched.iter().map(|&u| deg[u as usize]).collect();
        let flags = survival_list(pjrt, cfg, &tdeg, k)?;
        let mut next = Vec::new();
        for (i, &u) in touched.iter().enumerate() {
            if !flags[i] && alive[u as usize] {
                alive[u as usize] = false;
                next.push(u);
            }
        }
        dying = next;
        round += 1;
    }
    let converged =
        warn_exhausted(App::Kcore, dying.is_empty(), cfg.max_rounds);
    let labels = alive.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
    Ok(RunResult { app: App::Kcore, labels, rounds, total_cycles, converged })
}

/// Survival flags for a full degree array.
fn survival(
    pjrt: Option<&PjrtRuntime>,
    cfg: &EngineConfig,
    deg: &[u32],
    k: u32,
) -> Result<Vec<bool>> {
    survival_list(pjrt, cfg, deg, k)
}

/// Survival flags for an arbitrary degree list, tiled through the kernel in
/// Pjrt mode.
fn survival_list(
    pjrt: Option<&PjrtRuntime>,
    cfg: &EngineConfig,
    deg: &[u32],
    k: u32,
) -> Result<Vec<bool>> {
    match (cfg.compute, pjrt) {
        (ComputeMode::Pjrt, Some(rt)) if !deg.is_empty() => {
            let mut out = Vec::with_capacity(deg.len());
            let tile = 16_384.min(deg.len());
            for start in (0..deg.len()).step_by(tile) {
                let end = (start + tile).min(deg.len());
                out.extend(rt.kcore_alive(&deg[start..end], k)?);
            }
            Ok(out)
        }
        _ => Ok(deg.iter().map(|&d| d >= k).collect()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat::{self, RmatConfig};
    use crate::graph::EdgeList;

    fn rmat(scale: u32, seed: u64) -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(scale, seed)))
    }

    fn cfg_with(balancer: Balancer) -> EngineConfig {
        EngineConfig { balancer, ..EngineConfig::default() }
    }

    fn all_balancers() -> Vec<Balancer> {
        vec![
            Balancer::Vertex,
            Balancer::Twc,
            Balancer::EdgeLb { distribution: Distribution::Cyclic },
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
            Balancer::Alb { distribution: Distribution::Blocked, threshold: None },
        ]
    }

    #[test]
    fn bfs_matches_oracle_under_every_balancer() {
        let mut g = rmat(9, 1);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        for b in all_balancers() {
            let r = run(App::Bfs, &mut g, src, &cfg_with(b.clone()), None).unwrap();
            assert_eq!(r.labels, want, "balancer {}", b.name());
        }
    }

    #[test]
    fn sssp_matches_oracle() {
        let mut g = rmat(9, 2);
        let src = g.max_out_degree_vertex();
        let want = sssp::oracle(&g, src);
        let r = run(App::Sssp, &mut g, src, &EngineConfig::default(), None).unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn cc_matches_oracle() {
        let mut g = rmat(8, 3);
        let want = cc::oracle(&g);
        let r = run(App::Cc, &mut g, 0, &EngineConfig::default(), None).unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn pr_matches_oracle() {
        let mut g = rmat(8, 4);
        let cfg = EngineConfig { max_rounds: 100, ..EngineConfig::default() };
        let r = run(App::Pr, &mut g.clone(), 0, &cfg, None).unwrap();
        let (want, oracle_rounds) = pr::oracle(&mut g, cfg.pr_tol, 100);
        assert_eq!(r.labels, want);
        assert_eq!(r.rounds.len() as u32, oracle_rounds);
    }

    #[test]
    fn kcore_matches_oracle() {
        let mut g = rmat(8, 5);
        let cfg = EngineConfig { kcore_k: 8, ..EngineConfig::default() };
        let r = run(App::Kcore, &mut g.clone(), 0, &cfg, None).unwrap();
        let (want, _) = kcore::oracle(&mut g, 8);
        let got: Vec<bool> = r.labels.iter().map(|&x| x > 0.5).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn alb_faster_than_twc_on_skewed_input() {
        // Table 2's headline: rmat push apps speed up under ALB.
        let mut g = rmat(12, 6);
        let src = g.max_out_degree_vertex();
        let alb = run(App::Bfs, &mut g, src, &cfg_with(Balancer::Alb {
            distribution: Distribution::Cyclic,
            threshold: None,
        }), None)
        .unwrap();
        let twc = run(App::Bfs, &mut g, src, &cfg_with(Balancer::Twc), None).unwrap();
        assert_eq!(alb.labels, twc.labels);
        assert!(
            alb.total_cycles < twc.total_cycles,
            "alb {} vs twc {}",
            alb.total_cycles,
            twc.total_cycles
        );
        assert!(alb.rounds_with_lb() > 0, "ALB must trigger on rmat");
    }

    #[test]
    fn alb_stays_dormant_on_flat_degrees() {
        // road-USA regime: no huge vertices, LB never launches.
        let mut el = EdgeList::new(4096);
        for v in 0..4095u32 {
            el.push(v, v + 1, 1.0);
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let r = run(App::Bfs, &mut g, 0, &EngineConfig::default(), None).unwrap();
        assert_eq!(r.rounds_with_lb(), 0);
    }

    #[test]
    fn sparse_worklist_cheaper_when_few_active() {
        // §6.1: the dense scan dominates on long-diameter graphs.
        let mut el = EdgeList::new(8192);
        for v in 0..8191u32 {
            el.push(v, v + 1, 1.0);
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let dense = run(App::Bfs, &mut g, 0, &EngineConfig {
            worklist: WorklistKind::Dense,
            ..EngineConfig::default()
        }, None)
        .unwrap();
        let sparse = run(App::Bfs, &mut g, 0, &EngineConfig {
            worklist: WorklistKind::Sparse,
            ..EngineConfig::default()
        }, None)
        .unwrap();
        assert_eq!(dense.labels, sparse.labels);
        assert!(sparse.total_cycles < dense.total_cycles);
    }

    #[test]
    fn pjrt_mode_requires_runtime() {
        let mut g = rmat(6, 7);
        let cfg = EngineConfig { compute: ComputeMode::Pjrt, ..EngineConfig::default() };
        assert!(run(App::Bfs, &mut g, 0, &cfg, None).is_err());
    }

    #[test]
    fn record_blocks_attaches_kernel_stats() {
        let mut g = rmat(7, 8);
        let cfg = EngineConfig { record_blocks: true, ..EngineConfig::default() };
        let src = g.max_out_degree_vertex();
        let r = run(App::Bfs, &mut g, src, &cfg, None).unwrap();
        assert!(r.rounds[0].kernels.is_some());
        // Every round carries its own stats (the move out of the scratch
        // must not leave later rounds empty).
        for rec in &r.rounds {
            let ks = rec.kernels.as_ref().unwrap();
            assert!(!ks.is_empty(), "round {} lost its kernel stats", rec.round);
            assert_eq!(ks[0].label, "twc");
        }
    }

    #[test]
    fn sim_threads_do_not_change_results() {
        // §9 determinism at engine granularity: labels, per-round records,
        // and totals are bit-identical for any pool width.
        let mut g = rmat(10, 18);
        let src = g.max_out_degree_vertex();
        let base = run(
            App::Bfs,
            &mut g.clone(),
            src,
            &EngineConfig { sim_threads: 1, ..EngineConfig::default() },
            None,
        )
        .unwrap();
        for threads in [2usize, 4, 7] {
            let cfg = EngineConfig { sim_threads: threads, ..EngineConfig::default() };
            let r = run(App::Bfs, &mut g.clone(), src, &cfg, None).unwrap();
            assert_eq!(r, base, "sim_threads={threads}");
        }
    }

    #[test]
    fn reference_engine_matches_hot_path() {
        // The fresh-allocation golden reference and the scratch-reuse
        // engine must agree bit-for-bit: labels, per-round records, total.
        let mut g = rmat(10, 12);
        let src = g.max_out_degree_vertex();
        for app in [App::Bfs, App::Sssp, App::Cc] {
            for b in all_balancers() {
                let cfg = cfg_with(b);
                let hot = run(app, &mut g.clone(), src, &cfg, None).unwrap();
                let golden =
                    run_push_reference(app, &mut g.clone(), src, &cfg).unwrap();
                assert_eq!(hot, golden, "{}", app.name());
            }
        }
    }

    #[test]
    fn direction_opt_bfs_matches_oracle() {
        let mut g = rmat(11, 13);
        let src = g.max_out_degree_vertex();
        let want = bfs::oracle(&g, src);
        let cfg = EngineConfig { bfs_direction_opt: true, ..EngineConfig::default() };
        let r = run(App::Bfs, &mut g, src, &cfg, None).unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn direction_opt_helps_on_power_law() {
        // Big frontiers on rmat -> pull rounds with early exit beat pushing
        // the whole frontier's edges (Gunrock's parenthetical Table 2 bfs).
        let mut g = rmat(12, 14);
        let src = g.max_out_degree_vertex();
        let plain = run(App::Bfs, &mut g, src, &EngineConfig::default(), None).unwrap();
        let cfg = EngineConfig { bfs_direction_opt: true, ..EngineConfig::default() };
        let dopt = run(App::Bfs, &mut g, src, &cfg, None).unwrap();
        assert_eq!(plain.labels, dopt.labels);
        assert!(
            dopt.total_cycles < plain.total_cycles,
            "dopt {} vs plain {}",
            dopt.total_cycles,
            plain.total_cycles
        );
    }

    #[test]
    fn delta_stepping_matches_dijkstra() {
        let mut g = rmat(10, 15);
        let src = g.max_out_degree_vertex();
        let want = sssp::oracle(&g, src);
        for delta in [1.0f32, 10.0, 50.0, 1000.0] {
            let cfg = EngineConfig {
                sssp_delta: Some(delta),
                max_rounds: 1_000_000,
                ..EngineConfig::default()
            };
            let r = run(App::Sssp, &mut g, src, &cfg, None).unwrap();
            assert_eq!(r.labels, want, "delta {delta}");
        }
    }

    #[test]
    fn delta_stepping_survives_tiny_delta_on_disconnected_graph() {
        // Regression (ISSUE 4): with a tiny delta, every distance/delta
        // ratio saturates the `as usize` cast; pre-fix the requeue resized
        // the bucket array toward usize::MAX and panicked with "capacity
        // overflow" (or OOM'd on merely-huge finite ratios). The clamp
        // folds far distances into one tail bucket that is re-settled
        // until drained, and the unreached component stays at INF.
        let mut el = EdgeList::new(64);
        for v in 0..31u32 {
            el.push(v, v + 1, 100.0); // weighted path, reached component
        }
        for v in 33..63u32 {
            el.push(v, v + 1, 1.0); // disconnected from the source
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let want = sssp::oracle(&g, 0);
        assert!(want.iter().any(|&d| d >= INF), "graph must be disconnected");
        for delta in [1e-30f32, 1e-6, 0.5] {
            let cfg = EngineConfig {
                sssp_delta: Some(delta),
                max_rounds: 1_000_000,
                ..EngineConfig::default()
            };
            let r = run(App::Sssp, &mut g, 0, &cfg, None).unwrap();
            assert_eq!(r.labels, want, "delta {delta}");
        }
    }

    #[test]
    fn delta_stepping_clamped_tail_still_matches_dijkstra() {
        // A long weighted chain whose far distances overflow the clamp
        // boundary (TAIL_BUCKET * delta): the tail bucket must re-settle
        // itself instead of dropping pending heavy requeues.
        let n = 512u32;
        let mut el = EdgeList::new(n);
        for v in 0..n - 1 {
            el.push(v, v + 1, 100.0);
        }
        let mut g = CsrGraph::from_edge_list(&el);
        let want = sssp::oracle(&g, 0);
        let cfg = EngineConfig {
            sssp_delta: Some(1e-4),
            max_rounds: 1_000_000,
            ..EngineConfig::default()
        };
        let r = run(App::Sssp, &mut g, 0, &cfg, None).unwrap();
        assert_eq!(r.labels, want);
    }

    #[test]
    fn delta_stepping_does_fewer_wasted_relaxations() {
        // Bucketed ordering re-relaxes fewer edges than chaotic rounds on
        // weighted graphs: total processed edges should not be larger.
        let mut g = rmat(11, 16);
        let src = g.max_out_degree_vertex();
        let plain = run(App::Sssp, &mut g, src, &EngineConfig::default(), None).unwrap();
        let cfg = EngineConfig {
            sssp_delta: Some(25.0),
            max_rounds: 1_000_000,
            ..EngineConfig::default()
        };
        let ds = run(App::Sssp, &mut g, src, &cfg, None).unwrap();
        assert_eq!(plain.labels, ds.labels);
        assert!(ds.total_edges() > 0);
    }

    #[test]
    fn enterprise_between_twc_and_alb() {
        let mut g = rmat(12, 17);
        let src = g.max_out_degree_vertex();
        let t = run(App::Bfs, &mut g, src, &cfg_with(Balancer::Twc), None).unwrap();
        let e = run(App::Bfs, &mut g, src, &cfg_with(Balancer::Enterprise), None).unwrap();
        let a = run(App::Bfs, &mut g, src, &cfg_with(Balancer::Alb {
            distribution: Distribution::Cyclic,
            threshold: None,
        }), None).unwrap();
        assert_eq!(t.labels, e.labels);
        assert_eq!(t.labels, a.labels);
        assert!(e.total_cycles < t.total_cycles, "enterprise must beat TWC");
        assert!(a.total_cycles <= e.total_cycles, "ALB must not lose to enterprise");
    }

    #[test]
    fn run_result_accounting() {
        let mut g = rmat(7, 9);
        let src = g.max_out_degree_vertex();
        let r = run(App::Bfs, &mut g, src, &EngineConfig::default(), None).unwrap();
        assert!(r.total_cycles > 0);
        assert_eq!(r.total_cycles, r.rounds.iter().map(|x| x.cycles).sum::<u64>());
        assert!(r.ms(&GpuSpec::default_sim()) > 0.0);
        assert!(r.total_edges() > 0);
    }

    // ------------------------------- runtime-adaptive controller wiring

    fn adaptive_cfg() -> EngineConfig {
        cfg_with(Balancer::Adaptive {
            distribution: Distribution::Cyclic,
            threshold: None,
        })
    }

    fn plain_alb_cfg() -> EngineConfig {
        cfg_with(Balancer::Alb {
            distribution: Distribution::Cyclic,
            threshold: None,
        })
    }

    #[test]
    fn adaptive_round_zero_matches_plain_alb() {
        // The controller starts at ALB's threshold and only moves *after*
        // observing a round, so round 0 must be bit-identical to plain ALB
        // (and static runs must carry no controller trace at all).
        let mut g = rmat(12, 6);
        let src = g.max_out_degree_vertex();
        let alb = run(App::Bfs, &mut g, src, &plain_alb_cfg(), None).unwrap();
        let ada = run(App::Bfs, &mut g, src, &adaptive_cfg(), None).unwrap();
        assert_eq!(ada.labels, alb.labels);
        let (a0, b0) = (&ada.rounds[0], &alb.rounds[0]);
        assert_eq!(a0.cycles, b0.cycles, "round 0 must be plain ALB");
        assert_eq!(a0.edges, b0.edges);
        assert_eq!(a0.lb_triggered, b0.lb_triggered);
        let trace = a0.adaptive.as_ref().expect("adaptive rounds carry a trace");
        assert_eq!(trace.threshold, GpuSpec::default_sim().huge_threshold());
        assert_eq!(trace.sample_cap, CostModel::default().lb_warp_step_sample_cap);
        assert!(ada.rounds.iter().all(|r| r.adaptive.is_some()));
        assert!(alb.rounds.iter().all(|r| r.adaptive.is_none()));
    }

    #[test]
    fn adaptive_is_deterministic_across_sim_threads() {
        // The signal the controller consumes is itself deterministic
        // (DESIGN.md §9), so the whole feedback trajectory — thresholds,
        // sampling budgets, cycles — is bit-identical for any pool width.
        let mut g = rmat(12, 6);
        let src = g.max_out_degree_vertex();
        let base = run(
            App::Bfs,
            &mut g.clone(),
            src,
            &EngineConfig { sim_threads: 1, ..adaptive_cfg() },
            None,
        )
        .unwrap();
        for threads in [2usize, 4, 7] {
            let cfg = EngineConfig { sim_threads: threads, ..adaptive_cfg() };
            let r = run(App::Bfs, &mut g.clone(), src, &cfg, None).unwrap();
            assert_eq!(r, base, "sim_threads={threads}");
        }
    }

    #[test]
    fn adaptive_never_loses_to_plain_alb_on_skewed_input() {
        // The CI adaptive-gate's property at unit scale: starting as ALB
        // and shifting work only off a dominant, imbalanced TWC kernel must
        // not cost cycles on the skewed inputs ALB targets.
        let mut g = rmat(12, 6);
        let src = g.max_out_degree_vertex();
        let alb = run(App::Bfs, &mut g, src, &plain_alb_cfg(), None).unwrap();
        let ada = run(App::Bfs, &mut g, src, &adaptive_cfg(), None).unwrap();
        assert_eq!(ada.labels, alb.labels);
        assert!(
            ada.total_cycles <= alb.total_cycles,
            "adaptive {} vs alb {}",
            ada.total_cycles,
            alb.total_cycles
        );
    }

    #[test]
    fn adaptive_covers_every_app_driver() {
        // Each driver loop (push, dopt, delta, pr, kcore) threads the
        // controller: every simulated round must carry a trace and labels
        // must match the static-ALB run.
        let mut g = rmat(10, 19);
        let src = g.max_out_degree_vertex();
        let cfgs: Vec<(App, EngineConfig)> = vec![
            (App::Bfs, EngineConfig { bfs_direction_opt: true, ..adaptive_cfg() }),
            (App::Sssp, EngineConfig {
                sssp_delta: Some(25.0),
                max_rounds: 1_000_000,
                ..adaptive_cfg()
            }),
            (App::Pr, EngineConfig { max_rounds: 100, ..adaptive_cfg() }),
            (App::Kcore, adaptive_cfg()),
        ];
        for (app, cfg) in cfgs {
            let ada = run(app, &mut g.clone(), src, &cfg, None).unwrap();
            let alb = run(
                app,
                &mut g.clone(),
                src,
                &EngineConfig { balancer: plain_alb_cfg().balancer, ..cfg.clone() },
                None,
            )
            .unwrap();
            assert_eq!(ada.labels, alb.labels, "{}", app.name());
            assert!(
                ada.rounds.iter().all(|r| r.adaptive.is_some()),
                "{} rounds must carry the controller trace",
                app.name()
            );
        }
    }
}
