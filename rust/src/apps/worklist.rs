//! Worklists: how the active set is discovered each round.
//!
//! D-IrGL (and therefore ALB) uses an *implicit dense* worklist — every round
//! scans all |V| local vertices for an "active" flag. Gunrock keeps an
//! *explicit sparse* worklist of just the active ids. §6.1 shows where this
//! matters: bfs/cc on road-USA have tiny active sets, so the dense scan
//! dominates and Gunrock wins those cells despite weaker balancing.
//!
//! Functionally both produce the same active set; they differ in the
//! `scan_vertices` cost the engine charges to the simulator.

/// Worklist discovery policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorklistKind {
    /// Scan all |V| vertices for the active flag (D-IrGL style).
    Dense,
    /// Maintain explicit active-id lists (Gunrock style).
    Sparse,
}

impl WorklistKind {
    /// Vertices the runtime must touch to discover `active_len` actives.
    pub fn scan_cost(&self, num_vertices: u64, active_len: u64) -> u64 {
        match self {
            WorklistKind::Dense => num_vertices,
            WorklistKind::Sparse => active_len,
        }
    }
}

/// Deduplicating active-set builder for the *next* round: push-style
/// operators activate the same destination many times; the dense bitmap
/// keeps the worklist a set (matching `WL.push` + the dense-flag semantics).
///
/// §Perf (DESIGN.md §8): membership is one bit per vertex, and draining is
/// a counting pass over the touched word range — ascending bit order *is*
/// sorted order, so the per-round `sort_unstable` + `dedup` of the old
/// explicit-list implementation disappears while the output stays
/// bit-identical. The struct is reused across rounds (the engine's
/// `RoundScratch` owns one); steady-state pushes and drains allocate
/// nothing.
#[derive(Debug)]
pub struct NextWorklist {
    /// Dense membership bitmap, bit `v` = vertex `v` activated.
    words: Vec<u64>,
    /// Number of set bits.
    len: usize,
    /// Touched word range: `lo..hi` bounds the counting pass so tiny
    /// frontiers on huge graphs do not rescan the whole bitmap.
    lo: usize,
    hi: usize,
}

impl Default for NextWorklist {
    /// Route through [`new`](Self::new) so the empty sentinel (`lo =
    /// usize::MAX`) holds — a derived default (`lo = 0`) would silently
    /// defeat the touched-range optimization on the first drain.
    fn default() -> Self {
        NextWorklist::new(0)
    }
}

impl NextWorklist {
    pub fn new(num_vertices: usize) -> Self {
        NextWorklist {
            words: vec![0; num_vertices.div_ceil(64)],
            len: 0,
            lo: usize::MAX,
            hi: 0,
        }
    }

    /// Grow (never shrink) to cover `num_vertices`.
    pub fn resize_for(&mut self, num_vertices: usize) {
        let nw = num_vertices.div_ceil(64);
        if self.words.len() < nw {
            self.words.resize(nw, 0);
        }
    }

    /// Add vertex `v`; idempotent.
    #[inline]
    pub fn push(&mut self, v: u32) {
        let w = (v >> 6) as usize;
        let bit = 1u64 << (v & 63);
        let word = &mut self.words[w];
        if *word & bit == 0 {
            *word |= bit;
            self.len += 1;
            self.lo = self.lo.min(w);
            self.hi = self.hi.max(w + 1);
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, v: u32) -> bool {
        self.words[(v >> 6) as usize] & (1u64 << (v & 63)) != 0
    }

    /// Drain into a sorted active list, resetting for reuse.
    pub fn take_sorted(&mut self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len);
        self.take_sorted_into(&mut out);
        out
    }

    /// Drain into `out` (cleared first) in ascending vertex order,
    /// resetting for reuse. The counting pass walks only the touched word
    /// range and zeroes it on the way out.
    pub fn take_sorted_into(&mut self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.len);
        if self.len > 0 {
            for wi in self.lo..self.hi {
                let mut word = self.words[wi];
                if word == 0 {
                    continue;
                }
                self.words[wi] = 0;
                let base = (wi as u32) << 6;
                while word != 0 {
                    out.push(base + word.trailing_zeros());
                    word &= word - 1;
                }
            }
        }
        self.len = 0;
        self.lo = usize::MAX;
        self.hi = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_cost_dense_vs_sparse() {
        assert_eq!(WorklistKind::Dense.scan_cost(1000, 3), 1000);
        assert_eq!(WorklistKind::Sparse.scan_cost(1000, 3), 3);
    }

    #[test]
    fn push_dedups() {
        let mut wl = NextWorklist::new(10);
        wl.push(3);
        wl.push(3);
        wl.push(7);
        assert_eq!(wl.len(), 2);
        assert!(wl.contains(3));
        assert!(!wl.contains(4));
    }

    #[test]
    fn take_sorted_resets() {
        let mut wl = NextWorklist::new(10);
        wl.push(7);
        wl.push(2);
        wl.push(5);
        assert_eq!(wl.take_sorted(), vec![2, 5, 7]);
        assert!(wl.is_empty());
        assert!(!wl.contains(7));
        wl.push(7); // reusable after take
        assert_eq!(wl.take_sorted(), vec![7]);
    }

    #[test]
    fn empty_take() {
        let mut wl = NextWorklist::new(4);
        assert!(wl.take_sorted().is_empty());
    }

    #[test]
    fn take_sorted_into_reuses_buffer_and_matches_sort_dedup() {
        // The bitmap drain must equal the legacy sort+dedup bit-for-bit.
        let n = 5000usize;
        let mut wl = NextWorklist::new(n);
        let mut out = Vec::new();
        // Deterministic pseudo-random pushes with duplicates.
        let mut x = 12345u64;
        for round in 0..5 {
            let mut reference: Vec<u32> = Vec::new();
            for _ in 0..800 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(round + 1);
                let v = (x >> 33) as u32 % n as u32;
                wl.push(v);
                reference.push(v);
            }
            reference.sort_unstable();
            reference.dedup();
            assert_eq!(wl.len(), reference.len());
            wl.take_sorted_into(&mut out);
            assert_eq!(out, reference, "round {round}");
            assert!(wl.is_empty());
        }
    }

    #[test]
    fn word_boundaries_drain_in_order() {
        let mut wl = NextWorklist::new(200);
        for v in [63u32, 64, 127, 128, 0, 199, 65] {
            wl.push(v);
        }
        assert_eq!(wl.take_sorted(), vec![0, 63, 64, 65, 127, 128, 199]);
    }

    #[test]
    fn resize_for_grows_only() {
        let mut wl = NextWorklist::new(64);
        wl.resize_for(1000);
        wl.push(999);
        assert!(wl.contains(999));
        wl.resize_for(10); // no shrink: 999 still representable
        assert!(wl.contains(999));
    }
}
