//! Worklists: how the active set is discovered each round.
//!
//! D-IrGL (and therefore ALB) uses an *implicit dense* worklist — every round
//! scans all |V| local vertices for an "active" flag. Gunrock keeps an
//! *explicit sparse* worklist of just the active ids. §6.1 shows where this
//! matters: bfs/cc on road-USA have tiny active sets, so the dense scan
//! dominates and Gunrock wins those cells despite weaker balancing.
//!
//! Functionally both produce the same active set; they differ in the
//! `scan_vertices` cost the engine charges to the simulator.

/// Worklist discovery policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorklistKind {
    /// Scan all |V| vertices for the active flag (D-IrGL style).
    Dense,
    /// Maintain explicit active-id lists (Gunrock style).
    Sparse,
}

impl WorklistKind {
    /// Vertices the runtime must touch to discover `active_len` actives.
    pub fn scan_cost(&self, num_vertices: u64, active_len: u64) -> u64 {
        match self {
            WorklistKind::Dense => num_vertices,
            WorklistKind::Sparse => active_len,
        }
    }
}

/// Deduplicating active-set builder for the *next* round: push-style
/// operators activate the same destination many times; the dense bitmap
/// keeps the worklist a set (matching `WL.push` + the dense-flag semantics).
///
/// §Perf (DESIGN.md §8): membership is one bit per vertex, and draining is
/// a counting pass over the touched word range — ascending bit order *is*
/// sorted order, so the per-round `sort_unstable` + `dedup` of the old
/// explicit-list implementation disappears while the output stays
/// bit-identical. The struct is reused across rounds (the engine's
/// `RoundScratch` owns one); steady-state pushes and drains allocate
/// nothing.
#[derive(Debug)]
pub struct NextWorklist {
    /// Dense membership bitmap, bit `v` = vertex `v` activated.
    words: Vec<u64>,
    /// Number of set bits.
    len: usize,
    /// Touched word range: `lo..hi` bounds the counting pass so tiny
    /// frontiers on huge graphs do not rescan the whole bitmap.
    lo: usize,
    hi: usize,
}

impl Default for NextWorklist {
    /// Route through [`new`](Self::new) so the empty sentinel (`lo =
    /// usize::MAX`) holds — a derived default (`lo = 0`) would silently
    /// defeat the touched-range optimization on the first drain.
    fn default() -> Self {
        NextWorklist::new(0)
    }
}

impl NextWorklist {
    pub fn new(num_vertices: usize) -> Self {
        NextWorklist {
            words: vec![0; num_vertices.div_ceil(64)],
            len: 0,
            lo: usize::MAX,
            hi: 0,
        }
    }

    /// Grow (never shrink) to cover `num_vertices`.
    pub fn resize_for(&mut self, num_vertices: usize) {
        let nw = num_vertices.div_ceil(64);
        if self.words.len() < nw {
            self.words.resize(nw, 0);
        }
    }

    /// Add vertex `v`; idempotent.
    #[inline]
    pub fn push(&mut self, v: u32) {
        let w = (v >> 6) as usize;
        let bit = 1u64 << (v & 63);
        let word = &mut self.words[w];
        if *word & bit == 0 {
            *word |= bit;
            self.len += 1;
            self.lo = self.lo.min(w);
            self.hi = self.hi.max(w + 1);
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, v: u32) -> bool {
        self.words[(v >> 6) as usize] & (1u64 << (v & 63)) != 0
    }

    /// Drop every queued vertex without decoding, restoring the empty
    /// sentinel. Zeroes only the touched word range, so recycling a bitmap
    /// across runs ([`RoundScratch::reset_for`]
    /// (crate::apps::engine::RoundScratch::reset_for)) costs nothing when
    /// the previous run drained cleanly.
    pub fn clear(&mut self) {
        if self.lo != usize::MAX {
            for w in &mut self.words[self.lo..self.hi] {
                *w = 0;
            }
        }
        self.len = 0;
        self.lo = usize::MAX;
        self.hi = 0;
    }

    /// Drain into a sorted active list, resetting for reuse.
    pub fn take_sorted(&mut self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len);
        self.take_sorted_into(&mut out);
        out
    }

    /// Drain into `out` (cleared first) in ascending vertex order,
    /// resetting for reuse. The counting pass walks only the touched word
    /// range and zeroes it on the way out.
    ///
    /// §Perf (DESIGN.md §13): the walk is SWAR-batched — four words are
    /// OR-combined per step so all-zero stretches cost one compare, and
    /// dense words (>= [`DENSE_POPCOUNT`] set bits) decode eight bits per
    /// step through the precomputed [`BYTE_BITS`] position table instead of
    /// one trailing-zeros iteration per bit. Sparse words keep the
    /// trailing-zeros walk, which is faster when only a few bits are set.
    /// Output order is ascending either way, so the result is bit-identical
    /// to [`take_sorted_into_ref`](Self::take_sorted_into_ref).
    pub fn take_sorted_into(&mut self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.len);
        if self.len > 0 {
            let hi = self.hi;
            let mut wi = self.lo;
            while wi + 4 <= hi {
                let w = &self.words[wi..wi + 4];
                if w[0] | w[1] | w[2] | w[3] != 0 {
                    for k in 0..4 {
                        self.drain_word(wi + k, out);
                    }
                }
                wi += 4;
            }
            while wi < hi {
                self.drain_word(wi, out);
                wi += 1;
            }
        }
        self.len = 0;
        self.lo = usize::MAX;
        self.hi = 0;
    }

    /// Decode and clear one bitmap word into `out`, ascending.
    #[inline]
    fn drain_word(&mut self, wi: usize, out: &mut Vec<u32>) {
        let mut word = self.words[wi];
        if word == 0 {
            return;
        }
        self.words[wi] = 0;
        let base = (wi as u32) << 6;
        if word.count_ones() >= DENSE_POPCOUNT {
            let mut off = 0u32;
            while word != 0 {
                let byte = (word & 0xFF) as usize;
                let positions = &BYTE_BITS[byte];
                for &p in &positions[..byte.count_ones() as usize] {
                    out.push(base + off + p as u32);
                }
                word >>= 8;
                off += 8;
            }
        } else {
            while word != 0 {
                out.push(base + word.trailing_zeros());
                word &= word - 1;
            }
        }
    }

    /// The pre-SWAR scalar drain (one trailing-zeros walk per word, no
    /// batched zero-skip, no dense-word byte decode), kept in-binary as the
    /// `-ref` twin for `benches/hotpath.rs` and the oracle tests. Not a hot
    /// path.
    #[doc(hidden)]
    pub fn take_sorted_into_ref(&mut self, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.len);
        if self.len > 0 {
            for wi in self.lo..self.hi {
                let mut word = self.words[wi];
                if word == 0 {
                    continue;
                }
                self.words[wi] = 0;
                let base = (wi as u32) << 6;
                while word != 0 {
                    out.push(base + word.trailing_zeros());
                    word &= word - 1;
                }
            }
        }
        self.len = 0;
        self.lo = usize::MAX;
        self.hi = 0;
    }
}

/// Words with at least this many set bits take the byte-table decode; below
/// it the trailing-zeros walk wins (fewer iterations than table lookups).
const DENSE_POPCOUNT: u32 = 16;

/// `BYTE_BITS[b]` lists the set-bit positions of byte `b` in ascending
/// order (only the first `b.count_ones()` entries are meaningful). Built at
/// compile time; 2 KiB, hot in L1 during dense drains.
static BYTE_BITS: [[u8; 8]; 256] = build_byte_bits();

const fn build_byte_bits() -> [[u8; 8]; 256] {
    let mut table = [[0u8; 8]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut n = 0usize;
        let mut i = 0u8;
        while i < 8 {
            if b & (1usize << i) != 0 {
                table[b][n] = i;
                n += 1;
            }
            i += 1;
        }
        b += 1;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_cost_dense_vs_sparse() {
        assert_eq!(WorklistKind::Dense.scan_cost(1000, 3), 1000);
        assert_eq!(WorklistKind::Sparse.scan_cost(1000, 3), 3);
    }

    #[test]
    fn push_dedups() {
        let mut wl = NextWorklist::new(10);
        wl.push(3);
        wl.push(3);
        wl.push(7);
        assert_eq!(wl.len(), 2);
        assert!(wl.contains(3));
        assert!(!wl.contains(4));
    }

    #[test]
    fn take_sorted_resets() {
        let mut wl = NextWorklist::new(10);
        wl.push(7);
        wl.push(2);
        wl.push(5);
        assert_eq!(wl.take_sorted(), vec![2, 5, 7]);
        assert!(wl.is_empty());
        assert!(!wl.contains(7));
        wl.push(7); // reusable after take
        assert_eq!(wl.take_sorted(), vec![7]);
    }

    #[test]
    fn empty_take() {
        let mut wl = NextWorklist::new(4);
        assert!(wl.take_sorted().is_empty());
    }

    #[test]
    fn take_sorted_into_reuses_buffer_and_matches_sort_dedup() {
        // The bitmap drain must equal the legacy sort+dedup bit-for-bit.
        let n = 5000usize;
        let mut wl = NextWorklist::new(n);
        let mut out = Vec::new();
        // Deterministic pseudo-random pushes with duplicates.
        let mut x = 12345u64;
        for round in 0..5 {
            let mut reference: Vec<u32> = Vec::new();
            for _ in 0..800 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(round + 1);
                let v = (x >> 33) as u32 % n as u32;
                wl.push(v);
                reference.push(v);
            }
            reference.sort_unstable();
            reference.dedup();
            assert_eq!(wl.len(), reference.len());
            wl.take_sorted_into(&mut out);
            assert_eq!(out, reference, "round {round}");
            assert!(wl.is_empty());
        }
    }

    #[test]
    fn word_boundaries_drain_in_order() {
        let mut wl = NextWorklist::new(200);
        for v in [63u32, 64, 127, 128, 0, 199, 65] {
            wl.push(v);
        }
        assert_eq!(wl.take_sorted(), vec![0, 63, 64, 65, 127, 128, 199]);
    }

    #[test]
    fn resize_for_grows_only() {
        let mut wl = NextWorklist::new(64);
        wl.resize_for(1000);
        wl.push(999);
        assert!(wl.contains(999));
        wl.resize_for(10); // no shrink: 999 still representable
        assert!(wl.contains(999));
    }

    /// Push the same vertex set into two worklists and compare the SWAR
    /// drain against the scalar reference, bit for bit.
    fn assert_drains_agree(n: usize, vertices: &[u32]) {
        let mut opt = NextWorklist::new(n);
        let mut rf = NextWorklist::new(n);
        for &v in vertices {
            opt.push(v);
            rf.push(v);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        opt.take_sorted_into(&mut a);
        rf.take_sorted_into_ref(&mut b);
        assert_eq!(a, b);
        assert!(opt.is_empty() && rf.is_empty());
    }

    #[test]
    fn swar_drain_oracle_random_bitmaps() {
        // Densities from near-empty to near-full exercise both decode arms
        // (trailing-zeros for sparse words, byte table for dense) and the
        // 4-word zero-skip over untouched stretches.
        let n = 4096usize;
        let mut x = 0x9e3779b97f4a7c15u64;
        for density in [1usize, 8, 64, 700, 3000, 4000] {
            let mut vs = Vec::new();
            for _ in 0..density {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                vs.push((x >> 33) as u32 % n as u32);
            }
            assert_drains_agree(n, &vs);
        }
    }

    #[test]
    fn swar_drain_oracle_edges() {
        let n = 640usize;
        // All-zeros, all-ones, and single bits at every word boundary.
        assert_drains_agree(n, &[]);
        let all: Vec<u32> = (0..n as u32).collect();
        assert_drains_agree(n, &all);
        assert_drains_agree(n, &[0]);
        assert_drains_agree(n, &[n as u32 - 1]);
        for b in [63u32, 64, 127, 128, 191, 192, 255, 256, 639] {
            assert_drains_agree(n, &[b]);
        }
        // One fully-dense word surrounded by zero words (tests the dense
        // byte decode inside a zero-skipped stretch).
        let dense: Vec<u32> = (256..320u32).collect();
        assert_drains_agree(n, &dense);
        // Exactly DENSE_POPCOUNT bits in one word: the decode-arm boundary.
        let boundary: Vec<u32> = (0..super::DENSE_POPCOUNT).map(|i| 128 + i * 4).collect();
        assert_drains_agree(n, &boundary);
        // A touched range not divisible by 4 words (remainder loop).
        assert_drains_agree(n, &[70, 300]);
    }
}
