//! Worklists: how the active set is discovered each round.
//!
//! D-IrGL (and therefore ALB) uses an *implicit dense* worklist — every round
//! scans all |V| local vertices for an "active" flag. Gunrock keeps an
//! *explicit sparse* worklist of just the active ids. §6.1 shows where this
//! matters: bfs/cc on road-USA have tiny active sets, so the dense scan
//! dominates and Gunrock wins those cells despite weaker balancing.
//!
//! Functionally both produce the same active set; they differ in the
//! `scan_vertices` cost the engine charges to the simulator.

/// Worklist discovery policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorklistKind {
    /// Scan all |V| vertices for the active flag (D-IrGL style).
    Dense,
    /// Maintain explicit active-id lists (Gunrock style).
    Sparse,
}

impl WorklistKind {
    /// Vertices the runtime must touch to discover `active_len` actives.
    pub fn scan_cost(&self, num_vertices: u64, active_len: u64) -> u64 {
        match self {
            WorklistKind::Dense => num_vertices,
            WorklistKind::Sparse => active_len,
        }
    }
}

/// Deduplicating active-set builder for the *next* round: push-style
/// operators activate the same destination many times; the flag array keeps
/// the worklist a set (matching `WL.push` + the dense-flag semantics).
#[derive(Debug)]
pub struct NextWorklist {
    flags: Vec<bool>,
    items: Vec<u32>,
}

impl NextWorklist {
    pub fn new(num_vertices: usize) -> Self {
        NextWorklist { flags: vec![false; num_vertices], items: Vec::new() }
    }

    /// Add vertex `v`; idempotent.
    #[inline]
    pub fn push(&mut self, v: u32) {
        let f = &mut self.flags[v as usize];
        if !*f {
            *f = true;
            self.items.push(v);
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, v: u32) -> bool {
        self.flags[v as usize]
    }

    /// Drain into a sorted active list, resetting for reuse. Sorting keeps
    /// round order deterministic regardless of push order.
    pub fn take_sorted(&mut self) -> Vec<u32> {
        let mut items = std::mem::take(&mut self.items);
        for &v in &items {
            self.flags[v as usize] = false;
        }
        items.sort_unstable();
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_cost_dense_vs_sparse() {
        assert_eq!(WorklistKind::Dense.scan_cost(1000, 3), 1000);
        assert_eq!(WorklistKind::Sparse.scan_cost(1000, 3), 3);
    }

    #[test]
    fn push_dedups() {
        let mut wl = NextWorklist::new(10);
        wl.push(3);
        wl.push(3);
        wl.push(7);
        assert_eq!(wl.len(), 2);
        assert!(wl.contains(3));
        assert!(!wl.contains(4));
    }

    #[test]
    fn take_sorted_resets() {
        let mut wl = NextWorklist::new(10);
        wl.push(7);
        wl.push(2);
        wl.push(5);
        assert_eq!(wl.take_sorted(), vec![2, 5, 7]);
        assert!(wl.is_empty());
        assert!(!wl.contains(7));
        wl.push(7); // reusable after take
        assert_eq!(wl.take_sorted(), vec![7]);
    }

    #[test]
    fn empty_take() {
        let mut wl = NextWorklist::new(4);
        assert!(wl.take_sorted().is_empty());
    }
}
