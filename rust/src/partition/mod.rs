//! CuSP-like streaming graph partitioner (paper §5; Hoang et al. [13]).
//!
//! Splits a global graph into per-GPU partitions under three policies:
//!
//! * **OEC** (outgoing edge cut): vertices are assigned to owners by
//!   contiguous ranges balanced on *out*-degree; a partition holds every
//!   out-edge of its masters. Remote destinations appear as read/write
//!   *mirrors* (reduced back to masters after each round).
//! * **IEC** (incoming edge cut): ranges balanced on *in*-degree; a
//!   partition holds every in-edge of its masters; remote sources are
//!   read-only mirrors (refreshed by broadcast).
//! * **CVC** (cartesian vertex cut — the paper's default for multi-GPU
//!   runs): owners form a `pr x pc` grid; edge `(u, v)` goes to the
//!   partition at (row of u's owner, column of v's owner), bounding both
//!   mirror fan-in and fan-out.
//!
//! Every partition gets a local CSR (local ids: masters first, then
//! mirrors), plus the local<->global maps the Gluon-style communication
//! layer ([`crate::comm`]) uses.

use std::collections::HashMap;

use crate::graph::{CsrGraph, EdgeList};

/// Partitioning policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Oec,
    Iec,
    Cvc,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Oec => "oec",
            Policy::Iec => "iec",
            Policy::Cvc => "cvc",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "oec" => Some(Policy::Oec),
            "iec" => Some(Policy::Iec),
            "cvc" => Some(Policy::Cvc),
            _ => None,
        }
    }
}

/// Every name [`Policy::parse`] accepts, for error messages that name the
/// valid set (the C001 lint rule).
pub const POLICY_NAMES: &str = "oec, iec, cvc";

/// One GPU's partition.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: u32,
    /// Local CSR over local ids.
    pub graph: CsrGraph,
    /// local id -> global id (masters first, then mirrors).
    pub l2g: Vec<u32>,
    /// Local ids `[0, num_masters)` are masters owned by this partition.
    pub num_masters: usize,
}

impl Partition {
    pub fn num_mirrors(&self) -> usize {
        self.l2g.len() - self.num_masters
    }

    /// Global ids of this partition's mirrors.
    pub fn mirror_globals(&self) -> &[u32] {
        &self.l2g[self.num_masters..]
    }

    /// Local id of global `gid` in this partition, if present. Both the
    /// master and the mirror sections of `l2g` are sorted by global id, so
    /// two binary searches replace a `g2l` HashMap lookup on paths that
    /// only need occasional resolution (run setup, tests).
    pub fn local_of(&self, gid: u32) -> Option<u32> {
        if let Ok(i) = self.l2g[..self.num_masters].binary_search(&gid) {
            return Some(i as u32);
        }
        self.l2g[self.num_masters..]
            .binary_search(&gid)
            .ok()
            .map(|i| (self.num_masters + i) as u32)
    }
}

/// The partitioned graph plus ownership metadata.
#[derive(Debug, Clone)]
pub struct DistGraph {
    pub policy: Policy,
    pub num_global: u32,
    /// Owner partition of each global vertex.
    pub owner: Vec<u32>,
    pub parts: Vec<Partition>,
    /// Per-partition global->local maps.
    pub g2l: Vec<HashMap<u32, u32>>,
}

impl DistGraph {
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Total mirrors across partitions (replication overhead metric).
    pub fn total_mirrors(&self) -> usize {
        self.parts.iter().map(|p| p.num_mirrors()).sum()
    }
}

/// Assign contiguous owner ranges balanced by `weight(v)` (degree).
///
/// Degenerate-input contract (ISSUE 4): owners are monotone non-decreasing
/// and always `< k`; an empty weight list yields an empty assignment; when
/// `k > |V|` (or one mega-hub swallows the whole budget early) the trailing
/// partitions simply own nothing — they come out of [`partition`] as
/// well-formed empty partitions (0 masters, 0 mirrors, empty local CSR),
/// which the coordinator drives like any other GPU.
fn balanced_ranges(weights: &[u64], k: u32) -> Vec<u32> {
    if weights.is_empty() {
        return Vec::new();
    }
    let total: u64 = weights.iter().sum();
    let per = total.div_ceil(k as u64).max(1);
    let mut owner = vec![0u32; weights.len()];
    let mut acc = 0u64;
    let mut cur = 0u32;
    for (v, &w) in weights.iter().enumerate() {
        owner[v] = cur;
        acc += w;
        if acc >= per * (cur as u64 + 1) && cur + 1 < k {
            cur += 1;
        }
    }
    owner
}

/// Grid shape for CVC: the most square `pr x pc = k` factorization.
pub fn cvc_grid(k: u32) -> (u32, u32) {
    let mut best = (1, k);
    let mut r = 1;
    while r * r <= k {
        if k % r == 0 {
            best = (r, k / r);
        }
        r += 1;
    }
    best
}

/// Partition `g` into `k` parts.
pub fn partition(g: &CsrGraph, k: u32, policy: Policy) -> DistGraph {
    assert!(k >= 1);
    let n = g.num_vertices();
    // Owner assignment.
    let owner = match policy {
        Policy::Oec | Policy::Cvc => {
            let w: Vec<u64> = (0..n as u32).map(|v| g.out_degree(v) + 1).collect();
            balanced_ranges(&w, k)
        }
        Policy::Iec => {
            let mut counts = vec![1u64; n];
            for &d in &g.col_idx {
                counts[d as usize] += 1;
            }
            balanced_ranges(&counts, k)
        }
    };
    let (rows, cols) = cvc_grid(k);
    debug_assert_eq!(rows * cols, k);

    // Edge -> partition assignment.
    let mut edge_lists: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); k as usize];
    for u in 0..n as u32 {
        let (dsts, ws) = g.out_edges(u);
        for (&v, &w) in dsts.iter().zip(ws) {
            let p = match policy {
                Policy::Oec => owner[u as usize],
                Policy::Iec => owner[v as usize],
                Policy::Cvc => {
                    // Partition id p sits at grid cell (p / cols, p % cols),
                    // so the edge's cell must be derived the same way: row
                    // of u's owner, column of v's owner (ISSUE 4 bugfix —
                    // the old `owner % rows` row pick broke the row/column
                    // locality CVC exists to guarantee).
                    let r = owner[u as usize] / cols;
                    let c = owner[v as usize] % cols;
                    r * cols + c
                }
            };
            edge_lists[p as usize].push((u, v, w));
        }
    }

    // Build per-partition local graphs. A dense scratch map (global id ->
    // local id, reset per partition) keeps edge remapping O(1) per edge —
    // the public g2l HashMap is only built once per local vertex (§Perf:
    // replaced per-edge HashMap lookups and a sort-based mirror dedup).
    let mut parts = Vec::with_capacity(k as usize);
    let mut g2l_all = Vec::with_capacity(k as usize);
    let mut dense = vec![u32::MAX; n];
    let mut is_mirror = vec![false; n];
    for pid in 0..k {
        let edges = &edge_lists[pid as usize];
        // Mark mirrors: non-owned endpoints of local edges.
        for &(u, v, _) in edges {
            if owner[u as usize] != pid {
                is_mirror[u as usize] = true;
            }
            if owner[v as usize] != pid {
                is_mirror[v as usize] = true;
            }
        }
        // Local vertex set: own masters first (so every owned vertex exists
        // locally even if isolated), then mirrors in sorted global order
        // (the 0..n scan yields them sorted for free).
        let mut locals: Vec<u32> =
            (0..n as u32).filter(|&v| owner[v as usize] == pid).collect();
        let num_masters = locals.len();
        for v in 0..n as u32 {
            if is_mirror[v as usize] {
                locals.push(v);
                is_mirror[v as usize] = false; // reset for the next pass
            }
        }
        let l2g = locals;
        let mut g2l = HashMap::with_capacity(l2g.len());
        for (l, &gid) in l2g.iter().enumerate() {
            dense[gid as usize] = l as u32;
            g2l.insert(gid, l as u32);
        }
        let mut el = EdgeList::new(l2g.len() as u32);
        el.edges.reserve(edges.len());
        for &(u, v, w) in edges {
            el.push(dense[u as usize], dense[v as usize], w);
        }
        for &gid in &l2g {
            dense[gid as usize] = u32::MAX; // reset scratch
        }
        parts.push(Partition {
            id: pid,
            graph: CsrGraph::from_edge_list(&el),
            l2g,
            num_masters,
        });
        g2l_all.push(g2l);
    }
    DistGraph { policy, num_global: n as u32, owner, parts, g2l: g2l_all }
}

/// Re-partition after a GPU loss (ISSUE 8): the dead GPU's vertices are
/// redistributed across the `k_alive` survivors by running the full CuSP
/// streaming split at the new width. A fresh k-way split costs the same one
/// pass as any incremental patch-up would (the partitioner streams edges
/// once either way) and keeps the survivor layout identical to what a
/// fresh `k_alive`-GPU run would build — which is what lets the recovery
/// path reuse `ExchangePlan::new` wholesale and keeps replayed rounds
/// bit-deterministic.
pub fn repartition_survivors(g: &CsrGraph, k_alive: u32, policy: Policy) -> DistGraph {
    assert!(k_alive >= 1, "cannot re-partition onto zero survivors");
    partition(g, k_alive, policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat::{self, RmatConfig};

    fn test_graph() -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(9, 11)))
    }

    fn check_invariants(g: &CsrGraph, dg: &DistGraph) {
        // 1. every global vertex has exactly one owner, and appears as a
        //    master in exactly that partition.
        let mut master_count = vec![0u32; g.num_vertices()];
        for p in &dg.parts {
            for (l, &gid) in p.l2g.iter().enumerate() {
                if l < p.num_masters {
                    assert_eq!(dg.owner[gid as usize], p.id);
                    master_count[gid as usize] += 1;
                }
            }
        }
        assert!(master_count.iter().all(|&c| c == 1));
        // 2. edges are preserved exactly (as a multiset, global ids).
        let mut want: Vec<(u32, u32, u32)> = Vec::new();
        for u in 0..g.num_vertices() as u32 {
            let (d, w) = g.out_edges(u);
            for (&v, &x) in d.iter().zip(w) {
                want.push((u, v, x as u32));
            }
        }
        want.sort_unstable();
        let mut got: Vec<(u32, u32, u32)> = Vec::new();
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                let (d, w) = p.graph.out_edges(lu);
                for (&lv, &x) in d.iter().zip(w) {
                    got.push((p.l2g[lu as usize], p.l2g[lv as usize], x as u32));
                }
            }
        }
        got.sort_unstable();
        assert_eq!(want, got);
        // 3. l2g/g2l inverse.
        for (pi, p) in dg.parts.iter().enumerate() {
            for (l, &gid) in p.l2g.iter().enumerate() {
                assert_eq!(dg.g2l[pi][&gid], l as u32);
            }
        }
    }

    #[test]
    fn oec_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Oec));
    }

    #[test]
    fn iec_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Iec));
    }

    #[test]
    fn cvc_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Cvc));
        check_invariants(&g, &partition(&g, 6, Policy::Cvc));
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let g = test_graph();
        let dg = partition(&g, 1, Policy::Oec);
        assert_eq!(dg.parts.len(), 1);
        assert_eq!(dg.parts[0].num_masters, g.num_vertices());
        assert_eq!(dg.parts[0].graph.num_edges(), g.num_edges());
        assert_eq!(dg.total_mirrors(), 0);
    }

    #[test]
    fn oec_masters_hold_their_out_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Oec);
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                if p.graph.out_degree(lu) > 0 {
                    // Only masters have out-edges under OEC.
                    assert!((lu as usize) < p.num_masters);
                }
            }
        }
    }

    #[test]
    fn iec_masters_hold_their_in_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Iec);
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                let (dsts, _) = p.graph.out_edges(lu);
                for &lv in dsts {
                    assert!((lv as usize) < p.num_masters);
                }
            }
        }
    }

    #[test]
    fn oec_balances_out_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Oec);
        let loads: Vec<usize> =
            dg.parts.iter().map(|p| p.graph.num_edges()).collect();
        let max = *loads.iter().max().unwrap() as f64;
        let mean = g.num_edges() as f64 / 4.0;
        assert!(max / mean < 2.0, "edge balance {loads:?}");
    }

    #[test]
    fn cvc_grid_shapes() {
        assert_eq!(cvc_grid(1), (1, 1));
        assert_eq!(cvc_grid(4), (2, 2));
        assert_eq!(cvc_grid(6), (2, 3));
        assert_eq!(cvc_grid(16), (4, 4));
        assert_eq!(cvc_grid(7), (1, 7));
    }

    /// ISSUE 4 property test: under CVC with `p = r * cols + c`, every
    /// master's out-edges must land in its grid **row**, every master's
    /// in-edges in its grid **column**, and the mirror fan-in/fan-out bound
    /// follows: a vertex has copies in at most `rows + cols - 1` partitions.
    /// Includes prime `k`, where the grid degenerates to `1 x k`.
    #[test]
    fn cvc_edges_respect_grid_rows_and_columns() {
        let g = test_graph();
        for k in [2u32, 4, 6, 7, 12] {
            let dg = partition(&g, k, Policy::Cvc);
            let (rows, cols) = cvc_grid(k);
            for p in &dg.parts {
                let (r, c) = (p.id / cols, p.id % cols);
                for lu in 0..p.graph.num_vertices() as u32 {
                    let (dsts, _) = p.graph.out_edges(lu);
                    if dsts.is_empty() {
                        continue;
                    }
                    let gu = p.l2g[lu as usize] as usize;
                    assert_eq!(
                        dg.owner[gu] / cols,
                        r,
                        "k={k}: src owner row escaped partition {}",
                        p.id
                    );
                    for &lv in dsts {
                        let gv = p.l2g[lv as usize] as usize;
                        assert_eq!(
                            dg.owner[gv] % cols,
                            c,
                            "k={k}: dst owner column escaped partition {}",
                            p.id
                        );
                    }
                }
            }
            // Fan bound: out-copies live in the owner's row (<= cols cells),
            // in-copies in its column (<= rows cells), overlapping at the
            // owner cell.
            let mut copies = vec![0u32; g.num_vertices()];
            for p in &dg.parts {
                for &gid in &p.l2g {
                    copies[gid as usize] += 1;
                }
            }
            for (v, &cnt) in copies.iter().enumerate() {
                assert!(
                    cnt >= 1 && cnt <= rows + cols - 1,
                    "k={k} ({rows}x{cols}): vertex {v} has {cnt} copies"
                );
            }
        }
    }

    #[test]
    fn local_of_inverts_l2g_without_hashmap() {
        let g = test_graph();
        let dg = partition(&g, 6, Policy::Cvc);
        for p in &dg.parts {
            for (l, &gid) in p.l2g.iter().enumerate() {
                assert_eq!(p.local_of(gid), Some(l as u32));
            }
        }
        // A global that is neither master nor mirror resolves to None.
        for p in &dg.parts {
            let held: std::collections::HashSet<u32> =
                p.l2g.iter().copied().collect();
            if let Some(absent) =
                (0..g.num_vertices() as u32).find(|v| !held.contains(v))
            {
                assert_eq!(p.local_of(absent), None);
            }
        }
    }

    #[test]
    fn empty_graph_partitions_are_well_formed() {
        let g = CsrGraph::from_edge_list(&EdgeList::new(0));
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let dg = partition(&g, 4, policy);
            assert_eq!(dg.parts.len(), 4, "{policy:?}");
            for p in &dg.parts {
                assert_eq!(p.num_masters, 0);
                assert_eq!(p.num_mirrors(), 0);
                assert_eq!(p.graph.num_vertices(), 0);
                assert_eq!(p.graph.num_edges(), 0);
            }
        }
    }

    #[test]
    fn more_partitions_than_vertices_leaves_trailing_empties() {
        // k > n: every vertex still mastered exactly once; the surplus
        // partitions are empty but well-formed.
        let mut el = EdgeList::new(5);
        for v in 0..4u32 {
            el.push(v, v + 1, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let dg = partition(&g, 8, policy);
            check_invariants(&g, &dg);
            assert_eq!(dg.parts.len(), 8, "{policy:?}");
            let empties =
                dg.parts.iter().filter(|p| p.l2g.is_empty()).count();
            assert!(empties >= 3, "{policy:?}: expected trailing empties");
        }
    }

    #[test]
    fn mega_hub_keeps_every_partition_well_formed() {
        // One vertex owns almost all edges: the hub's partition absorbs the
        // weight budget immediately, later partitions own thin tails, and
        // any trailing empty partitions must still be well-formed.
        let n = 1024u32;
        let mut el = EdgeList::new(n);
        for i in 0..20_000u32 {
            el.push(0, 1 + (i % (n - 1)), 1.0);
        }
        for v in 1..64u32 {
            el.push(v, v + 1, 1.0);
        }
        let g = CsrGraph::from_edge_list(&el);
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let dg = partition(&g, 4, policy);
            check_invariants(&g, &dg);
            // Owners monotone non-decreasing (contiguous ranges).
            for w in dg.owner.windows(2) {
                assert!(w[0] <= w[1], "{policy:?}: owners not contiguous");
            }
        }
    }

    #[test]
    fn repartition_survivors_matches_fresh_partition() {
        let g = test_graph();
        for policy in [Policy::Oec, Policy::Iec, Policy::Cvc] {
            let survivors = repartition_survivors(&g, 3, policy);
            check_invariants(&g, &survivors);
            let fresh = partition(&g, 3, policy);
            assert_eq!(survivors.owner, fresh.owner, "{policy:?}");
            for (a, b) in survivors.parts.iter().zip(&fresh.parts) {
                assert_eq!(a.l2g, b.l2g, "{policy:?}");
                assert_eq!(a.num_masters, b.num_masters, "{policy:?}");
            }
        }
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("oec"), Some(Policy::Oec));
        assert_eq!(Policy::parse("iec"), Some(Policy::Iec));
        assert_eq!(Policy::parse("cvc"), Some(Policy::Cvc));
        assert_eq!(Policy::parse("x"), None);
    }
}
