//! CuSP-like streaming graph partitioner (paper §5; Hoang et al. [13]).
//!
//! Splits a global graph into per-GPU partitions under three policies:
//!
//! * **OEC** (outgoing edge cut): vertices are assigned to owners by
//!   contiguous ranges balanced on *out*-degree; a partition holds every
//!   out-edge of its masters. Remote destinations appear as read/write
//!   *mirrors* (reduced back to masters after each round).
//! * **IEC** (incoming edge cut): ranges balanced on *in*-degree; a
//!   partition holds every in-edge of its masters; remote sources are
//!   read-only mirrors (refreshed by broadcast).
//! * **CVC** (cartesian vertex cut — the paper's default for multi-GPU
//!   runs): owners form a `pr x pc` grid; edge `(u, v)` goes to the
//!   partition at (row of u's owner, column of v's owner), bounding both
//!   mirror fan-in and fan-out.
//!
//! Every partition gets a local CSR (local ids: masters first, then
//! mirrors), plus the local<->global maps the Gluon-style communication
//! layer ([`crate::comm`]) uses.

use std::collections::HashMap;

use crate::graph::{CsrGraph, EdgeList};

/// Partitioning policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Oec,
    Iec,
    Cvc,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Oec => "oec",
            Policy::Iec => "iec",
            Policy::Cvc => "cvc",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "oec" => Some(Policy::Oec),
            "iec" => Some(Policy::Iec),
            "cvc" => Some(Policy::Cvc),
            _ => None,
        }
    }
}

/// One GPU's partition.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: u32,
    /// Local CSR over local ids.
    pub graph: CsrGraph,
    /// local id -> global id (masters first, then mirrors).
    pub l2g: Vec<u32>,
    /// Local ids `[0, num_masters)` are masters owned by this partition.
    pub num_masters: usize,
}

impl Partition {
    pub fn num_mirrors(&self) -> usize {
        self.l2g.len() - self.num_masters
    }

    /// Global ids of this partition's mirrors.
    pub fn mirror_globals(&self) -> &[u32] {
        &self.l2g[self.num_masters..]
    }
}

/// The partitioned graph plus ownership metadata.
#[derive(Debug, Clone)]
pub struct DistGraph {
    pub policy: Policy,
    pub num_global: u32,
    /// Owner partition of each global vertex.
    pub owner: Vec<u32>,
    pub parts: Vec<Partition>,
    /// Per-partition global->local maps.
    pub g2l: Vec<HashMap<u32, u32>>,
}

impl DistGraph {
    pub fn num_parts(&self) -> usize {
        self.parts.len()
    }

    /// Total mirrors across partitions (replication overhead metric).
    pub fn total_mirrors(&self) -> usize {
        self.parts.iter().map(|p| p.num_mirrors()).sum()
    }
}

/// Assign contiguous owner ranges balanced by `weight(v)` (degree).
fn balanced_ranges(weights: &[u64], k: u32) -> Vec<u32> {
    let total: u64 = weights.iter().sum();
    let per = total.div_ceil(k as u64).max(1);
    let mut owner = vec![0u32; weights.len()];
    let mut acc = 0u64;
    let mut cur = 0u32;
    for (v, &w) in weights.iter().enumerate() {
        owner[v] = cur;
        acc += w;
        if acc >= per * (cur as u64 + 1) && cur + 1 < k {
            cur += 1;
        }
    }
    owner
}

/// Grid shape for CVC: the most square `pr x pc = k` factorization.
pub fn cvc_grid(k: u32) -> (u32, u32) {
    let mut best = (1, k);
    let mut r = 1;
    while r * r <= k {
        if k % r == 0 {
            best = (r, k / r);
        }
        r += 1;
    }
    best
}

/// Partition `g` into `k` parts.
pub fn partition(g: &CsrGraph, k: u32, policy: Policy) -> DistGraph {
    assert!(k >= 1);
    let n = g.num_vertices();
    // Owner assignment.
    let owner = match policy {
        Policy::Oec | Policy::Cvc => {
            let w: Vec<u64> = (0..n as u32).map(|v| g.out_degree(v) + 1).collect();
            balanced_ranges(&w, k)
        }
        Policy::Iec => {
            let mut counts = vec![1u64; n];
            for &d in &g.col_idx {
                counts[d as usize] += 1;
            }
            balanced_ranges(&counts, k)
        }
    };
    let (rows, cols) = cvc_grid(k);

    // Edge -> partition assignment.
    let mut edge_lists: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); k as usize];
    for u in 0..n as u32 {
        let (dsts, ws) = g.out_edges(u);
        for (&v, &w) in dsts.iter().zip(ws) {
            let p = match policy {
                Policy::Oec => owner[u as usize],
                Policy::Iec => owner[v as usize],
                Policy::Cvc => {
                    let r = owner[u as usize] % rows;
                    let c = owner[v as usize] % cols;
                    r * cols + c
                }
            };
            edge_lists[p as usize].push((u, v, w));
        }
    }

    // Build per-partition local graphs. A dense scratch map (global id ->
    // local id, reset per partition) keeps edge remapping O(1) per edge —
    // the public g2l HashMap is only built once per local vertex (§Perf:
    // replaced per-edge HashMap lookups and a sort-based mirror dedup).
    let mut parts = Vec::with_capacity(k as usize);
    let mut g2l_all = Vec::with_capacity(k as usize);
    let mut dense = vec![u32::MAX; n];
    let mut is_mirror = vec![false; n];
    for pid in 0..k {
        let edges = &edge_lists[pid as usize];
        // Mark mirrors: non-owned endpoints of local edges.
        for &(u, v, _) in edges {
            if owner[u as usize] != pid {
                is_mirror[u as usize] = true;
            }
            if owner[v as usize] != pid {
                is_mirror[v as usize] = true;
            }
        }
        // Local vertex set: own masters first (so every owned vertex exists
        // locally even if isolated), then mirrors in sorted global order
        // (the 0..n scan yields them sorted for free).
        let mut locals: Vec<u32> =
            (0..n as u32).filter(|&v| owner[v as usize] == pid).collect();
        let num_masters = locals.len();
        for v in 0..n as u32 {
            if is_mirror[v as usize] {
                locals.push(v);
                is_mirror[v as usize] = false; // reset for the next pass
            }
        }
        let l2g = locals;
        let mut g2l = HashMap::with_capacity(l2g.len());
        for (l, &gid) in l2g.iter().enumerate() {
            dense[gid as usize] = l as u32;
            g2l.insert(gid, l as u32);
        }
        let mut el = EdgeList::new(l2g.len() as u32);
        el.edges.reserve(edges.len());
        for &(u, v, w) in edges {
            el.push(dense[u as usize], dense[v as usize], w);
        }
        for &gid in &l2g {
            dense[gid as usize] = u32::MAX; // reset scratch
        }
        parts.push(Partition {
            id: pid,
            graph: CsrGraph::from_edge_list(&el),
            l2g,
            num_masters,
        });
        g2l_all.push(g2l);
    }
    DistGraph { policy, num_global: n as u32, owner, parts, g2l: g2l_all }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::rmat::{self, RmatConfig};

    fn test_graph() -> CsrGraph {
        CsrGraph::from_edge_list(&rmat::generate(&RmatConfig::paper(9, 11)))
    }

    fn check_invariants(g: &CsrGraph, dg: &DistGraph) {
        // 1. every global vertex has exactly one owner, and appears as a
        //    master in exactly that partition.
        let mut master_count = vec![0u32; g.num_vertices()];
        for p in &dg.parts {
            for (l, &gid) in p.l2g.iter().enumerate() {
                if l < p.num_masters {
                    assert_eq!(dg.owner[gid as usize], p.id);
                    master_count[gid as usize] += 1;
                }
            }
        }
        assert!(master_count.iter().all(|&c| c == 1));
        // 2. edges are preserved exactly (as a multiset, global ids).
        let mut want: Vec<(u32, u32, u32)> = Vec::new();
        for u in 0..g.num_vertices() as u32 {
            let (d, w) = g.out_edges(u);
            for (&v, &x) in d.iter().zip(w) {
                want.push((u, v, x as u32));
            }
        }
        want.sort_unstable();
        let mut got: Vec<(u32, u32, u32)> = Vec::new();
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                let (d, w) = p.graph.out_edges(lu);
                for (&lv, &x) in d.iter().zip(w) {
                    got.push((p.l2g[lu as usize], p.l2g[lv as usize], x as u32));
                }
            }
        }
        got.sort_unstable();
        assert_eq!(want, got);
        // 3. l2g/g2l inverse.
        for (pi, p) in dg.parts.iter().enumerate() {
            for (l, &gid) in p.l2g.iter().enumerate() {
                assert_eq!(dg.g2l[pi][&gid], l as u32);
            }
        }
    }

    #[test]
    fn oec_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Oec));
    }

    #[test]
    fn iec_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Iec));
    }

    #[test]
    fn cvc_invariants() {
        let g = test_graph();
        check_invariants(&g, &partition(&g, 4, Policy::Cvc));
        check_invariants(&g, &partition(&g, 6, Policy::Cvc));
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let g = test_graph();
        let dg = partition(&g, 1, Policy::Oec);
        assert_eq!(dg.parts.len(), 1);
        assert_eq!(dg.parts[0].num_masters, g.num_vertices());
        assert_eq!(dg.parts[0].graph.num_edges(), g.num_edges());
        assert_eq!(dg.total_mirrors(), 0);
    }

    #[test]
    fn oec_masters_hold_their_out_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Oec);
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                if p.graph.out_degree(lu) > 0 {
                    // Only masters have out-edges under OEC.
                    assert!((lu as usize) < p.num_masters);
                }
            }
        }
    }

    #[test]
    fn iec_masters_hold_their_in_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Iec);
        for p in &dg.parts {
            for lu in 0..p.graph.num_vertices() as u32 {
                let (dsts, _) = p.graph.out_edges(lu);
                for &lv in dsts {
                    assert!((lv as usize) < p.num_masters);
                }
            }
        }
    }

    #[test]
    fn oec_balances_out_edges() {
        let g = test_graph();
        let dg = partition(&g, 4, Policy::Oec);
        let loads: Vec<usize> =
            dg.parts.iter().map(|p| p.graph.num_edges()).collect();
        let max = *loads.iter().max().unwrap() as f64;
        let mean = g.num_edges() as f64 / 4.0;
        assert!(max / mean < 2.0, "edge balance {loads:?}");
    }

    #[test]
    fn cvc_grid_shapes() {
        assert_eq!(cvc_grid(1), (1, 1));
        assert_eq!(cvc_grid(4), (2, 2));
        assert_eq!(cvc_grid(6), (2, 3));
        assert_eq!(cvc_grid(16), (4, 4));
        assert_eq!(cvc_grid(7), (1, 7));
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("oec"), Some(Policy::Oec));
        assert_eq!(Policy::parse("iec"), Some(Policy::Iec));
        assert_eq!(Policy::parse("cvc"), Some(Policy::Cvc));
        assert_eq!(Policy::parse("x"), None);
    }
}
