//! Run configuration: the *framework* presets the paper compares
//! (Table 2 / Figures 6–11), expressed as (balancer, worklist) combinations
//! inside our simulator — same substrate, only the strategy varies, which
//! isolates the variable the paper studies.

use crate::apps::engine::{ComputeMode, EngineConfig};
use crate::apps::worklist::WorklistKind;
use crate::gpu::{CostModel, GpuSpec};
use crate::lb::{Balancer, Distribution};

/// A framework under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    /// D-IrGL with TWC only (no inter-block balancing) — the main baseline.
    DIrglTwc,
    /// D-IrGL with the paper's Adaptive Load Balancer — the contribution.
    DIrglAlb,
    /// Gunrock with its TWC policy (sparse explicit worklists).
    GunrockTwc,
    /// Gunrock with its static LB policy: all active edges split evenly
    /// every round, chosen up front, never adaptive.
    GunrockLb,
    /// Lux-style: vertex-balanced executor without inter-block balancing.
    Lux,
}

/// Frameworks in the paper's Table 2 column order.
pub const TABLE2_FRAMEWORKS: [Framework; 4] = [
    Framework::GunrockTwc,
    Framework::GunrockLb,
    Framework::DIrglTwc,
    Framework::DIrglAlb,
];

impl Framework {
    pub fn name(&self) -> &'static str {
        match self {
            Framework::DIrglTwc => "d-irgl(twc)",
            Framework::DIrglAlb => "d-irgl(alb)",
            Framework::GunrockTwc => "gunrock(twc)",
            Framework::GunrockLb => "gunrock(lb)",
            Framework::Lux => "lux",
        }
    }

    pub fn parse(s: &str) -> Option<Framework> {
        match s.to_ascii_lowercase().as_str() {
            "d-irgl-twc" | "dirgl-twc" | "twc" => Some(Framework::DIrglTwc),
            "d-irgl-alb" | "dirgl-alb" | "alb" => Some(Framework::DIrglAlb),
            "gunrock-twc" => Some(Framework::GunrockTwc),
            "gunrock-lb" | "gunrock" => Some(Framework::GunrockLb),
            "lux" => Some(Framework::Lux),
            _ => None,
        }
    }

    /// Every spelling [`Framework::parse`] accepts, for error messages
    /// that name the valid set (the C001 lint rule).
    pub const NAMES: &'static str =
        "d-irgl-twc|dirgl-twc|twc, d-irgl-alb|dirgl-alb|alb, gunrock-twc, \
         gunrock-lb|gunrock, lux";

    /// The balancer/worklist combination this framework stands for.
    pub fn engine_config(&self, spec: GpuSpec) -> EngineConfig {
        let (balancer, worklist) = match self {
            Framework::DIrglTwc => (Balancer::Twc, WorklistKind::Dense),
            Framework::DIrglAlb => (
                Balancer::Alb { distribution: Distribution::Cyclic, threshold: None },
                WorklistKind::Dense,
            ),
            Framework::GunrockTwc => (Balancer::Twc, WorklistKind::Sparse),
            Framework::GunrockLb => (
                Balancer::EdgeLb { distribution: Distribution::Cyclic },
                WorklistKind::Sparse,
            ),
            Framework::Lux => (Balancer::Vertex, WorklistKind::Dense),
        };
        EngineConfig {
            balancer,
            worklist,
            spec,
            cost: CostModel::default(),
            compute: ComputeMode::Native,
            max_rounds: 1_000_000,
            ..EngineConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for f in [
            Framework::DIrglTwc,
            Framework::DIrglAlb,
            Framework::GunrockTwc,
            Framework::GunrockLb,
            Framework::Lux,
        ] {
            // name() contains punctuation; parse accepts the CLI spellings.
            assert!(Framework::parse(match f {
                Framework::DIrglTwc => "dirgl-twc",
                Framework::DIrglAlb => "dirgl-alb",
                Framework::GunrockTwc => "gunrock-twc",
                Framework::GunrockLb => "gunrock-lb",
                Framework::Lux => "lux",
            })
            .is_some());
            let _ = f.name();
        }
        assert_eq!(Framework::parse("nope"), None);
    }

    #[test]
    fn alb_preset_is_adaptive_cyclic_dense() {
        let cfg = Framework::DIrglAlb.engine_config(GpuSpec::default_sim());
        assert!(matches!(
            cfg.balancer,
            Balancer::Alb { distribution: Distribution::Cyclic, threshold: None }
        ));
        assert_eq!(cfg.worklist, WorklistKind::Dense);
    }

    #[test]
    fn gunrock_uses_sparse_worklists() {
        for f in [Framework::GunrockTwc, Framework::GunrockLb] {
            assert_eq!(
                f.engine_config(GpuSpec::default_sim()).worklist,
                WorklistKind::Sparse
            );
        }
    }

    #[test]
    fn lux_is_vertex_balanced() {
        let cfg = Framework::Lux.engine_config(GpuSpec::default_sim());
        assert_eq!(cfg.balancer, Balancer::Vertex);
    }
}
